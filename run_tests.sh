#!/usr/bin/env sh
# Tier-1 verify (see ROADMAP.md). Must pass on a bare environment:
# jax + numpy + pytest only — no zstandard, no hypothesis.
set -eu
cd "$(dirname "$0")"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
