"""Cluster mode: hash ring, sharded backend, replication, failover, leases.

The failover tests are the satellite contract of ISSUE 5: shard death during
an in-flight lease (waiters re-elect on the ring), replica read-repair after
a shard restarts, and ``has()`` adoption when the key's primary and replica
disagree.
"""
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import Client
from repro.core import IntermediateStore, MemoryBackend
from repro.net import (
    CachingBackend,
    DistributedSingleFlight,
    HashRing,
    RemoteStoreError,
    ShardedBackend,
    StoreServer,
    StoreUnreachable,
)
from repro.net.protocol import parse_urls


# -- helpers -------------------------------------------------------------------
def _cluster(n=3, backend_factory=MemoryBackend):
    servers = [StoreServer(backend_factory()).start() for _ in range(n)]
    urls = ",".join(f"127.0.0.1:{s.port}" for s in servers)
    return servers, urls


def _sharded(urls, **kw):
    kw.setdefault("replication", 2)
    kw.setdefault("down_cooldown_s", 0.05)
    kw.setdefault("retries", 1)
    kw.setdefault("retry_backoff_s", 0.01)
    return ShardedBackend(urls, **kw)


def _node_of(server):
    return f"127.0.0.1:{server.port}"


def _key_with_primary(ring, node, tag="k"):
    """A key whose ring primary is ``node`` (exists within a few tries)."""
    for i in range(10_000):
        key = f"{tag}-{i}"
        if ring.primary(key) == node:
            return key
    raise AssertionError(f"no key found with primary {node}")


@pytest.fixture()
def cluster3():
    servers, urls = _cluster(3)
    yield servers, urls
    for s in servers:
        s.stop()


# -- ring ----------------------------------------------------------------------
def test_parse_urls():
    assert parse_urls("tcp://h:1,h:2, other:3") == [("h", 1), ("h", 2), ("other", 3)]
    assert parse_urls("h:7077") == [("h", 7077)]
    with pytest.raises(ValueError):
        parse_urls("h:1,h:1")  # duplicate member = silently halved replication
    with pytest.raises(ValueError):
        parse_urls(",")


def test_ring_balance_and_determinism():
    nodes = ["a:1", "b:1", "c:1"]
    ring = HashRing(nodes)
    keys = [f"key{i}" for i in range(3000)]
    spread = ring.spread(keys)
    # near-uniform: no shard owns less than half or more than double its share
    assert all(500 <= v <= 2000 for v in spread.values()), spread
    # member order is irrelevant: every client routes identically
    ring2 = HashRing(list(reversed(nodes)))
    assert all(ring.order(k) == ring2.order(k) for k in keys[:300])


def test_ring_order_and_replicas():
    ring = HashRing(["a:1", "b:1", "c:1"])
    order = ring.order("some-key")
    assert sorted(order) == ["a:1", "b:1", "c:1"]  # every node, once
    assert ring.primary("some-key") == order[0]
    assert ring.replicas("some-key", 2) == order[:2]
    assert ring.replicas("some-key", 99) == order  # clamped
    assert ring.replicas("some-key", 0) == order[:1]  # at least one
    single = HashRing(["solo:1"])
    assert single.order("x") == ["solo:1"]


def test_ring_remap_is_minimal():
    """Dropping one member remaps only that member's keys (consistent
    hashing's point, vs hash % N remapping almost everything)."""
    keys = [f"key{i}" for i in range(2000)]
    big = HashRing(["a:1", "b:1", "c:1"])
    small = HashRing(["a:1", "b:1"])
    moved = sum(
        1
        for k in keys
        if big.primary(k) != "c:1" and small.primary(k) != big.primary(k)
    )
    assert moved == 0


# -- sharded backend: contract + replication ----------------------------------
def test_sharded_backend_contract(cluster3):
    servers, urls = cluster3
    sb = _sharded(urls)
    try:
        assert sb.ping()
        assert not sb.exists("k")
        sb.write_blob("k", "manifest.json", b"{}")
        sb.write_blob("k", "leaf0.bin", b"\x01" * 100)
        assert sb.exists("k")
        assert sb.read_blob("k", "leaf0.bin") == b"\x01" * 100
        assert sb.nbytes("k") == 102
        with pytest.raises(KeyError):
            sb.read_blob("k", "missing.bin")
        sb.write_meta("index.json", '{"a": 1}')
        assert sb.read_meta("index.json") == '{"a": 1}'
        assert sb.read_meta("nope.json") is None
        sb.delete("k")
        assert not sb.exists("k")
        sb.delete("k")  # idempotent
    finally:
        sb.close()


def test_write_replicates_to_r_shards(cluster3):
    servers, urls = cluster3
    sb = _sharded(urls, replication=2)
    try:
        for i in range(8):
            sb.write_blob(f"k{i}", "manifest.json", b"{}")
        for i in range(8):
            holders = [s for s in servers if s.backend.exists(f"k{i}")]
            assert len(holders) == 2, f"k{i} on {len(holders)} shards, want 2"
            # and they are exactly the ring's replica set
            want = set(sb.ring.replicas(f"k{i}", 2))
            assert {_node_of(s) for s in holders} == want
    finally:
        sb.close()


def test_write_dials_cooldown_replicas(cluster3):
    """A down-marker from a transient blip must not make writes skip a
    replica that is actually alive — a skipped write is silent
    under-replication, invisible until the surviving copy dies too."""
    servers, urls = cluster3
    sb = _sharded(urls, replication=2)
    try:
        targets = sb.ring.replicas("k", 2)
        sb._mark_down(targets[1])  # blip marker; the shard itself is healthy
        sb.write_blob("k", "manifest.json", b"{}")
        holders = {_node_of(s) for s in servers if s.backend.exists("k")}
        assert holders == set(targets), "write must reach cooldown replicas too"
    finally:
        sb.close()


def test_failover_read_when_primary_down(cluster3):
    servers, urls = cluster3
    sb = _sharded(urls, replication=2)
    try:
        sb.write_blob("k", "manifest.json", b"{}")
        sb.write_blob("k", "b", b"payload")
        prim = sb.shard_for("k")
        next(s for s in servers if _node_of(s) == prim).stop()
        assert sb.read_blob("k", "b") == b"payload"
        assert sb.exists("k")
        assert sb.failover_reads >= 1
    finally:
        sb.close()


def test_zero_loss_after_killing_one_shard(cluster3):
    """The acceptance shape in miniature: R=2, kill any one shard, every
    artifact stays readable through the store layer."""
    servers, urls = cluster3
    sb = _sharded(urls, replication=2)
    try:
        store = IntermediateStore(backend=sb)
        keys = [f"art{i}" for i in range(12)]
        for i, key in enumerate(keys):
            store.put(key, np.arange(16.0) + i)
        servers[1].stop()
        for i, key in enumerate(keys):
            assert store.has(key), f"{key} lost after shard kill"
            np.testing.assert_array_equal(
                np.asarray(store.get(key)), np.arange(16.0) + i
            )
    finally:
        sb.close()


def test_corrupt_replica_fails_over_and_heals(cluster3):
    """A replica whose copy repeatedly fails digest verification is treated
    like a miss: the read fails over to a verified-good replica and repairs
    the rotten copy instead of failing the run."""
    from repro.net import IntegrityError

    servers, urls = cluster3
    sb = _sharded(urls, replication=2)
    try:
        sb.write_blob("k", "manifest.json", b"{}")
        sb.write_blob("k", "b", b"good-bytes")
        prim = sb.shard_for("k")

        def corrupt_read(key, name):
            raise IntegrityError(f"blob {key}/{name} failed digest verification")

        sb._shards[prim].read_blob = corrupt_read  # this replica serves rot
        assert sb.read_blob("k", "b") == b"good-bytes"
        assert sb.failover_reads >= 1
        assert sb.read_repairs >= 1  # good bytes written back over the rot
        # every copy bad and every replica reachable -> IntegrityError, not
        # a phantom KeyError (the artifact exists, its bytes are damaged)
        succ = sb.ring.replicas("k", 2)[1]
        sb._shards[succ].read_blob = corrupt_read
        with pytest.raises(IntegrityError):
            sb.read_blob("k", "b")
    finally:
        sb.close()


def test_server_reported_errors_do_not_mark_shard_down(cluster3):
    """A reachable shard rejecting a bad request is not a dead shard: the
    error propagates as plain RemoteStoreError (not StoreUnreachable) and
    routing for other keys is unaffected."""
    servers, urls = cluster3
    sb = _sharded(urls, replication=2)
    try:
        with pytest.raises(RemoteStoreError) as exc:
            sb.write_blob("k", "../evil", b"x")  # server rejects the name
        assert not isinstance(exc.value, StoreUnreachable)
        assert not sb._down_until  # nobody got marked down
        sb.write_blob("k", "manifest.json", b"{}")  # cluster fully usable
        assert sb.exists("k")
    finally:
        sb.close()


def test_read_repair_after_shard_restart(cluster3):
    """Satellite: a shard that restarts empty is healed by the first read
    that falls through it to a surviving replica."""
    servers, urls = cluster3
    sb = _sharded(urls, replication=2)
    try:
        sb.write_blob("k", "manifest.json", b"{}")
        sb.write_blob("k", "b", b"precious")
        prim = sb.shard_for("k")
        idx = next(i for i, s in enumerate(servers) if _node_of(s) == prim)
        port = servers[idx].port
        servers[idx].stop()
        # restart EMPTY on the same port (disk wiped / fresh volume)
        servers[idx] = StoreServer(MemoryBackend(), port=port).start()
        deadline = time.monotonic() + 2  # outlive the down-marker cooldown
        while time.monotonic() < deadline:
            if sb.read_blob("k", "b") == b"precious" and sb.read_repairs:
                break
            time.sleep(0.05)
        assert sb.read_repairs >= 1
        # the restarted primary now holds the healed copy locally
        assert servers[idx].backend.read_blob("k", "b") == b"precious"
    finally:
        sb.close()


def test_exists_undecidable_raises_and_store_has_degrades(cluster3):
    """With the only replica down, absence is unprovable: the backend raises
    BackendUnavailable and ``store.has`` answers False WITHOUT pruning the
    record — the bytes come back when the shard does."""
    servers, urls = cluster3
    sb = _sharded(urls, replication=1)
    try:
        store = IntermediateStore(backend=sb)
        store.put("solo", np.arange(8.0))
        assert store.has("solo")
        prim = sb.shard_for("solo")
        idx = next(i for i, s in enumerate(servers) if _node_of(s) == prim)
        port = servers[idx].port
        servers[idx].stop()
        with pytest.raises(StoreUnreachable):
            sb.exists("solo")
        assert store.has_state("solo") == "unreachable"
        assert not store.has("solo")  # degraded, not crashed
        assert "solo" in store.records  # …and NOT pruned
        # shard returns with its disk intact: artifact is reusable again
        servers[idx] = StoreServer(
            servers[idx].backend, port=port
        ).start()
        deadline = time.monotonic() + 2
        while time.monotonic() < deadline and not store.has("solo"):
            time.sleep(0.05)
        assert store.has("solo")
    finally:
        sb.close()


def test_has_adoption_when_primary_and_replica_disagree(cluster3):
    """Satellite: primary restarted empty, replica still holds the artifact.
    A fresh client's ``has()`` must adopt from the replica (OR-semantics),
    and ``get`` must assemble the value from it."""
    servers, urls = cluster3
    sb1 = _sharded(urls, replication=2)
    try:
        writer = IntermediateStore(backend=sb1)
        writer.put("shared", {"a": jnp.arange(6.0).reshape(2, 3)})
        prim = sb1.shard_for("shared")
        idx = next(i for i, s in enumerate(servers) if _node_of(s) == prim)
        port = servers[idx].port
        servers[idx].stop()
        servers[idx] = StoreServer(MemoryBackend(), port=port).start()
        assert not servers[idx].backend.exists("shared")  # primary: "no"
        sb2 = _sharded(urls, replication=2)
        try:
            reader = IntermediateStore(backend=sb2)
            assert reader.has("shared")  # replica: "yes" wins
            out = reader.get("shared")
            np.testing.assert_array_equal(
                np.asarray(out["a"]), np.arange(6.0).reshape(2, 3)
            )
        finally:
            sb2.close()
    finally:
        sb1.close()


# -- leases on the ring --------------------------------------------------------
def test_lease_routes_to_primary_and_release_works(cluster3):
    servers, urls = cluster3
    sb1, sb2 = _sharded(urls), _sharded(urls)
    try:
        g = sb1.lease_acquire("k", wait=False)
        assert g.granted
        # held server-side on the key's primary
        prim = sb1.shard_for("k")
        srv = next(s for s in servers if _node_of(s) == prim)
        assert srv.stats()["active_leases"] == 1
        assert not sb2.lease_acquire("k", wait=False).granted
        sb1.lease_release("k", g.token, stored=True)
        g2 = sb2.lease_acquire("k", wait=False)
        assert g2.granted
        sb2.lease_release("k", g2.token, stored=False)
    finally:
        sb1.close()
        sb2.close()


def test_shard_death_during_inflight_lease_reelects_on_ring(cluster3):
    """Satellite: the lease primary dies while a leader holds the lease and
    a waiter blocks on it.  The waiter's broken wait must fail over along
    the ring and win a fresh election on the next live shard."""
    servers, urls = cluster3
    sb_leader = _sharded(urls)
    sb_waiter = _sharded(urls)
    try:
        prim_node = _node_of(servers[0])
        key = _key_with_primary(sb_leader.ring, prim_node, tag="lease")
        g = sb_leader.lease_acquire(key, wait=False)
        assert g.granted

        out = {}

        def wait_for_lease():
            # the DistributedSingleFlight contention loop in miniature: a
            # wait that ends without the artifact (auto-release of the dying
            # leader, or a transport failure failed over by the ring)
            # re-contends until it is elected
            for _ in range(4):
                grant = sb_waiter.lease_acquire(key, wait=True, timeout_s=30)
                out["grant"] = grant
                if grant.granted or grant.stored:
                    return

        t = threading.Thread(target=wait_for_lease)
        t.start()
        deadline = time.monotonic() + 2  # waiter must be blocked server-side
        while time.monotonic() < deadline and servers[0].stats()["ops"].get(
            "lease_acquire", 0
        ) < 2:
            time.sleep(0.02)
        servers[0].stop()  # primary dies mid-wait
        t.join(timeout=10)
        assert not t.is_alive(), "waiter wedged on a dead shard"
        assert out["grant"].granted, "waiter must re-elect itself on the ring"
        # and the election moved off the dead primary along the ring
        assert sb_waiter.lease_failovers >= 1
        # the stand-in electorate is the ring successor, for every client
        assert sb_waiter.ring.order(key)[1] == sb_leader.ring.order(key)[1]
    finally:
        sb_leader.close()
        sb_waiter.close()


def test_distributed_singleflight_exactly_once_over_cluster(cluster3):
    servers, urls = cluster3
    computes = []
    lock = threading.Lock()

    def make_client():
        sb = _sharded(urls)
        store = IntermediateStore(backend=CachingBackend(sb))
        sf = DistributedSingleFlight(sb, stored_fn=store.has, lease_timeout_s=10)
        return sb, store, sf

    clients = [make_client() for _ in range(4)]
    barrier = threading.Barrier(4)
    results = []

    def run(i):
        sb, store, sf = clients[i]

        def produce():
            if store.has("cold-key"):
                return np.asarray(store.get("cold-key"))
            with lock:
                computes.append(i)
            time.sleep(0.1)
            value = np.arange(16.0)
            store.put("cold-key", value)
            return value

        barrier.wait()
        value, leader = sf.run("cold-key", produce)
        results.append((i, leader, value))

    threads = [threading.Thread(target=run, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        assert len(computes) == 1, f"expected exactly one compute, got {computes}"
        for _, _, value in results:
            np.testing.assert_array_equal(value, np.arange(16.0))
        assert sum(1 for r in results if r[1]) == 1
    finally:
        for sb, _, _ in clients:
            sb.close()


# -- events + api.Client end to end -------------------------------------------
def test_replicated_delete_events_converge_listeners(cluster3):
    servers, urls = cluster3
    sb1, sb2 = _sharded(urls), _sharded(urls)
    try:
        s2_cache = CachingBackend(sb2)
        s2 = IntermediateStore(backend=s2_cache)
        seen = []

        def on_event(event, key):
            if event == "evicted":
                s2_cache.invalidate(key)
                s2.on_external_evict(key)
                seen.append(key)

        sb2.add_event_listener(on_event)
        deadline = time.monotonic() + 2
        while (
            sum(
                s.stats()["subscribers"] for s in servers
            ) < len(servers)
            and time.monotonic() < deadline
        ):
            time.sleep(0.01)

        s1 = IntermediateStore(backend=sb1)
        s1.put("shared", jnp.ones((8,)))
        assert s2.has("shared")
        s1.evict("shared")
        deadline = time.monotonic() + 2
        while not seen and time.monotonic() < deadline:
            time.sleep(0.01)
        # a replicated delete may broadcast from up to R shards; listeners
        # are idempotent, so convergence — not event count — is the contract
        assert set(seen) == {"shared"}
        assert "shared" not in s2.records
        assert not s2.has("shared")
    finally:
        sb1.close()
        sb2.close()


def test_client_cluster_mode_end_to_end(cluster3):
    servers, urls = cluster3

    def mk(cid):
        c = Client(store_url=urls, replication=2, policy="TSAR", client_id=cid)
        c.register_fn("double", lambda x: x * 2)
        c.register_fn("inc", lambda x, by=1: x + by, by=1)
        return c

    a, b = mk("a"), mk("b")
    try:
        data = jnp.arange(32.0)
        ra = a.run_steps("ds", data, ["double", "inc"], "wa")
        assert ra.n_skipped == 0
        rb = b.run_steps("ds", data, ["double", "inc"], "wb")
        assert rb.n_skipped >= 1, "second client must reuse across the cluster"
        # kill the deepest stored key's primary: a THIRD client still reuses
        key = ra.stored_keys[-1]
        prim = a._remote.shard_for(key)
        next(s for s in servers if _node_of(s) == prim).stop()
        c = mk("c")
        try:
            rc = c.run_steps("ds", data, ["double", "inc"], "wc")
            assert rc.n_skipped >= 1, "kill of one shard must not lose the prefix"
            np.testing.assert_array_equal(
                np.asarray(rc.output), np.asarray(ra.output)
            )
        finally:
            c.close()
    finally:
        a.close()
        b.close()


def test_client_replication_validation():
    with pytest.raises(ValueError, match="replication"):
        Client(policy="TSAR", replication=2)
    with pytest.raises(ValueError, match="replication"):
        Client(store_url="127.0.0.1:1", replication=2)


# -- catalog over the cluster (ISSUE 8) ----------------------------------------
def _catalog_client(urls, cid):
    c = Client(store_url=urls, replication=2, policy="TSAR", client_id=cid)
    c.register_fn("load", lambda d, scale=1: [x * scale for x in d], scale=1)
    return c


def _await_subscribers(servers, n, timeout=2.0):
    deadline = time.monotonic() + timeout
    while (
        sum(s.stats()["subscribers"] for s in servers) < n
        and time.monotonic() < deadline
    ):
        time.sleep(0.01)


def test_catalog_writes_follow_blob_replica_sets(cluster3):
    servers, urls = cluster3
    c = _catalog_client(urls, "cat-route")
    try:
        for scale in range(4):
            spec = c.spec("ds")
            spec.chain([("load", {"scale": scale})])
            c.run(spec, [1, 2, 3])
        hits = c.find(module="load")
        assert len(hits) == 4
        # each record lives on exactly the shards that hold its blob
        for h in hits:
            replicas = set(c._remote._replicas(h.key))
            for s in servers:
                has_rec = s.catalog.get(h.key) is not None
                assert has_rec == (_node_of(s) in replicas), h.key
    finally:
        c.close()


def test_concurrent_evictions_event_delivery_and_catalog_convergence(cluster3):
    """Satellite: concurrent evictions across the cluster — every eviction
    event is delivered to the subscribed client (at-least-once; replicated
    deletes may broadcast up to R times), and once the stream drains the
    catalog never reports an evicted artifact as present."""
    servers, urls = cluster3
    c = _catalog_client(urls, "cat-evt")
    try:
        for scale in range(6):
            spec = c.spec("ds")
            spec.chain([("load", {"scale": scale})])
            c.run(spec, [1, 2, 3])
        keys = sorted(h.key for h in c.find(module="load"))
        assert len(keys) == 6

        seen: list[str] = []
        c._remote.add_event_listener(
            lambda ev, k: seen.append(k) if ev == "evicted" else None
        )
        _await_subscribers(servers, len(servers))

        victims = keys[:3]
        sb = _sharded(urls)
        try:
            threads = [
                threading.Thread(target=sb.delete, args=(k,)) for k in victims
            ]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            # drain: the victims leave the client's local records AND its
            # catalog index via the event listeners
            deadline = time.monotonic() + 5
            while time.monotonic() < deadline and any(
                k in c.catalog.index or k in c.store.records for k in victims
            ):
                time.sleep(0.02)

            assert set(victims) <= set(seen), "every eviction must be delivered"
            for k in victims:
                assert k not in c.catalog.index
                assert k not in c.store.records
                # the shard-side indexes pruned on delete too
                assert all(s.catalog.get(k) is None for s in servers)
            # zero phantoms: find answers exactly the survivors
            assert sorted(h.key for h in c.find(module="load")) == keys[3:]
        finally:
            sb.close()
    finally:
        c.close()


def test_cluster_find_zero_phantoms_after_shard_kill(cluster3):
    """Acceptance: kill one shard; ``Client.find`` answers from the replicas
    and every returned record's artifact is verifiably present."""
    servers, urls = cluster3
    c = _catalog_client(urls, "cat-kill")
    try:
        for scale in range(5):
            spec = c.spec("ds")
            spec.chain([("load", {"scale": scale})])
            c.run(spec, [1, 2, 3])
        before = {h.key for h in c.find(module="load")}
        assert len(before) == 5

        servers[0].stop()
        # a fresh client has no local index: answers come from the surviving
        # shards' catalogs, then get presence-verified in one batched probe
        c2 = _catalog_client(urls, "cat-kill-2")
        try:
            hits = c2.find(module="load")
            assert {h.key for h in hits} == before, "replicas cover the dead shard"
            presence = c2.store.has_state_many([h.key for h in hits])
            assert all(v == "present" for v in presence.values()), presence
        finally:
            c2.close()
    finally:
        c.close()
