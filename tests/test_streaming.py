"""Streaming data plane: fault injection, op-count regressions, cache guard.

The ISSUE 6 satellite contract:

* **torn streams** — a peer killed mid-chunked-put or mid-chunked-get must
  never leave a partial blob visible to ``exists``/``get``, the server must
  reclaim its spill file, and ``RemoteBackend``'s reconnect-and-retry must
  complete the op against a restarted server;
* **op counts** — a depth-d reuse-probe walk issues O(1) batched round
  trips (was O(d)), and ``ShardedBackend``'s batch fan-out sends at most
  one request per involved shard — both asserted against ``server_stats``
  counters, not wall-clock;
* **cache guard** — ``CachingBackend`` refuses to cache any single blob
  larger than ``max_entry_fraction`` of its capacity, so one huge artifact
  cannot evict the entire hot set.
"""
import pathlib
import socket
import time

import pytest

import jax.numpy as jnp
import numpy as np

from repro.core import IntermediateStore, LocalFSBackend, MemoryBackend, TSAR
from repro.core.executor import probe_reusable_prefix
from repro.core.workflow import ModuleRef, PrefixKey
from repro.net import (
    CachingBackend,
    IntegrityError,
    PROTO_VERSION,
    RemoteBackend,
    ShardedBackend,
    StoreServer,
)
from repro.net import protocol as P


@pytest.fixture()
def server(tmp_path):
    srv = StoreServer(LocalFSBackend(tmp_path / "pool")).start()
    yield srv
    srv.stop()


def _fast_backend(url, **kw):
    kw.setdefault("retries", 2)
    kw.setdefault("retry_backoff_s", 0.01)
    kw.setdefault("stream_threshold", 4096)
    kw.setdefault("chunk_bytes", 8192)
    return RemoteBackend(url, **kw)


def _spill_leftovers(pool_root):
    """Any dot-tmp spill file the server failed to reclaim."""
    root = pathlib.Path(pool_root)
    return [p for p in root.rglob("*") if p.name.startswith(".") and ".tmp." in p.name]


# -- chunked transfer end-to-end ----------------------------------------------
def test_chunked_put_get_roundtrip(server):
    rb = _fast_backend(server.url)
    try:
        big = bytes(bytearray(range(256)) * 300)  # ~75 KiB, many chunks
        assert rb.write_blob("k", "big.bin", big) == len(big)
        assert rb.read_blob("k", "big.bin") == big
        assert rb.streamed_writes == 1
        assert rb.streamed_reads == 1
        st = rb.server_stats()
        assert st["proto"] == PROTO_VERSION
        assert st["streaming"]["streamed_writes"] == 1
        assert st["streaming"]["chunks_in"] >= 9
    finally:
        rb.close()


def test_small_blobs_stay_one_shot(server):
    rb = _fast_backend(server.url)
    try:
        rb.write_blob("k", "small.bin", b"tiny")
        assert rb.read_blob("k", "small.bin") == b"tiny"
        assert rb.streamed_writes == 0
        assert rb.streamed_reads == 0
    finally:
        rb.close()


def test_chunked_get_after_server_restart_no_sidecar(tmp_path):
    """A restarted server has an empty digest sidecar: the first chunked
    read folds server-side and repopulates it; the second can zero-copy."""
    srv = StoreServer(LocalFSBackend(tmp_path / "pool")).start()
    port = srv.port
    rb = _fast_backend(srv.url, retries=6)
    try:
        big = b"\xab" * 50_000
        rb.write_blob("k", "b.bin", big)
        srv.stop()
        srv = StoreServer(LocalFSBackend(tmp_path / "pool"), port=port).start()
        assert rb.read_blob("k", "b.bin") == big  # fold-and-record pass
        assert rb.read_blob("k", "b.bin") == big  # sidecar (sendfile) pass
        assert rb.server_stats()["streaming"].get("sendfile_reads", 0) >= 1
    finally:
        rb.close()
        srv.stop()


# -- fault injection: torn streams --------------------------------------------
def test_torn_chunked_put_leaves_no_partial(server, tmp_path):
    """Kill the client mid-chunked-put: nothing visible, spill reclaimed."""
    raw = socket.create_connection((server.host, server.port), timeout=5)
    P.send_frame(
        raw,
        {"op": "write_blob_chunked", "key": "torn", "name": "manifest.json",
         "size": 1 << 20, "chunk_bytes": 1 << 14},
    )
    ack, _ = P.recv_frame(raw)
    assert ack.get("ready")
    P.send_chunk(raw, b"x" * (1 << 14))  # one chunk of 64, then die
    raw.close()

    rb = _fast_backend(server.url)
    try:
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline:
            if server.stats()["streaming"].get("spill_aborts", 0) >= 1:
                break
            time.sleep(0.02)
        assert server.stats()["streaming"].get("spill_aborts", 0) >= 1
        assert rb.exists("torn") is False
        with pytest.raises(KeyError):
            rb.read_blob("torn", "manifest.json")
        assert _spill_leftovers(tmp_path / "pool") == []
        # the same op, completed by a healthy client, lands fine afterwards
        rb.write_blob("torn", "manifest.json", b"{}" * 40000)
        assert rb.exists("torn") is True
    finally:
        rb.close()


def test_torn_chunked_get_does_not_wedge_server(server):
    rb = _fast_backend(server.url)
    try:
        big = b"\xcd" * 120_000
        rb.write_blob("k", "b.bin", big)
        # hand-roll a chunked GET and vanish after the first chunk
        raw = socket.create_connection((server.host, server.port), timeout=5)
        P.send_frame(
            raw,
            {"op": "read_blob", "key": "k", "name": "b.bin",
             "accept_chunked": True, "stream_min_bytes": 1, "chunk_bytes": 4096},
        )
        resp, _ = P.recv_frame(raw)
        assert resp.get("chunked") and resp["size"] == len(big)
        buf = bytearray(4096)
        P.recv_frame_into(raw, memoryview(buf))  # take one chunk…
        raw.close()  # …and die with ~29 more in flight
        # the server must shrug it off and keep serving everyone else
        assert rb.ping()
        assert rb.read_blob("k", "b.bin") == big
    finally:
        rb.close()


def test_abort_end_frame_discards_stream(server, tmp_path):
    """A client can abort its own put cleanly; the server must discard."""
    raw = socket.create_connection((server.host, server.port), timeout=5)
    P.send_frame(
        raw,
        {"op": "write_blob_chunked", "key": "ab", "name": "manifest.json",
         "size": 1 << 16, "chunk_bytes": 1 << 14},
    )
    ack, _ = P.recv_frame(raw)
    assert ack.get("ready")
    P.send_chunk(raw, b"y" * (1 << 14))
    P.send_stream_end(raw, abort=True, error="caller changed its mind", kind="client")
    resp, _ = P.recv_frame(raw)
    assert not resp["ok"] and resp["kind"] == "aborted"
    raw.close()
    rb = _fast_backend(server.url)
    try:
        assert rb.exists("ab") is False
        assert _spill_leftovers(tmp_path / "pool") == []
    finally:
        rb.close()


def test_chunked_put_digest_mismatch_rejected(server):
    raw = socket.create_connection((server.host, server.port), timeout=5)
    data = b"z" * 9000
    P.send_frame(
        raw,
        {"op": "write_blob_chunked", "key": "bad", "name": "manifest.json",
         "size": len(data), "chunk_bytes": 4096},
    )
    ack, _ = P.recv_frame(raw)
    assert ack.get("ready")
    for off in range(0, len(data), 4096):
        P.send_chunk(raw, data[off : off + 4096])
    P.send_stream_end(raw, digest_hex="0" * 64)  # lie about the digest
    resp, _ = P.recv_frame(raw)
    assert not resp["ok"] and resp["kind"] == "integrity"
    raw.close()
    rb = _fast_backend(server.url)
    try:
        assert rb.exists("bad") is False
    finally:
        rb.close()


def test_server_restart_mid_streaming_ops_retries_complete(tmp_path):
    """RemoteBackend's reconnect-and-retry covers the chunked paths too:
    a whole torn stream replays on a fresh socket against the new server."""
    srv = StoreServer(LocalFSBackend(tmp_path / "pool")).start()
    port = srv.port
    rb = _fast_backend(srv.url, retries=6, retry_backoff_s=0.05)
    try:
        big = bytes(bytearray(range(256)) * 400)
        rb.write_blob("k", "manifest.json", big)
        srv.stop()
        srv = StoreServer(LocalFSBackend(tmp_path / "pool"), port=port).start()
        assert rb.read_blob("k", "manifest.json") == big  # chunked read, retried
        rb.write_blob("k2", "manifest.json", big)  # chunked write, fresh epoch
        assert rb.exists("k2")
        assert rb.reconnects > 0
        assert rb.streamed_reads >= 1 and rb.streamed_writes >= 2
    finally:
        rb.close()
        srv.stop()


# -- op-count regressions ------------------------------------------------------
def _chain(depth, dataset="ds"):
    mods = tuple(ModuleRef(f"m{i}") for i in range(depth))
    return PrefixKey(dataset, mods)


def test_probe_walk_is_one_round_trip(server):
    """Depth-8 probe walk: one ``batch`` request, zero singular ``exists``."""
    rb = _fast_backend(server.url)
    try:
        store = IntermediateStore(backend=rb)
        policy = TSAR()
        before = rb.server_stats()["ops"]
        prefix, value, _ = probe_reusable_prefix(store, policy, _chain(8))
        after = rb.server_stats()["ops"]
        assert prefix is None and value is None
        assert after.get("batch", 0) - before.get("batch", 0) == 1
        assert after.get("exists", 0) == before.get("exists", 0)
        # total round trips for the whole walk: the one batch (+ the stats
        # request that read ``after`` itself)
        delta_requests = sum(after.values()) - sum(before.values())
        assert delta_requests == 2
    finally:
        rb.close()


def test_probe_walk_loads_deepest_present(server):
    rb = _fast_backend(server.url)
    try:
        store = IntermediateStore(backend=rb)
        policy = TSAR()
        chain = _chain(8)
        hit = chain.parent().parent()  # depth 6
        store.put(hit.key(policy.with_state), jnp.arange(16.0))
        before = rb.server_stats()["ops"]
        prefix, value, _ = probe_reusable_prefix(store, policy, chain)
        after = rb.server_stats()["ops"]
        assert prefix == hit
        np.testing.assert_array_equal(np.asarray(value), np.arange(16.0))
        assert after.get("batch", 0) - before.get("batch", 0) == 1
        assert after.get("exists", 0) == before.get("exists", 0)
    finally:
        rb.close()


def test_has_state_many_matches_has_state(server):
    rb = _fast_backend(server.url)
    try:
        store = IntermediateStore(backend=rb)
        store.put("alive", jnp.arange(4.0))
        states = store.has_state_many(["alive", "ghost-a", "ghost-b"])
        assert states == {
            "alive": "present",
            "ghost-a": "absent",
            "ghost-b": "absent",
        }
        for k, want in states.items():
            assert store.has_state(k) == want
    finally:
        rb.close()


def test_sharded_batch_at_most_one_request_per_shard(tmp_path):
    servers = [
        StoreServer(LocalFSBackend(tmp_path / f"pool{i}")).start() for i in range(3)
    ]
    sb = ShardedBackend(
        ",".join(f"127.0.0.1:{s.port}" for s in servers),
        replication=2,
        retries=1,
        retry_backoff_s=0.01,
    )
    try:
        keys = [f"key-{i}" for i in range(24)]
        sb.write_blob(keys[0], "manifest.json", b"{}")
        before = {s.port: s.stats()["ops"].get("batch", 0) for s in servers}
        out = sb.exists_many(keys)
        after = {s.port: s.stats()["ops"].get("batch", 0) for s in servers}
        assert out[keys[0]] is True
        assert all(out[k] is False for k in keys[1:])
        for port in before:
            assert after[port] - before[port] <= 1  # ≤ one request per shard
        assert sum(after.values()) - sum(before.values()) >= 1
    finally:
        sb.close()
        for s in servers:
            s.stop()


def test_sharded_exists_many_undecidable_is_none(tmp_path):
    """With a dead shard, keys whose full replica set is unreachable come
    back ``None`` (undecidable) — never a false ``False``."""
    servers = [
        StoreServer(LocalFSBackend(tmp_path / f"pool{i}")).start() for i in range(2)
    ]
    sb = ShardedBackend(
        ",".join(f"127.0.0.1:{s.port}" for s in servers),
        replication=1,  # one replica: a dead shard makes its keys undecidable
        retries=0,
        retry_backoff_s=0.01,
    )
    try:
        keys = [f"k{i}" for i in range(16)]
        dead = servers[1]
        dead_node = f"127.0.0.1:{dead.port}"
        dead_keys = [k for k in keys if sb.shard_for(k) == dead_node]
        assert dead_keys, "hash ring should land some keys on each shard"
        dead.stop()
        out = sb.exists_many(keys)
        for k in keys:
            assert out[k] is (None if k in dead_keys else False)
    finally:
        sb.close()
        servers[0].stop()


def test_remote_exists_many_unreachable_is_none():
    rb = RemoteBackend("tcp://127.0.0.1:1", retries=0, retry_backoff_s=0.01)
    try:
        assert rb.exists_many(["a", "b"]) == {"a": None, "b": None}
    finally:
        rb.close()


def test_batch_falls_back_to_pipelining_on_v1_server(server, monkeypatch):
    """Against a server without the batch op the client pipelines the sub-ops
    on one socket — and remembers, so it never re-probes."""
    rb = _fast_backend(server.url)
    try:
        monkeypatch.delattr(StoreServer, "_op_batch")
        rb.write_blob("k", "manifest.json", b"{}")
        out = rb.exists_many(["k", "ghost"])
        assert out == {"k": True, "ghost": False}
        assert rb._server_proto == 1
        st = rb.server_stats()
        assert st["ops"].get("exists", 0) >= 2  # pipelined singular ops
    finally:
        rb.close()


def test_chunked_write_falls_back_on_v1_server(server, monkeypatch):
    rb = _fast_backend(server.url)
    try:
        monkeypatch.delattr(StoreServer, "_op_write_blob_chunked")
        big = b"\x77" * 50_000
        rb.write_blob("k", "b.bin", big)
        assert rb.read_blob("k", "b.bin") == big
        assert rb.streamed_writes == 0
        assert rb._server_proto == 1
    finally:
        rb.close()


# -- CachingBackend oversize guard (satellite fix) -----------------------------
def test_cache_rejects_oversize_entry():
    inner = MemoryBackend()
    cache = CachingBackend(inner, capacity_bytes=1000, max_entry_fraction=0.25)
    # populate a hot set of small blobs
    for i in range(3):
        cache.write_blob(f"k{i}", "b", bytes([i]) * 200)
    hot = cache.cached_bytes
    assert hot == 600
    # a blob over 25% of capacity must pass through uncached…
    cache.write_blob("huge", "b", b"\xff" * 600)
    assert cache.oversize_rejected == 1
    assert cache.cached_bytes == hot  # …without evicting the hot set
    # and reading it back stays uncached but correct
    assert cache.read_blob("huge", "b") == b"\xff" * 600
    assert cache.oversize_rejected == 2
    # the small hot set still serves from cache
    misses = cache.misses
    assert cache.read_blob("k0", "b") == b"\x00" * 200
    assert cache.misses == misses and cache.hits >= 1


def test_cache_default_fraction_allows_half():
    cache = CachingBackend(MemoryBackend(), capacity_bytes=1000)
    cache.write_blob("k", "b", b"x" * 500)  # exactly half: allowed
    assert cache.oversize_rejected == 0
    assert cache.cached_bytes == 500
    with pytest.raises(ValueError):
        CachingBackend(MemoryBackend(), capacity_bytes=10, max_entry_fraction=0.0)
