"""Beyond-paper perf features: chunked attention, streaming CE, MoE EP
annotations — correctness vs reference paths."""
import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.models.attention import chunked_gqa_attention, gqa_attention
from repro.models.layers import init_params
from repro.models.transformer import streaming_ce_loss
from repro.train import build_loss_fn, build_param_specs

CELL = ShapeCell("t", "train", {"seq_len": 64, "global_batch": 2})


def test_chunked_attention_bitexact_incl_grad():
    rng = np.random.default_rng(0)
    B, S, Hq, Hkv, d = 2, 96, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, Hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, d)), jnp.float32)
    for window, gf in [(None, None), (16, None), (16, jnp.asarray(0.0))]:
        a = gqa_attention(q, k, v, causal=True, window=window, global_flag=gf)
        b = chunked_gqa_attention(
            q, k, v, causal=True, window=window, global_flag=gf, block_q=32
        )
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    g1 = jax.grad(lambda q: gqa_attention(q, k, v).sum())(q)
    g2 = jax.grad(lambda q: chunked_gqa_attention(q, k, v, block_q=32).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


def test_streaming_ce_matches_dense_ce():
    rng = np.random.default_rng(1)
    B, S, d, V = 2, 8, 16, 96
    x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    head = jnp.asarray(rng.normal(size=(d, V)), jnp.float32)
    t = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    ref = -jnp.take_along_axis(
        jax.nn.log_softmax(x @ head, -1), t[..., None], -1
    )[..., 0].mean()
    for n in (1, 2, 4, 8):
        np.testing.assert_allclose(
            float(streaming_ce_loss(x, head, t, n)), float(ref), rtol=1e-6
        )


def test_lm_loss_vocab_chunks_equals_dense():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), build_param_specs(cfg, CELL), jnp.float32)
    from repro.data import make_batch

    batch = make_batch(cfg, CELL, seed=0)
    dense = build_loss_fn(cfg, CELL)(params, batch)[0]
    cfg_c = dataclasses.replace(cfg, loss_vocab_chunks=8)
    chunked = build_loss_fn(cfg_c, CELL)(params, batch)[0]
    np.testing.assert_allclose(float(dense), float(chunked), rtol=1e-5)


def test_chunked_attention_impl_in_model_matches():
    cfg = get_config("gemma3-4b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), build_param_specs(cfg, CELL), jnp.float32)
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 48)), jnp.int32)
    from repro.models import transformer

    ref, _ = transformer.forward(params, cfg, tokens)
    cfg_c = dataclasses.replace(cfg, attention_impl="chunked", attn_block_q=16)
    out, _ = transformer.forward(params, cfg_c, tokens)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=1e-4, rtol=1e-4)


def test_moe_ep_annotations_preserve_values():
    cfg = get_config("qwen2-moe-a2.7b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    params = init_params(jax.random.PRNGKey(0), build_param_specs(cfg, CELL), jnp.float32)
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
    from repro.models import transformer

    ref, _ = transformer.forward(params, cfg, tokens)
    # single-device mesh: annotations must be value-neutral
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg_a = dataclasses.replace(cfg, moe_ep_axis="model", moe_token_axes=("data",))
    from repro.launch.mesh import mesh_context

    with mesh_context(mesh):
        out, _ = jax.jit(lambda p, t: transformer.forward(p, cfg_a, t))(params, tokens)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out), atol=1e-5)
