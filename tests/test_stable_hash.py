"""Regression tests for ``_stable_hash`` determinism (ISSUE 2 satellite).

The old implementation fell back to ``repr`` for non-JSON leaves, which (a)
leaked memory addresses (``<object at 0x...>``) into digests — unique per
process, silently defeating cross-process reuse — and (b) collided on large
arrays whose reprs are elided (``[0 1 2 ... 999]``)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.workflow import ToolState, _stable_hash


def test_same_array_hashes_equal_in_fresh_encoders():
    # two independently-constructed equal arrays must hash identically
    # (the old repr fallback was value-based only by accident of smallness)
    a = np.arange(8, dtype=np.float32)
    b = np.arange(8, dtype=np.float32)
    assert a is not b
    assert _stable_hash(a) == _stable_hash(b)
    assert _stable_hash({"x": a}) == _stable_hash({"x": b})


def test_large_arrays_do_not_collide():
    # np.repr elides the middle of large arrays; the old encoder hashed the
    # elided repr, colliding on arrays that differ only in the middle
    a = np.zeros(100_000, dtype=np.float32)
    b = a.copy()
    b[50_000] = 1.0
    assert repr(a) == repr(b)  # the collision the old encoder inherited
    assert _stable_hash(a) != _stable_hash(b)


def test_dtype_and_shape_distinguish():
    a = np.zeros(16, dtype=np.float32)
    assert _stable_hash(a) != _stable_hash(a.astype(np.float64))
    assert _stable_hash(a) != _stable_hash(a.reshape(4, 4))


def test_jax_arrays_hash_like_numpy():
    a = jnp.arange(8.0)
    assert _stable_hash(a) == _stable_hash(np.arange(8, dtype=np.float32))


def test_address_bearing_repr_rejected():
    class Opaque:
        pass

    with pytest.raises(TypeError, match="memory address"):
        _stable_hash(Opaque())
    with pytest.raises(TypeError):
        _stable_hash({"nested": [1, 2, object()]})


def test_containers_canonicalize():
    assert _stable_hash({"a": 1, "b": 2}) == _stable_hash({"b": 2, "a": 1})
    assert _stable_hash({1, 2, 3}) == _stable_hash({3, 2, 1})
    assert _stable_hash((1, 2)) == _stable_hash([1, 2])
    assert _stable_hash(b"abc") == _stable_hash(b"abc")
    assert _stable_hash(b"abc") != _stable_hash(b"abd")


def test_tool_state_digests_unchanged_for_plain_params():
    # ToolState params are (str, str) tuples — already JSON-safe; the digest
    # must stay byte-compatible with pre-fix stores (pinned value)
    state = ToolState.from_config({"by": 3, "mode": "fast"})
    assert state.digest == _stable_hash(state.params)
    assert ToolState.from_config(None).digest == "default"
    # deterministic across fresh objects
    assert state.digest == ToolState.from_config({"mode": "fast", "by": 3}).digest
