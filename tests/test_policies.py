"""Policy replay + metric invariants (unit + property tests)."""
from _hypothesis_compat import HealthCheck, given, settings, st

from repro.core import (
    TSAR,
    TSFR,
    TSPAR,
    Workflow,
    evaluate_all,
    generate_corpus,
    make_policy,
)
from repro.core.corpus import CorpusSpec


def small_corpus(seed=0, n=60, with_state=False):
    return generate_corpus(
        CorpusSpec(
            n_workflows=n,
            n_datasets=6,
            n_modules=30,
            mean_len=6,
            with_state=with_state,
            seed=seed,
        )
    )


def test_tsar_stores_all_prefixes_dedup():
    wfs = [
        Workflow.build("D1", ["A", "B", "C"]),
        Workflow.build("D1", ["A", "B", "D"]),
    ]
    pol = TSAR()
    pol.step(wfs[0])
    pol.step(wfs[1])
    # prefixes: A, AB, ABC from wf1; A, AB (dup) + ABD from wf2 -> 4 distinct
    assert pol.n_stored == 4
    assert pol.n_reusable_pipelines == 1  # wf2 reuses AB


def test_tsfr_full_rerun_reuses_final():
    pol = TSFR()
    pol.step(Workflow.build("D1", ["A", "B"]))
    rec = pol.step(Workflow.build("D1", ["A", "B"]))
    assert rec.reuse is not None and rec.reuse.depth == 2
    assert pol.n_stored == 1


def test_tsfr_stored_final_usable_as_prefix():
    pol = TSFR()
    pol.step(Workflow.build("D1", ["A", "B"]))
    rec = pol.step(Workflow.build("D1", ["A", "B", "C"]))
    assert rec.reuse is not None and rec.reuse.depth == 2


def test_tspar_stores_only_previously_appeared():
    pol = TSPAR()
    rec1 = pol.step(Workflow.build("D1", ["A", "B"]))
    assert rec1.store == []  # nothing appeared before
    rec2 = pol.step(Workflow.build("D1", ["A", "C"]))
    assert len(rec2.store) == 1 and rec2.store[0].depth == 1  # A appeared before


def test_reuse_is_longest_prefix():
    pol = TSAR()
    pol.step(Workflow.build("D1", ["A", "B", "C", "D"]))
    rec = pol.step(Workflow.build("D1", ["A", "B", "C", "E"]))
    assert rec.reuse is not None and rec.reuse.depth == 3


@settings(max_examples=20, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10_000), with_state=st.booleans())
def test_metric_invariants(seed, with_state):
    corpus = small_corpus(seed=seed, with_state=with_state)
    reports = evaluate_all(corpus, with_state=with_state)
    pt, tsar, tspar, tsfr = (
        reports["PT"],
        reports["TSAR"],
        reports["TSPAR"],
        reports["TSFR"],
    )
    # TSAR stores a superset => its reuse likeliness dominates everything
    assert tsar.lr >= pt.lr
    assert tsar.lr >= tspar.lr
    assert tsar.lr >= tsfr.lr
    # storing-all cannot store fewer than the selective policies
    assert tsar.n_stored >= pt.n_stored
    assert tsar.n_stored >= tspar.n_stored
    assert tsar.n_stored >= tsfr.n_stored
    # all PISRS within [0, 100]; all totals consistent
    for r in reports.values():
        assert 0 <= r.pisrs <= 100.0
        assert 0 <= r.lr <= 100.0
        assert r.n_stored_reused <= r.n_stored
        assert r.total_intermediate_states == sum(len(w) for w in corpus)


@settings(max_examples=15, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10_000))
def test_history_extension_monotone_support(seed):
    """Adding pipelines never decreases a rule's support."""
    corpus = small_corpus(seed=seed, n=30)
    from repro.core import RuleMiner

    m = RuleMiner()
    probe = corpus[0].prefix(1)
    prev = 0
    for wf in corpus:
        m.add(wf)
        cur = m.support(probe)
        assert cur >= prev
        prev = cur


def test_pt_stores_at_most_one_per_pipeline():
    corpus = small_corpus(seed=3)
    pol = make_policy("PT")
    for wf in corpus:
        rec = pol.step(wf)
        assert len(rec.store) <= 1
    assert pol.n_stored <= len(corpus)
