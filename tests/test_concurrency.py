"""Race-reproduction tests: store index + eviction bookkeeping under
concurrent workers (barrier-synchronized to maximize interleaving)."""
import json
import threading

import numpy as np

import jax.numpy as jnp

from repro.core import IntermediateStore


N_THREADS = 8


def _run_threads(n, fn):
    barrier = threading.Barrier(n)
    errors = []

    def runner(i):
        try:
            barrier.wait()
            fn(i)
        except Exception as e:  # noqa: BLE001 - surfaced in the assertion
            errors.append(e)

    threads = [threading.Thread(target=runner, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return errors


def test_concurrent_puts_keep_index_consistent(tmp_path):
    """N workers put distinct artifacts through one store at the same instant;
    without the index lock this corrupts ``records``/``index.json`` (dict
    mutation during iteration, interleaved partial flushes)."""
    store = IntermediateStore(tmp_path / "s")

    def put_many(i):
        for j in range(6):
            store.put(f"k{i}.{j}", jnp.full((64,), float(i * 10 + j)))

    errors = _run_threads(N_THREADS, put_many)
    assert not errors, errors
    assert len(store.records) == N_THREADS * 6
    for i in range(N_THREADS):
        for j in range(6):
            np.testing.assert_array_equal(
                np.asarray(store.get(f"k{i}.{j}")), np.full((64,), float(i * 10 + j))
            )
    # the persisted index must be a clean snapshot another process can load
    # (flushes are batched now: flush() persists the tail before reopening)
    store.flush()
    reopened = IntermediateStore(tmp_path / "s")
    assert len(reopened.records) == N_THREADS * 6


def test_concurrent_mixed_ops_no_corruption(tmp_path):
    """puts + gets + deletes + accounting racing on one store."""
    store = IntermediateStore(tmp_path / "s")
    for j in range(8):
        store.put(f"seed{j}", jnp.arange(32.0) + j)

    def mixed(i):
        for j in range(8):
            store.put(f"t{i}.{j}", jnp.ones((16,)) * i)
            _ = store.total_disk_bytes
            if store.has(f"seed{j}"):
                try:
                    store.get(f"seed{j}")
                except KeyError:
                    pass  # deleted by a sibling: acceptable, not corruption
            if i % 2 == 0:
                store.delete(f"seed{j}")

    errors = _run_threads(N_THREADS, mixed)
    assert not errors, errors
    for i in range(N_THREADS):
        for j in range(8):
            assert store.has(f"t{i}.{j}")


def test_concurrent_puts_respect_budget_and_evict_bookkeeping(tmp_path):
    """Eviction under concurrency: budget holds, listener fires for every
    evicted key exactly once, and evictor byte accounting matches."""
    capacity = 64 * 1024
    store = IntermediateStore(tmp_path / "s", capacity_bytes=capacity, eviction="lru")
    evicted = []
    evict_lock = threading.Lock()

    def listener(key):
        with evict_lock:
            evicted.append(key)

    store.add_evict_listener(listener)

    def put_many(i):
        for j in range(10):
            store.put(f"k{i}.{j}", jnp.arange(2048.0) + i * 100 + j)  # 8KB raw

    errors = _run_threads(N_THREADS, put_many)
    assert not errors, errors
    assert store.total_disk_bytes <= capacity
    assert len(evicted) == len(set(evicted)), "listener fired twice for a key"
    assert store.evictor.n_evictions == len(evicted)
    # every evicted key is really gone; every surviving record is readable
    for key in evicted:
        assert not store.has(key)
    for key in list(store.records):
        np.testing.assert_array_equal(
            np.asarray(store.get(key)).shape, (2048,)
        )


def test_index_flush_is_atomic_snapshot(tmp_path):
    """index.json written while readers/writers race must always parse."""
    store = IntermediateStore(tmp_path / "s")

    def churn(i):
        for j in range(5):
            store.put(f"c{i}.{j}", jnp.ones((8,)))
            raw = store.backend.read_meta("index.json")
            if raw:
                json.loads(raw)  # must never observe a torn write

    errors = _run_threads(N_THREADS, churn)
    assert not errors, errors


def test_tiered_backend_concurrent_read_demote_race(tmp_path):
    """Readers racing ``_shrink_hot``: a tiny hot tier demotes constantly
    while N threads read/write/delete.  Without the hot-tier lock this
    crashes (LRU mutated during iteration) or corrupts ``_hot_nbytes``;
    a read losing its hot entry mid-flight must fall back to cold."""
    from repro.core import LocalFSBackend, TieredBackend

    tiered = TieredBackend(
        LocalFSBackend(tmp_path / "cold"), hot_capacity_bytes=2048
    )
    payloads = {f"k{i}": bytes([i]) * 700 for i in range(12)}
    for k, v in payloads.items():
        tiered.write_blob(k, "manifest.json", v)

    def churn(i):
        for j in range(60):
            k = f"k{(i + j) % 12}"
            got = tiered.read_blob(k, "manifest.json")
            assert got == payloads[k]
            if j % 10 == 5:
                tiered.write_blob(k, "manifest.json", payloads[k])

    errors = _run_threads(N_THREADS, churn)
    assert not errors, errors
    # at-rest accounting must be exact and within budget
    assert tiered._hot_bytes() == sum(
        tiered.hot.nbytes(k) for k in list(tiered.hot._objects)
    )
    assert tiered._hot_bytes() <= tiered.hot_capacity_bytes
    assert tiered.demotions > 0  # the race window was actually exercised
