"""Pluggable backends, codec registry, and gain-loss eviction."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    IntermediateStore,
    LocalFSBackend,
    MemoryBackend,
    RISP,
    TSAR,
    TieredBackend,
    WorkflowExecutor,
    available_codecs,
    gain_loss_ratio,
    resolve_codec,
)
from repro.core.eviction import EvictionContext
from repro.core.store import ArtifactRecord


def _pytree():
    return {
        "a": jnp.arange(12.0).reshape(3, 4),
        "b": [np.int32(7), jnp.ones((2, 2), jnp.bfloat16)],
    }


def _assert_roundtrip(store):
    value = _pytree()
    res = store.put("k", value)
    assert res.admitted and store.has("k")
    out = store.get("k")
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(value["a"]))
    assert out["b"][1].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["b"][1]), np.ones((2, 2)))


def _backends(tmp_path):
    return {
        "localfs": LocalFSBackend(tmp_path / "fs"),
        "memory": MemoryBackend(),
        "tiered": TieredBackend(LocalFSBackend(tmp_path / "cold")),
    }


@pytest.mark.parametrize("name", ["localfs", "memory", "tiered"])
def test_roundtrip_each_backend(tmp_path, name):
    _assert_roundtrip(IntermediateStore(backend=_backends(tmp_path)[name]))


@pytest.mark.parametrize("codec", ["none", "zlib"])
def test_roundtrip_each_codec(tmp_path, codec):
    _assert_roundtrip(IntermediateStore(tmp_path / codec, codec=codec))


def test_codec_registry():
    assert {"none", "zlib"} <= set(available_codecs())
    payload = b"abc" * 1000
    for name in available_codecs():
        c = resolve_codec(name)
        assert c.decompress(c.compress(payload)) == payload
    with pytest.raises(KeyError):
        resolve_codec("snappy")


def test_default_codec_is_best_available(tmp_path):
    store = IntermediateStore(tmp_path)
    expected = "zstd" if "zstd" in available_codecs() else "zlib"
    assert store.codec.name == expected


def test_tiered_serves_hot_reads_and_demotes(tmp_path):
    cold = LocalFSBackend(tmp_path / "cold")
    tiered = TieredBackend(cold, hot_capacity_bytes=4096)
    store = IntermediateStore(backend=tiered, codec="none")
    store.put("small", jnp.arange(16.0))  # fits hot
    store.put("big", jnp.arange(2048.0))  # 8KB > hot capacity once mirrored
    # demotion kept the hot tier under its budget, cold still has everything
    assert tiered._hot_bytes() <= tiered.hot_capacity_bytes
    np.testing.assert_array_equal(np.asarray(store.get("small")), np.arange(16.0))
    np.testing.assert_array_equal(np.asarray(store.get("big")), np.arange(2048.0))
    # reading a cold-only artifact promotes it when it fits
    tiered._hot_drop("small")
    before = tiered.promotions
    store.get("small")
    assert tiered.promotions > before  # manifest/skeleton/leaf blobs re-cached


def test_eviction_keeps_store_under_budget(tmp_path):
    budget = 3000
    store = IntermediateStore(tmp_path, codec="none", capacity_bytes=budget)
    for i in range(12):
        store.put(f"k{i}", jnp.arange(128.0) + i, compute_seconds=0.01)
        assert store.total_disk_bytes <= budget
    assert len(store.records) < 12  # something was actually evicted
    assert store.evictor.n_evictions > 0


def test_gain_loss_prefers_precious_artifacts(tmp_path):
    # small+expensive artifact must outlive big+cheap ones under pressure
    store = IntermediateStore(tmp_path, codec="none", capacity_bytes=6000)
    store.put("precious", jnp.arange(32.0), compute_seconds=120.0)
    for i in range(8):
        store.put(f"bulk{i}", jnp.arange(512.0) + i, compute_seconds=1e-4)
    assert store.has("precious")
    assert store.total_disk_bytes <= 6000


def test_gain_loss_ratio_orders_by_value():
    ctx = EvictionContext(load_bps=1e9)
    precious = ArtifactRecord("p", 100, 100, save_s=0.01, compute_s=10.0)
    cheap = ArtifactRecord("c", 1_000_000, 1_000_000, save_s=0.01, compute_s=1e-4)
    assert gain_loss_ratio(precious, ctx) > gain_loss_ratio(cheap, ctx)


def test_oversized_artifact_not_admitted(tmp_path):
    store = IntermediateStore(tmp_path, codec="none", capacity_bytes=100)
    res = store.put("huge", jnp.arange(1024.0))
    assert not res.admitted
    assert not store.has("huge")
    assert store.total_disk_bytes == 0


def test_executor_eviction_clears_policy_stored(tmp_path):
    policy = TSAR(with_state=True)  # distinct tool states -> many distinct keys
    store = IntermediateStore(tmp_path / "s", codec="none", capacity_bytes=4096)
    ex = WorkflowExecutor(store=store, policy=policy)
    ex.register_fn("double", lambda x: x * 2)
    ex.register_fn("inc", lambda x, by=1: x + by, by=1)
    data = jnp.arange(128.0)  # 512B per artifact
    for i in range(20):
        ex.run("ds", data, ["double", "inc", ("inc", {"by": i})], f"w{i}")
        assert store.total_disk_bytes <= 4096
    assert store.evictor.n_evictions > 0
    # every key the policy still believes is stored must exist in the store
    for key in policy.stored:
        assert key in store.records, f"stale policy entry {key}"


def test_lru_policy_available(tmp_path):
    store = IntermediateStore(
        tmp_path, codec="none", capacity_bytes=2000, eviction="lru"
    )
    for i in range(8):
        store.put(f"k{i}", jnp.arange(128.0) + i)
    assert store.total_disk_bytes <= 2000
    # LRU keeps the most recent key regardless of value
    assert store.has("k7")


def test_index_survives_reopen_with_backend_meta(tmp_path):
    s1 = IntermediateStore(tmp_path / "s", codec="zlib")
    s1.put("k", jnp.arange(4), compute_seconds=0.5)
    s1.close()  # index flushes are batched; close persists the tail
    s2 = IntermediateStore(tmp_path / "s", codec="zlib")
    assert s2.has("k")
    assert s2.records["k"].compute_s == 0.5
    np.testing.assert_array_equal(np.asarray(s2.get("k")), np.arange(4))


def test_unflushed_artifact_adopted_on_reopen(tmp_path):
    """Crash before an index flush must not lose the artifact: the reopened
    store re-discovers it from the backend on first probe."""
    s1 = IntermediateStore(tmp_path / "s", codec="zlib")
    s1.put("k", jnp.arange(6.0))
    # simulate a crash: no flush/close; wipe the in-memory index path
    s2 = IntermediateStore(tmp_path / "s", codec="zlib")
    assert s2.has("k")  # adopted from backend existence, not the index
    np.testing.assert_array_equal(np.asarray(s2.get("k")), np.arange(6.0))


def test_risp_executor_runs_on_memory_backend():
    # end-to-end: policy + executor entirely in memory (no disk I/O)
    ex = WorkflowExecutor(
        store=IntermediateStore(backend=MemoryBackend(), codec="none"),
        policy=RISP(),
    )
    ex.register_fn("double", lambda x: x * 2)
    data = jnp.arange(8.0)
    r1 = ex.run("ds", data, ["double", "double"], "w1")  # mines the rule
    ex.run("ds", data, ["double", "double"], "w2")  # support>=2: stores
    r3 = ex.run("ds", data, ["double", "double"], "w3")  # reuses
    assert r3.n_skipped >= 1
    np.testing.assert_array_equal(np.asarray(r1.output), np.asarray(r3.output))


class _MetaCountingBackend(MemoryBackend):
    """Counts index flushes so the O(n^2)-churn regression stays fixed."""

    def __init__(self):
        super().__init__()
        self.meta_writes = 0

    def write_meta(self, name, text):
        self.meta_writes += 1
        super().write_meta(name, text)


def test_index_flush_is_batched_not_per_put():
    """100 puts must NOT rewrite index.json 100 times (the old O(n^2) churn);
    the dirty-flag batches flushes by count/interval and close() persists
    the tail."""
    backend = _MetaCountingBackend()
    store = IntermediateStore(
        backend=backend, codec="none", index_flush_interval_s=3600.0
    )
    for i in range(100):
        store.put(f"k{i}", jnp.ones((4,)) * i)
    # flush_every=64 default: one threshold flush, nothing per-put
    assert backend.meta_writes <= 100 // store.index_flush_every + 1
    store.close()
    reopened = IntermediateStore(backend=backend, codec="none")
    assert len(reopened.records) == 100
    np.testing.assert_array_equal(np.asarray(reopened.get("k42")), np.full((4,), 42.0))


def test_index_flush_interval_forces_write():
    backend = _MetaCountingBackend()
    store = IntermediateStore(
        backend=backend, codec="none", index_flush_interval_s=0.0
    )
    store.put("a", jnp.ones((2,)))
    assert backend.meta_writes == 1  # zero interval: every mutation flushes
