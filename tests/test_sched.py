"""DAG model, scheduler, single-flight, and WorkflowService concurrency."""
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (
    IntermediateStore,
    ProvenanceLog,
    RISP,
    TSAR,
    WorkflowExecutor,
)
from repro.sched import (
    DagScheduler,
    DagWorkflow,
    DagWorkflowError,
    SingleFlight,
    WorkflowService,
)


@pytest.fixture()
def store(tmp_path):
    return IntermediateStore(tmp_path / "store")


def make_service(store, policy=None, max_workers=4, **kw):
    svc = WorkflowService(
        store=store, policy=policy or TSAR(with_state=True), max_workers=max_workers, **kw
    )
    calls = {"double": 0, "inc": 0, "merge": 0, "fail": 0}
    lock = threading.Lock()

    def count(name, fn):
        def wrapped(x, **params):
            with lock:
                calls[name] += 1
            return fn(x, **params)

        return wrapped

    svc.register_fn("double", count("double", lambda x: x * 2))
    svc.register_fn("inc", count("inc", lambda x, by=1: x + by), by=1)
    svc.register_fn("merge", count("merge", lambda xs: sum(xs[1:], xs[0])))

    def failing(x):
        with lock:
            calls["fail"] += 1
        raise RuntimeError("boom")

    svc.register_fn("fail", failing)
    return svc, calls


# -- DAG model ----------------------------------------------------------------
def test_dag_validation_errors():
    dag = DagWorkflow("ds")
    with pytest.raises(ValueError):
        dag.validate()  # empty
    dag.add("a", "double")
    with pytest.raises(ValueError):
        dag.add("a", "double")  # duplicate id
    with pytest.raises(ValueError):
        dag.add("b", "inc", after="nope")  # unknown parent


def test_dag_topo_order_and_structure():
    dag = DagWorkflow("ds")
    dag.add("a", "m1")
    dag.add("b", "m2", after="a")
    dag.add("c", "m3", after="a")
    dag.add("d", "m4", after=("b", "c"))
    assert dag.topo_order() == ("a", "b", "c", "d")
    assert dag.roots() == ("a",)
    assert dag.sinks() == ("d",)
    assert dag.children_of("a") == ("b", "c")


def test_dag_chain_prefix_linear_ancestry_only():
    dag = DagWorkflow("ds")
    dag.add("a", "m1")
    dag.add("b", "m2", after="a")
    dag.add("c", "m3", after="a")
    dag.add("d", "m4", after=("b", "c"))
    dag.add("e", "m5", after="d")
    assert dag.chain_prefix("b").key() == "ds::m1>m2"
    assert dag.chain_prefix("c").key() == "ds::m1>m3"
    assert dag.chain_prefix("d") is None  # fan-in
    assert dag.chain_prefix("e") is None  # fan-in ancestor


def test_dag_path_decomposition():
    dag = DagWorkflow("ds", "w")
    dag.add("a", "m1")
    dag.add("b", "m2", after="a")
    dag.add("c", "m3", after="a")
    dag.add("d", "m4", after=("b", "c"))
    paths = dag.paths()
    keys = sorted(wf.prefix(len(wf)).key() for wf in paths)
    assert keys == ["ds::m1>m2>m4", "ds::m1>m3>m4"]


def test_dag_from_workflow_roundtrip(store):
    ex = WorkflowExecutor(store=store, policy=TSAR(with_state=True))
    ex.register_fn("double", lambda x: x * 2)
    ex.register_fn("inc", lambda x, by=1: x + by, by=1)
    wf = ex.make_workflow("ds", ["double", ("inc", {"by": 3})], "w")
    dag = DagWorkflow.from_workflow(wf)
    last = dag.topo_order()[-1]
    # lifted chain produces the exact sequential prefix identities
    assert dag.chain_prefix(last).key(True) == wf.prefix(2).key(True)


# -- single-flight ------------------------------------------------------------
def test_singleflight_one_leader():
    sf = SingleFlight()
    calls = []
    barrier = threading.Barrier(8)
    results = []

    def compute():
        calls.append(1)
        time.sleep(0.1)
        return 42

    def racer():
        barrier.wait()
        results.append(sf.run("k", compute))

    threads = [threading.Thread(target=racer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(calls) == 1
    assert all(v == 42 for v, _ in results)
    assert sum(1 for _, leader in results if leader) == 1
    assert sf.leads == 1 and sf.waits == 7 and sf.in_flight == 0


def test_singleflight_leader_failure_propagates():
    sf = SingleFlight()
    started = threading.Event()
    errors = []

    def compute():
        started.set()
        time.sleep(0.05)
        raise ValueError("boom")

    def leader():
        with pytest.raises(ValueError):
            sf.run("k", compute)

    def follower():
        started.wait()
        try:
            sf.run("k", lambda: 1)
        except ValueError as e:
            errors.append(e)

    t1 = threading.Thread(target=leader)
    t2 = threading.Thread(target=follower)
    t1.start()
    t2.start()
    t1.join()
    t2.join()
    # follower either coalesced onto the failing flight (sees the error) or
    # arrived after it resolved (computed 1 itself) — never hangs
    assert sf.in_flight == 0


# -- scheduler ----------------------------------------------------------------
def test_dag_matches_sequential_executor(store, tmp_path):
    """A chain DAG must produce the sequential executor's exact output and
    share its stored artifact identities (cross-front-door reuse)."""
    ex = WorkflowExecutor(store=store, policy=TSAR(with_state=True))
    ex.register_fn("double", lambda x: x * 2)
    ex.register_fn("inc", lambda x, by=1: x + by, by=1)
    data = jnp.linspace(-2, 2, 16)
    seq = ex.run("ds", data, ["double", ("inc", {"by": 3})], "w1")

    svc, calls = make_service(IntermediateStore(tmp_path / "s2"))
    r = svc.run_steps("ds", data, ["double", ("inc", {"by": 3})], "w2")
    np.testing.assert_array_equal(np.asarray(seq.output), np.asarray(r.output))
    svc.close()

    # same registry defaults => same prefix keys: a DAG run against the
    # sequential executor's store reuses its artifacts
    svc2, calls2 = make_service(store, policy=ex.policy)
    r2 = svc2.run_steps("ds", data, ["double", ("inc", {"by": 3})], "w3")
    assert calls2["double"] == 0 and calls2["inc"] == 0  # fully reused
    assert r2.n_skipped == 2
    np.testing.assert_array_equal(np.asarray(seq.output), np.asarray(r2.output))
    svc2.close()


def test_dag_fan_out_fan_in_correctness(store):
    svc, calls = make_service(store)
    dag = svc.dag("ds", "w1")
    dag.add("a", "double")
    dag.add("b", "inc", {"by": 3}, after="a")
    dag.add("c", "inc", {"by": 5}, after="a")
    dag.add("m", "merge", after=("b", "c"))
    data = jnp.arange(4.0)
    r = svc.run(dag, data)
    expect = (np.arange(4.0) * 2 + 3) + (np.arange(4.0) * 2 + 5)
    np.testing.assert_allclose(np.asarray(r.output), expect)
    assert calls["double"] == 1  # shared prefix computed once within the run
    assert r.node_results["m"].key is None  # fan-in: not store-addressable
    svc.close()


def test_dag_prefix_reuse_and_pruning(store):
    svc, calls = make_service(store)
    data = jnp.arange(6.0)
    svc.run_steps("ds", data, ["double", ("inc", {"by": 1}), "double"], "w1")
    assert calls["double"] == 2
    # second run extends a stored prefix: ancestors are pruned, not re-run
    r2 = svc.run_steps("ds", data, ["double", ("inc", {"by": 1}), ("inc", {"by": 9})], "w2")
    assert calls["double"] == 2 and calls["inc"] == 2
    assert r2.n_skipped == 2
    assert r2.reused_prefix is not None and r2.reused_prefix.depth == 2
    sources = {n: res.source for n, res in r2.node_results.items()}
    assert sorted(sources.values()) == ["computed", "loaded", "pruned"]
    np.testing.assert_allclose(np.asarray(r2.output), np.arange(6.0) * 2 + 1 + 9)
    svc.close()


def test_dag_module_failure_raises_and_recovers(store):
    svc, calls = make_service(store)
    data = jnp.arange(4.0)
    dag = svc.dag("ds", "w1")
    dag.add("a", "double")
    dag.add("b", "inc", after="a")
    dag.add("f", "fail", after="b")
    with pytest.raises(DagWorkflowError) as ei:
        svc.run(dag, data)
    assert ei.value.node_id == "f"
    # recovery point persisted: retry with a fixed tail skips the good prefix
    r = svc.run_steps("ds", data, ["double", "inc", "double"], "w2")
    assert calls["double"] == 2 and calls["inc"] == 1
    np.testing.assert_allclose(np.asarray(r.output), (np.arange(4.0) * 2 + 1) * 2)
    svc.close()


def test_dag_provenance_records(store, tmp_path):
    log = ProvenanceLog(tmp_path / "prov.jsonl")
    svc, _ = make_service(store, provenance=log)
    svc.run_steps("ds", jnp.arange(4.0), ["double", "inc"], "w1")
    svc.close()
    assert len(log) == 1
    rec = log.records[0]
    assert rec.extra.get("scheduler") == "dag"
    assert len(rec.modules) == 2 and len(rec.module_seconds) == 2


def test_scheduler_worker_counts_equivalent(tmp_path):
    """Same DAG, same results at 1 and 4 workers (determinism)."""
    outs = []
    for workers in (1, 4):
        svc, _ = make_service(
            IntermediateStore(tmp_path / f"s{workers}"), max_workers=workers
        )
        dag = svc.dag("ds", "w")
        dag.add("a", "double")
        for i in range(6):
            dag.add(f"b{i}", "inc", {"by": i}, after="a")
        r = svc.run(dag, jnp.arange(8.0))
        outs.append({k: np.asarray(v) for k, v in r.outputs.items()})
        svc.close()
    assert outs[0].keys() == outs[1].keys()
    for k in outs[0]:
        np.testing.assert_array_equal(outs[0][k], outs[1][k])


def test_dag_recomputes_when_planned_load_vanishes(store):
    """A prefix evicted between planning and execution: the worker falls back
    to recomputing the chain inline (recursing through pruned ancestors)."""
    svc, calls = make_service(store)
    data = jnp.arange(4.0)
    svc.run_steps("ds", data, ["double", "inc"], "w1")
    assert calls["double"] == 1 and calls["inc"] == 1

    # the deepest artifact vanishes at get() time, though planning saw it live
    deep_key = [k for k in store.records if ">" in k][0]
    real_get = store.get
    vanished = {"done": False}

    def vanishing_get(key, sharding=None):
        if key == deep_key and not vanished["done"]:
            vanished["done"] = True
            raise KeyError(key)  # simulates eviction between has() and get()
        return real_get(key, sharding)

    store.get = vanishing_get
    try:
        r = svc.run_steps("ds", data, ["double", "inc", ("inc", {"by": 9})], "w2")
    finally:
        store.get = real_get
    assert vanished["done"], "test did not exercise the fallback path"
    np.testing.assert_allclose(np.asarray(r.output), np.arange(4.0) * 2 + 1 + 9)
    # the chain was recomputed from the depth-1 artifact: double not re-run
    assert calls["double"] == 1 and calls["inc"] == 3
    svc.close()


# -- WorkflowService concurrency stress (ISSUE satellite) ---------------------
def test_service_singleflight_stress(tmp_path):
    """≥16 overlapping DAGs sharing one expensive prefix: the prefix is
    computed exactly once, every run succeeds, and the store respects its
    byte budget throughout."""
    capacity = 1 << 20
    store = IntermediateStore(tmp_path / "store", capacity_bytes=capacity)
    svc = WorkflowService(
        store=store, policy=TSAR(with_state=True), max_workers=4
    )
    n_shared = [0]
    lock = threading.Lock()
    release = threading.Event()

    def shared_stem(x):
        with lock:
            n_shared[0] += 1
        release.wait(timeout=5.0)  # hold the flight open until all submitted
        return x * 2

    svc.register_fn("stem", shared_stem)
    svc.register_fn("tail", lambda x, by=0: x + by, by=0)

    futs = []
    for i in range(16):
        dag = svc.dag("ds", f"w{i}")
        dag.add("a", "stem")
        dag.add("b", "tail", {"by": i}, after="a")
        futs.append(svc.submit(dag, jnp.arange(32.0)))
    release.set()
    results = [f.result(timeout=60) for f in futs]

    assert n_shared[0] == 1, "single-flight must compute the shared prefix once"
    for i, r in enumerate(results):
        np.testing.assert_allclose(np.asarray(r.output), np.arange(32.0) * 2 + i)
    stats = svc.stats()
    assert stats.runs == 16 and stats.failures == 0
    assert stats.singleflight_waits >= 1
    assert store.total_disk_bytes <= capacity
    svc.close()


def test_service_stats_shape(store):
    svc, _ = make_service(store)
    svc.run_steps("ds", jnp.arange(4.0), ["double"], "w1")
    svc.run_steps("ds", jnp.arange(4.0), ["double"], "w2")
    st = svc.stats()
    assert st.runs == 2 and st.units_total == 2
    assert 0.0 <= st.reuse_rate <= 1.0
    assert st.throughput_rps > 0
    assert "runs=2" in st.row()
    svc.close()
