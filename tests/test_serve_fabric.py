"""Distributed KV-prefix reuse: codec exactness, racing engines, zero phantoms.

The fabric-serving acceptance contract (ISSUE 10):

* the KV codec round-trips bit-exactly, and a generation served entirely
  from restored snapshots produces the same tokens as a cold prefill;
* two engines racing on one shared prefix prefill it exactly once
  (fleet-wide single-flight election over the store-server lease table);
* a leader dying mid-prefill does not wedge the fleet — a follower
  re-elects and completes;
* eviction anywhere leaves zero phantoms: snapshot records, the policy's
  ``stored`` claims, the provenance catalog, and the tenant ledger all
  converge, in-process and across the event stream.
"""
import threading
import time

import numpy as np
import pytest

import jax

from repro.catalog import Catalog
from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.core import LocalFSBackend, MemoryBackend
from repro.core.kvcodec import load_kv, read_kv_info, save_kv
from repro.core.risp import TSAR
from repro.models.layers import init_params
from repro.net import DistributedSingleFlight, RemoteBackend, StoreServer
from repro.sched.stats import TenantLedger
from repro.serve import FabricSnapshotStore, ServeEngine
from repro.train import build_param_specs

CELL = ShapeCell("t", "train", {"seq_len": 16, "global_batch": 4})


@pytest.fixture(scope="module")
def model():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = init_params(
        jax.random.PRNGKey(1), build_param_specs(cfg, CELL), cfg.dtype
    )
    return cfg, params


@pytest.fixture(scope="module")
def prompt(model):
    cfg, _ = model
    rng = np.random.default_rng(7)
    return rng.integers(0, cfg.vocab, size=24).tolist()  # 3 chunks of 8


@pytest.fixture(scope="module")
def reference(model, prompt):
    """Cold generation — no snapshot ever restored."""
    cfg, params = model
    eng = ServeEngine(cfg, params, max_len=64, chunk=8)
    toks, st = eng.generate(prompt, max_new_tokens=4)
    assert st.chunks_skipped == 0
    return toks


def _fabric_engine(model, backend, **kw):
    cfg, params = model
    snaps = FabricSnapshotStore(backend, **kw)
    return ServeEngine(
        cfg, params, max_len=64, chunk=8, policy=TSAR(), snapshots=snaps
    )


# -- codec ---------------------------------------------------------------------
def test_kv_codec_bit_exact_and_deterministic(tmp_path):
    rng = np.random.default_rng(0)
    tree = {
        "layers": [
            {
                "k": rng.standard_normal((1, 2, 8, 4), dtype=np.float32),
                "v": rng.standard_normal((1, 2, 8, 4), dtype=np.float32),
            }
            for _ in range(2)
        ],
        "pos": np.arange(8, dtype=np.int32),
    }
    backend = LocalFSBackend(tmp_path)
    info = save_kv(backend, "kv/a", tree, 8, prefill_s=0.25)
    out, length, info2 = load_kv(backend, "kv/a", verify=True)
    assert length == 8 and info2.prefill_s == 0.25
    for want, got in zip(
        jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)
    ):
        assert want.dtype == got.dtype and want.shape == got.shape
        # bit-exact, not approximately equal
        np.testing.assert_array_equal(
            want.view(np.uint8), got.view(np.uint8)
        )
    # identical input -> identical payloads and manifest (modulo the
    # save timestamp): the encode is deterministic, so snapshots are
    # content-addressable across processes
    import json as _json

    save_kv(backend, "kv/b", tree, 8, prefill_s=0.25)
    for i in range(info.n_leaves):
        assert backend.read_blob("kv/a", f"kv{i}.bin") == backend.read_blob(
            "kv/b", f"kv{i}.bin"
        )
    m_a = _json.loads(backend.read_blob("kv/a", "manifest.json"))
    m_b = _json.loads(backend.read_blob("kv/b", "manifest.json"))
    m_a.pop("created_at"), m_b.pop("created_at")
    assert m_a == m_b
    assert read_kv_info(backend, "kv/a").n_leaves == info.n_leaves


def test_generation_from_restored_snapshots_matches_cold(
    model, prompt, reference, tmp_path
):
    """An engine that prefilled nothing (every chunk restored from another
    engine's fabric snapshots) must emit the exact same tokens."""
    root = LocalFSBackend(tmp_path)
    writer = _fabric_engine(model, root)
    warm_toks, warm_st = writer.generate(prompt, max_new_tokens=4)
    assert warm_toks == reference
    assert warm_st.stored_prefixes >= 1

    # brand-new engine, brand-new policy, same store root: full-prefix hit
    # on its FIRST request — cross-process adoption through the fabric
    reader = _fabric_engine(model, LocalFSBackend(tmp_path))
    toks, st = reader.generate(prompt, max_new_tokens=4)
    assert st.chunks_skipped == st.n_chunks == 3
    assert toks == reference


# -- racing engines ------------------------------------------------------------
def _served_engine(model, port, **store_kw):
    """One 'process': its own connection, snapshot store, and flight."""
    rb = RemoteBackend(f"127.0.0.1:{port}")
    snaps = FabricSnapshotStore(rb, events_from=rb, **store_kw)
    flight = DistributedSingleFlight(
        rb, stored_fn=snaps.contains, lease_timeout_s=30
    )
    cfg, params = model
    eng = ServeEngine(
        cfg, params, max_len=64, chunk=8,
        policy=TSAR(), snapshots=snaps, flight=flight,
    )
    return eng, flight, rb


def test_racing_engines_prefill_shared_prefix_exactly_once(
    model, prompt, reference
):
    server = StoreServer(MemoryBackend()).start()
    eng_a, flight_a, rb_a = _served_engine(model, server.port)
    eng_b, flight_b, rb_b = _served_engine(model, server.port)
    barrier = threading.Barrier(2)
    results: dict[str, tuple] = {}

    def run(name, eng):
        barrier.wait()
        results[name] = eng.generate(prompt, max_new_tokens=4)

    try:
        threads = [
            threading.Thread(target=run, args=("a", eng_a)),
            threading.Thread(target=run, args=("b", eng_b)),
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120)
        assert set(results) == {"a", "b"}
        stats = {k: v[1] for k, v in results.items()}
        # both emit the cold-reference tokens
        assert results["a"][0] == reference and results["b"][0] == reference
        # exactly one engine won the fleet-wide election and prefilled;
        # the other restored the leader's snapshot and computed nothing
        assert flight_a.remote_leads + flight_b.remote_leads == 1
        leader = "a" if flight_a.remote_leads else "b"
        follower = "b" if leader == "a" else "a"
        assert stats[leader].chunks_skipped == 0
        assert stats[leader].stored_prefixes >= 1
        assert stats[follower].chunks_skipped == stats[follower].n_chunks
        assert stats[follower].stored_prefixes == 0
        # only the leader ever persisted snapshots
        total_saves = int(
            eng_a.snapshots._m_saves.value + eng_b.snapshots._m_saves.value
        )
        assert total_saves == stats[leader].stored_prefixes
    finally:
        rb_a.close()
        rb_b.close()
        server.stop()


def test_follower_reelects_when_leader_dies_mid_prefill(
    model, prompt, reference
):
    server = StoreServer(MemoryBackend()).start()
    eng_a, flight_a, rb_a = _served_engine(model, server.port)
    eng_b, flight_b, rb_b = _served_engine(model, server.port)
    leader_started = threading.Event()
    real = eng_a._prefill_prefix

    def dying_prefill(*a, **kw):
        # the lease is already held when the flight invokes the produce fn:
        # signal the follower to start contending, then die
        leader_started.set()
        time.sleep(0.1)
        raise RuntimeError("accelerator lost")

    eng_a._prefill_prefix = dying_prefill
    outcome: dict[str, object] = {}

    def run_a():
        try:
            eng_a.generate(prompt, max_new_tokens=4)
        except RuntimeError as e:
            outcome["a_error"] = e

    try:
        t_a = threading.Thread(target=run_a)
        t_a.start()
        assert leader_started.wait(30), "doomed leader never took the lease"
        toks, st = eng_b.generate(prompt, max_new_tokens=4)
        t_a.join(60)
        # the dying leader surfaced its own failure...
        assert isinstance(outcome.get("a_error"), RuntimeError)
        # ...and the follower re-elected, prefilled, and served correctly
        assert toks == reference
        assert st.chunks_skipped == 0 and st.stored_prefixes >= 1
        assert flight_b.remote_leads == 1
        assert flight_b.remote_waits >= 1
        # the recovered engine A serves from B's snapshots afterwards
        eng_a._prefill_prefix = real
        toks2, st2 = eng_a.generate(prompt, max_new_tokens=4)
        assert toks2 == reference
        assert st2.chunks_skipped == st2.n_chunks
    finally:
        rb_a.close()
        rb_b.close()
        server.stop()


# -- zero-phantom eviction convergence ----------------------------------------
def test_eviction_converges_catalog_policy_ledger(model, prompt):
    backend = MemoryBackend()
    catalog = Catalog(backend, persist=False)
    ledger = TenantLedger()
    eng = _fabric_engine(
        model, backend, catalog=catalog, ledger=ledger, tenant="tenant:a"
    )
    eng.generate(prompt, max_new_tokens=2)
    snaps = eng.snapshots
    assert snaps.n_snapshots >= 1
    keys = [k for k in list(snaps._records)]
    assert ledger.bytes_stored("tenant:a") == snaps.snapshot_bytes()
    for key in keys:
        assert catalog.index.get(key) is not None
        assert key in eng.policy.stored
    # evict everything, one key at a time, through the store's own path
    for key in keys:
        snaps.drop(key)
        assert snaps.record(key) is None
        assert catalog.index.get(key) is None, "catalog phantom"
        assert key not in eng.policy.stored, "policy phantom"
        assert not backend.exists(key)
    assert ledger.bytes_stored("tenant:a") == 0, "ledger phantom"
    assert snaps.snapshot_bytes() == 0


def test_remote_eviction_event_prunes_other_engines(model, prompt):
    """Engine A evicts; engine B (which adopted the snapshot) learns through
    the server's event stream and forgets — no phantom planning."""
    server = StoreServer(MemoryBackend()).start()
    eng_a, _, rb_a = _served_engine(model, server.port)
    eng_b, _, rb_b = _served_engine(model, server.port)
    try:
        eng_a.generate(prompt, max_new_tokens=2)
        # B adopts A's snapshots (restores them on its first request)
        _, st_b = eng_b.generate(prompt, max_new_tokens=2)
        assert st_b.chunks_skipped == st_b.n_chunks
        keys = list(eng_b.snapshots._records)
        assert keys and all(k in eng_b.policy.stored for k in keys)
        for key in list(eng_a.snapshots._records):
            eng_a.snapshots.drop(key)
        deadline = time.monotonic() + 5
        while time.monotonic() < deadline:
            if all(eng_b.snapshots.record(k) is None for k in keys):
                break
            time.sleep(0.05)
        for key in keys:
            assert eng_b.snapshots.record(key) is None, "record phantom on B"
            assert key not in eng_b.policy.stored, "policy phantom on B"
    finally:
        rb_a.close()
        rb_b.close()
        server.stop()
