"""End-to-end observability: one trace across gateway → scheduler → shards,
and one cluster-merged ``GET /metrics`` exposition.

The acceptance scenario of the observability PR: an HTTP-submitted run
against a live 3-shard cluster produces a single stitched trace (spans from
at least three distinct (service, pid) processes, server-side store spans on
at least two shards), a second warm submission turns the reuse counters and
the seconds-saved-by-reuse rollup non-zero, and the gateway's ``/metrics``
shows all of it merged across every process.
"""
from __future__ import annotations

import json
import re
import socket
import threading
import time
import urllib.request

import pytest

from repro.api import Client, WorkflowSpec
from repro.core import MemoryBackend
from repro.gateway import GatewayServer, TokenAuthenticator
from repro.gateway.serve import register_demo_modules
from repro.net import RemoteBackend, ShardedBackend, StoreServer
from repro.net.protocol import recv_frame, send_frame
from repro.obs.trace import build_trace, critical_path, render_trace, reuse_rollup
from repro.obs.tracing import TraceContext, configure_tracing, iter_spans

TOKEN = "tok-alice"
SLOW_S = 0.4


def _register_slow(registry):
    @registry.module("slow", seconds=SLOW_S)
    def slow(xs, seconds=SLOW_S):
        time.sleep(seconds)
        return [x * 2 for x in xs]


def _http(base, method, path, body=None, headers=None, timeout=60):
    req = urllib.request.Request(base + path, method=method)
    req.add_header("Authorization", f"Bearer {TOKEN}")
    for k, v in (headers or {}).items():
        req.add_header(k, v)
    data = json.dumps(body).encode() if body is not None else None
    with urllib.request.urlopen(req, data=data, timeout=timeout) as resp:
        raw = resp.read()
        ctype = resp.headers.get("Content-Type", "")
        return resp.status, (
            json.loads(raw) if "json" in ctype else raw.decode()
        )


@pytest.fixture()
def fabric(tmp_path):
    trace_dir = str(tmp_path / "traces")
    configure_tracing(trace_dir, "gw")
    servers = [
        StoreServer(MemoryBackend(), trace_service=f"shard{i}").start()
        for i in range(3)
    ]
    urls = ",".join(f"127.0.0.1:{s.port}" for s in servers)
    client = Client(store_url=urls, replication=2, max_pending=16)
    register_demo_modules(client.registry)
    _register_slow(client.registry)
    gw = GatewayServer(client, TokenAuthenticator({TOKEN: "alice"}))
    gw.start()
    try:
        yield gw, client, servers, urls, trace_dir
    finally:
        gw.close()
        client.close()
        for s in servers:
            s.stop()
        configure_tracing(None)


def test_gateway_run_produces_stitched_trace_and_cluster_metrics(fabric):
    gw, client, servers, urls, trace_dir = fabric
    spec = WorkflowSpec.from_steps(
        "nums", [("slow", {"seconds": SLOW_S}), "scale"]
    ).to_dict()
    ctx = TraceContext.new()

    # -- cold run, trace context propagated via the traceparent header ------
    st, doc = _http(
        gw.url, "POST", "/v1/workflows",
        {"spec": spec, "data": [1.0, 2.0], "wait": True},
        headers={"traceparent": ctx.to_traceparent()},
    )
    assert st == 200 and doc["status"] == "done", doc
    assert doc["trace_id"] == ctx.trace_id
    assert doc["result"]["n_computed"] == 2

    # -- warm runs: the policy mines history for a couple of runs, then the
    # stored prefix replaces the slow recompute ------------------------------
    doc2 = None
    for _ in range(4):
        st2, doc2 = _http(
            gw.url, "POST", "/v1/workflows",
            {"spec": spec, "data": [1.0, 2.0], "wait": True},
            headers={"traceparent": TraceContext.new().to_traceparent()},
        )
        assert st2 == 200 and doc2["status"] == "done", doc2
        if doc2["result"]["n_skipped"] >= 1:
            break
    assert doc2["result"]["n_skipped"] >= 1
    assert doc2["result"]["total_seconds"] < SLOW_S / 2

    # -- one stitched trace: gateway -> run -> nodes -> rpcs -> shard ops ---
    spans = list(iter_spans(trace_dir))
    mine = [s for s in spans if s["trace"] == ctx.trace_id]
    names = {s["name"] for s in mine}
    assert "gateway.submit" in names and "run" in names
    assert "node" in names and any(n.startswith("rpc") for n in names)
    gw_span = next(s for s in mine if s["name"] == "gateway.submit")
    assert gw_span["parent"] == ctx.span_id  # adopted the HTTP caller's ctx
    run_span = next(s for s in mine if s["name"] == "run")
    assert run_span["parent"] == gw_span["span"]
    # server-side spans from at least two shards joined the same trace
    shard_svcs = {
        s["svc"] for s in mine if s["name"].startswith("store.")
    }
    assert len(shard_svcs) >= 2, shard_svcs
    processes = {(s["svc"], s["pid"]) for s in mine}
    assert len(processes) >= 3, processes

    # the CLI stitches the same trace into a renderable tree w/ critical path
    tree = build_trace(spans, ctx.trace_id)
    assert tree["roots"] and critical_path(tree)
    rendered = render_trace(tree)
    assert "gateway.submit" in rendered and "critical path" in rendered

    # the WARM trace carries the reuse rollup (saved_s on the store.get span)
    warm_tree = build_trace(spans, doc2["trace_id"])
    roll = reuse_rollup(warm_tree)
    assert roll["reuse_hits"] >= 1
    assert roll["seconds_saved"] > 0.0

    # -- GET /metrics: the whole fabric in one Prometheus page --------------
    st3, text = _http(gw.url, "GET", "/metrics")
    assert st3 == 200
    assert "# TYPE repro_store_server_requests_total counter" in text

    def metric_value(name, **labels):
        for line in text.splitlines():
            if not line.startswith(name + "{") and line.split(" ")[0] != name:
                continue
            if all(f'{k}="{v}"' in line for k, v in labels.items()):
                return float(line.rsplit(" ", 1)[1])
        return None

    assert metric_value("repro_reuse_hits_total") >= 1
    assert metric_value("repro_reuse_seconds_saved_total") > 0.0
    assert metric_value("repro_gateway_requests_total", op="accepted") >= 2
    assert metric_value("repro_runs_total", status="ok") >= 2
    # server-side series arrive shard-stamped, from >= 2 distinct shards
    shards = set(
        re.findall(r'repro_store_server_requests_total\{[^}]*shard="([^"]+)"', text)
    )
    assert len(shards) >= 2, shards
    # non-additive per-shard gauges stayed apart (one uptime series per shard)
    uptimes = re.findall(r'repro_store_server_uptime_seconds\{[^}]*shard="([^"]+)"', text)
    assert len(set(uptimes)) == len(servers)


def test_cross_process_lease_wait_span_on_non_leader(fabric):
    gw, client, servers, urls, trace_dir = fabric
    # a SECOND client process-equivalent (own DistributedSingleFlight, own
    # lease identity) racing the first on the same uncomputed prefix
    client2 = Client(store_url=urls, replication=2)
    _register_slow(client2.registry)
    spec = WorkflowSpec.from_steps("lease-ds", [("slow", {"seconds": SLOW_S})])
    try:
        fut1 = client.submit(spec, [1.0])
        time.sleep(SLOW_S / 3)  # let the leader take the lease
        fut2 = client2.submit(spec, [1.0])
        r1 = fut1.result(timeout=30)
        r2 = fut2.result(timeout=30)
        assert r1.output == r2.output == [2.0]
    finally:
        client2.close()
    waits = [s for s in iter_spans(trace_dir) if s["name"] == "lease.wait"]
    assert waits, "non-leader never recorded a lease.wait span"
    assert any(s["dur"] > 0.0 for s in waits)


def test_tp_field_is_ignored_by_servers_and_optional_for_peers(fabric):
    """Forward/backward compat of the optional ``tp`` request field: a server
    answers one-shot ops carrying ``tp`` (and unknown future fields) exactly
    as without them, and a peer that predates the ``metrics`` op degrades to
    ``metrics_doc() -> None`` instead of erroring."""
    gw, client, servers, urls, trace_dir = fabric
    ctx = TraceContext.new()
    sock = socket.create_connection(("127.0.0.1", servers[0].port))
    try:
        send_frame(
            sock,
            {
                "op": "write_meta", "name": "obs-compat",
                "tp": ctx.to_traceparent(), "some_future_field": [1, 2],
            },
            b"1",
        )
        resp, _ = recv_frame(sock)
        assert resp["ok"] is True
        send_frame(sock, {"op": "read_meta", "name": "obs-compat", "tp": "garbage"})
        resp, payload = recv_frame(sock)
        assert resp["ok"] is True and payload == b"1"
    finally:
        sock.close()
    # the tp-stamped op joined the caller's trace on the server side
    adopted = [s for s in iter_spans(trace_dir) if s["trace"] == ctx.trace_id]
    assert any(s["name"] == "store.write_meta" for s in adopted)


class _V1Server(StoreServer):
    """A store server from before the ``metrics`` op existed."""
    _op_metrics = None


def test_pre_metrics_peers_are_skipped_in_cluster_merge(tmp_path):
    old = _V1Server(MemoryBackend()).start()
    new = StoreServer(MemoryBackend()).start()
    sb = ShardedBackend(
        f"127.0.0.1:{old.port},127.0.0.1:{new.port}", replication=1
    )
    try:
        rb_old = RemoteBackend(f"127.0.0.1:{old.port}")
        assert rb_old.metrics_doc() is None  # bad_op -> graceful None
        rb_old.close()
        doc = sb.metrics_doc()
        shards = {
            s["labels"].get("shard")
            for s in doc["repro_store_server_requests_total"]["series"]
        }
        assert shards == {f"127.0.0.1:{new.port}"}
    finally:
        sb.close()
        old.stop()
        new.stop()
