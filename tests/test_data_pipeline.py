"""Data-prep pipeline through the SWfMS executor: reuse + state sensitivity."""
import numpy as np
import pytest

from repro.core import IntermediateStore, RISP, TSAR, WorkflowExecutor
from repro.data.pipeline import make_corpus_blob, register_data_modules


@pytest.fixture()
def ex(tmp_path):
    e = WorkflowExecutor(
        store=IntermediateStore(tmp_path / "s"), policy=TSAR(with_state=True)
    )
    register_data_modules(e, vocab=1000)
    return e


def test_data_pipeline_reuse(ex):
    blob = make_corpus_blob(1 << 16)
    steps = ["tokenize", ("pack", {"seq_len": 64}), "split"]
    r1 = ex.run("corpus-v1", blob, steps, "prep1")
    assert r1.n_skipped == 0
    assert r1.output["train"].shape[1] == 65
    # a second training job over the same corpus reuses everything
    r2 = ex.run("corpus-v1", blob, steps, "prep2")
    assert r2.n_skipped == 3
    np.testing.assert_array_equal(
        np.asarray(r1.output["train"]), np.asarray(r2.output["train"])
    )


def test_data_pipeline_state_sensitivity(ex):
    blob = make_corpus_blob(1 << 16)
    ex.run("corpus-v1", blob, ["tokenize", ("pack", {"seq_len": 64})], "a")
    # different seq_len: tokenize reused, pack recomputed
    r = ex.run("corpus-v1", blob, ["tokenize", ("pack", {"seq_len": 32})], "b")
    assert r.n_skipped == 1
    assert r.output.shape[1] == 33


def test_cost_model_gain_accounting(tmp_path):
    from repro.core import CostModel
    from repro.core.workflow import ModuleRef, PrefixKey, ToolState

    store = IntermediateStore(tmp_path / "c")
    cm = CostModel(store=store)
    ref = ModuleRef("m", ToolState())
    cm.observe(ref, seconds=2.0, out_bytes=1000)
    prefix = PrefixKey("d", (ref,))
    # T1 = exec (2s) + store estimate; T2 = load estimate; gain ~ 2s
    assert cm.t1(prefix) >= 2.0
    assert cm.gain(prefix) > 1.0
    assert cm.should_store(prefix)
