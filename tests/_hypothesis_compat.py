"""Optional-hypothesis shim.

`hypothesis` is an optional dev dependency (see pyproject.toml extras).  On a
bare environment the property-based tests should *skip*, not break collection
of the whole module.  Import `given`/`settings`/`st`/`HealthCheck` from here
instead of from hypothesis directly.
"""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import HealthCheck, given, settings

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on bare environments
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategy construction/chaining; never actually draws."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    class HealthCheck:
        too_slow = None
        data_too_large = None
        filter_too_much = None

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco
