"""repro.api facade: ModuleRegistry, WorkflowSpec, Client, recommendations
(ISSUE 3 tentpole + satellites)."""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import (
    Client,
    ModuleRegistry,
    SpecError,
    ToolStateError,
    UnknownModuleError,
    WorkflowSpec,
)
from repro.core import (
    IntermediateStore,
    ModuleSpec,
    RISP,
    TSAR,
    WorkflowExecutor,
    decode_param,
    encode_param,
    galaxy_ch4_corpus,
)
from repro.core.workflow import ToolState
from repro.sched import WorkflowService


# -- canonical tool-state params (satellite: from_config round-trip) ----------
class TestToolStateRoundTrip:
    @pytest.mark.parametrize(
        "value",
        [
            None,
            True,
            3,
            0.1,
            1e-300,
            "fast",
            "1.5",  # string that looks numeric must stay a string
            (1, 2),
            (1.5, "a", None),
            [1, [2, 3]],
            {"a": (1.0, 2), "b": {"c": [4, 5]}},
            {1, 2, 3},
            frozenset({"x", "y"}),
            b"\x00\xffraw",
            (("nested",), {"deep": (0.25,)}),
        ],
    )
    def test_encode_decode_identity(self, value):
        out = decode_param(encode_param(value))
        assert out == value
        assert type(out) is type(value)

    def test_tool_state_config_roundtrip(self):
        cfg = {"scale": 2.5, "dims": (0, 1), "opts": {"mode": "fast", "k": [1, 2]}}
        state = ToolState.from_config(cfg)
        assert state.to_config() == cfg
        # tuples must stay tuples (the old repr path happened to get this
        # right; the canonical path must not regress it)
        assert isinstance(state.to_config()["dims"], tuple)

    def test_ndarray_param_roundtrips(self):
        cfg = {"w": np.arange(6, dtype=np.float32).reshape(2, 3)}
        out = ToolState.from_config(cfg).to_config()
        np.testing.assert_array_equal(out["w"], cfg["w"])
        assert out["w"].dtype == np.float32

    def test_non_recoverable_param_raises_loudly(self):
        # the old repr path silently degraded this to the string "slice(...)"
        with pytest.raises(TypeError, match="not value-recoverable"):
            ToolState.from_config({"s": slice(1, 2)})

    def test_nested_frozenset_roundtrips(self):
        v = frozenset({frozenset({1, 2}), 3})
        out = decode_param(encode_param(v))
        assert out == v and isinstance(out, frozenset)
        assert {type(e) for e in out} == {frozenset, int}

    def test_non_str_key_dict_order_independent(self):
        # non-str-key dicts must encode insertion-order independently, or
        # value-equal tool states digest differently across processes
        a = encode_param({"m": {1: "a", 2: "b"}})
        b = encode_param({"m": {2: "b", 1: "a"}})
        assert a == b
        assert decode_param(a) == {"m": {1: "a", 2: "b"}}
        # frozenset keys survive too
        k = frozenset({1, 2})
        assert decode_param(encode_param({k: "x"})) == {k: "x"}

    def test_legacy_repr_params_still_decode(self):
        # states persisted before the canonical encoder used repr()
        legacy = ToolState(params=(("a", "(1, 2)"), ("b", "'fast'"), ("c", "3")))
        assert legacy.to_config() == {"a": (1, 2), "b": "fast", "c": 3}

    def test_executor_receives_decoded_values(self, tmp_path):
        """End to end: a tuple/float param reaches the module fn with its
        original type (the satellite's silent-degradation bug)."""
        seen = {}

        def probe(x, dims=(), scale=1.0):
            seen["dims"], seen["scale"] = dims, scale
            return x

        ex = WorkflowExecutor(store=IntermediateStore(tmp_path / "s"), policy=TSAR())
        ex.register(ModuleSpec("probe", probe))
        ex.run("ds", jnp.arange(4.0), [("probe", {"dims": (0, 1), "scale": 0.5})])
        assert seen["dims"] == (0, 1) and isinstance(seen["dims"], tuple)
        assert seen["scale"] == 0.5 and isinstance(seen["scale"], float)

    def test_digest_distinguishes_types(self):
        assert (
            ToolState.from_config({"x": (1, 2)}).digest
            != ToolState.from_config({"x": [1, 2]}).digest
        )
        assert (
            ToolState.from_config({"x": "1"}).digest
            != ToolState.from_config({"x": 1}).digest
        )


# -- ModuleRegistry -----------------------------------------------------------
class TestModuleRegistry:
    def test_decorator_and_defaults(self):
        reg = ModuleRegistry()

        @reg.module("inc", by=2)
        def inc(x, by=1):
            return x + by

        @reg.module()
        def double(x):
            return x * 2

        assert set(reg) == {"inc", "double"}
        assert reg["inc"].default_params == {"by": 2}
        assert inc(1) == 2  # decorated fn stays directly callable
        # defaults merge into the tool state (engine-identical refs)
        assert reg.ref("inc").state.to_config() == {"by": 2}

    def test_unknown_module_error(self):
        reg = ModuleRegistry()
        with pytest.raises(UnknownModuleError, match="unknown module 'nope'"):
            reg["nope"]

    def test_tool_state_validation(self):
        reg = ModuleRegistry()
        reg.register_fn("inc", lambda x, by=1: x + by)
        reg.validate_state("inc", {"by": 3})
        with pytest.raises(ToolStateError, match="does not accept"):
            reg.validate_state("inc", {"step": 3})
        # **kwargs modules accept anything
        reg.register_fn("anykw", lambda x, **kw: x)
        reg.validate_state("anykw", {"whatever": 1})

    def test_tool_state_validation_positional_only_data_arg(self):
        reg = ModuleRegistry()

        def analyze(x, /, q=50, *, mode="fast"):
            return x

        reg.register_fn("analyze", analyze)
        reg.validate_state("analyze", {"q": 10, "mode": "slow"})  # must not raise
        with pytest.raises(ToolStateError, match="does not accept"):
            reg.validate_state("analyze", {"x": 1})  # the data arg is not a param

    def test_mapping_protocol_guards(self):
        reg = ModuleRegistry()
        spec = ModuleSpec("m", lambda x: x)
        with pytest.raises(ValueError, match="does not match"):
            reg["other"] = spec
        reg["m"] = spec
        del reg["m"]
        assert len(reg) == 0

    def test_shared_registry_executor_and_service(self, tmp_path):
        """The divergence fix: a module registered through the service is
        visible to a standalone executor sharing the registry (and vice
        versa)."""
        store = IntermediateStore(tmp_path / "s")
        reg = ModuleRegistry()
        policy = TSAR(with_state=True)
        ex = WorkflowExecutor(store=store, policy=policy, registry=reg)
        svc = WorkflowService(store=store, policy=policy, registry=reg)
        try:
            svc.register_fn("double", lambda x: x * 2)  # via the service...
            ex.register_fn("inc", lambda x, by=1: x + by, by=1)  # via the executor
            # ...both visible on either engine
            r = ex.run("ds", jnp.arange(4.0), ["double", "inc"], "w1")
            np.testing.assert_allclose(np.asarray(r.output), np.arange(4.0) * 2 + 1)
            r2 = svc.run_steps("ds", jnp.arange(4.0), ["double", "inc"], "w2")
            assert r2.n_skipped == 2  # and they share the stored artifacts
        finally:
            svc.close()

    def test_plain_dict_adopted_by_reference(self, tmp_path):
        legacy: dict = {}
        ex = WorkflowExecutor(
            store=IntermediateStore(tmp_path / "s"), policy=TSAR(), registry=legacy
        )
        legacy["double"] = ModuleSpec("double", lambda x: x * 2)  # old-style mutation
        r = ex.run("ds", jnp.arange(3.0), ["double"])
        np.testing.assert_allclose(np.asarray(r.output), np.arange(3.0) * 2)


# -- WorkflowSpec -------------------------------------------------------------
def fanout_spec() -> WorkflowSpec:
    spec = WorkflowSpec("survey", workflow_id="report")
    spec.add("a", "double")
    spec.add("b", "inc", {"by": (1, 2)}, after="a")
    spec.add("c", "inc", {"by": (3, 4)}, after="a")
    spec.add("m", "merge", after=("b", "c"))
    return spec


class TestWorkflowSpec:
    def test_chain_json_roundtrip_preserves_digest(self):
        spec = WorkflowSpec.from_steps(
            "ds", ["double", ("inc", {"by": 3, "mode": "fast"})], "w"
        )
        clone = WorkflowSpec.from_json(spec.to_json(indent=2))
        assert clone.digest == spec.digest
        assert [n.node_id for n in clone.nodes] == [n.node_id for n in spec.nodes]
        assert clone.node(clone.nodes[1].node_id).config() == {
            "by": 3,
            "mode": "fast",
        }

    def test_dag_json_roundtrip_preserves_digest_and_fanin_order(self):
        spec = fanout_spec()
        clone = WorkflowSpec.from_json(spec.to_json())
        assert clone.digest == spec.digest
        assert clone.node("m").after == ("b", "c")  # fan-in order is semantic
        # and a doubly-round-tripped copy still agrees
        assert WorkflowSpec.from_json(clone.to_json()).digest == spec.digest

    def test_digest_independent_of_declaration_order(self):
        a = WorkflowSpec("ds")
        a.add("root", "double")
        a.add("x", "inc", {"by": 1}, after="root")
        a.add("y", "inc", {"by": 2}, after="root")
        b = WorkflowSpec("ds")
        b.add("root", "double")
        b.add("y", "inc", {"by": 2}, after="root")  # branches swapped
        b.add("x", "inc", {"by": 1}, after="root")
        assert a.digest == b.digest
        # but renaming a node or changing params changes it
        c = WorkflowSpec("ds")
        c.add("root", "double")
        c.add("x", "inc", {"by": 7}, after="root")
        c.add("y", "inc", {"by": 2}, after="root")
        assert a.digest != c.digest

    def test_cyclic_spec_rejected(self):
        doc = {
            "kind": "repro.workflow_spec",
            "version": 1,
            "dataset_id": "ds",
            "nodes": [
                {"id": "a", "module": "m1", "after": ["b"]},
                {"id": "b", "module": "m2", "after": ["a"]},
            ],
        }
        spec = WorkflowSpec.from_dict(doc)
        with pytest.raises(SpecError, match="cycle"):
            spec.validate()

    def test_structural_errors(self):
        with pytest.raises(SpecError, match="dataset_id"):
            WorkflowSpec("")
        spec = WorkflowSpec("ds")
        with pytest.raises(SpecError, match="at least one node"):
            spec.validate()
        spec.add("a", "m1")
        with pytest.raises(SpecError, match="duplicate node id"):
            spec.add("a", "m1")
        spec.add("b", "m2", after="ghost")
        with pytest.raises(SpecError, match="unknown parent 'ghost'"):
            spec.validate()

    def test_unknown_module_rejected_with_registry(self):
        reg = ModuleRegistry()
        reg.register_fn("double", lambda x: x * 2)
        spec = WorkflowSpec.from_steps("ds", ["double", "mystery"])
        with pytest.raises(SpecError, match="unknown module 'mystery'"):
            spec.validate(reg)

    def test_bad_tool_state_rejected_with_registry(self):
        reg = ModuleRegistry()
        reg.register_fn("inc", lambda x, by=1: x + by)
        spec = WorkflowSpec.from_steps("ds", [("inc", {"step": 2})])
        with pytest.raises(ToolStateError, match="does not accept"):
            spec.validate(reg)

    def test_is_linear(self):
        assert WorkflowSpec.from_steps("ds", ["a", "b", "c"]).is_linear
        assert not fanout_spec().is_linear

    def test_hand_written_doc_params_normalize(self):
        # plain JSON values pass through; string values are *encodings*
        # (a literal string is its JSON-quoted form — docs/api.md)
        doc = {
            "kind": "repro.workflow_spec",
            "version": 1,
            "dataset_id": "d",
            "nodes": [
                {
                    "id": "a",
                    "module": "m",
                    "params": {"bins": 10, "mode": '"fast"', "on": True},
                    "after": [],
                }
            ],
        }
        spec = WorkflowSpec.from_dict(doc)
        assert spec.node("a").config() == {"bins": 10, "mode": "fast", "on": True}
        # and it digests identically to the programmatic equivalent
        prog = WorkflowSpec("d")
        prog.add("a", "m", {"bins": 10, "mode": "fast", "on": True})
        assert spec.digest == prog.digest

    def test_from_json_rejects_garbage(self):
        with pytest.raises(SpecError, match="invalid workflow spec JSON"):
            WorkflowSpec.from_json("{nope")
        with pytest.raises(SpecError, match="must be an object"):
            WorkflowSpec.from_json("[1, 2]")
        with pytest.raises(SpecError, match="kind"):
            WorkflowSpec.from_json(json.dumps({"kind": "other", "dataset_id": "d"}))
        with pytest.raises(SpecError, match="missing 'dataset_id'"):
            WorkflowSpec.from_json(json.dumps({"kind": "repro.workflow_spec"}))
        with pytest.raises(SpecError, match="missing field"):
            WorkflowSpec.from_json(
                json.dumps(
                    {
                        "kind": "repro.workflow_spec",
                        "dataset_id": "d",
                        "nodes": [{"id": "a"}],
                    }
                )
            )

    def test_spec_prefix_keys_match_engine_keys(self, tmp_path):
        """The document's resolved PrefixKeys are exactly the store keys a
        sequential run produces — the cross-process contract."""
        reg = ModuleRegistry()
        reg.register_fn("double", lambda x: x * 2)
        reg.register_fn("inc", lambda x, by=1: x + by, by=1)
        spec = WorkflowSpec.from_steps("ds", ["double", ("inc", {"by": 3})])
        ex = WorkflowExecutor(
            store=IntermediateStore(tmp_path / "s"),
            policy=TSAR(with_state=True),
            registry=reg,
        )
        ex.run_workflow(spec.to_workflow(reg), jnp.arange(4.0))
        assert set(spec.prefix_keys(reg)) == set(ex.store.records)

    def test_legacy_toolstate_workflow_roundtrip_preserves_digest(self):
        """A spec lifted from a legacy repr-encoded ToolState normalizes at
        construction, so serialization cannot change its digest."""
        from repro.core.workflow import ModuleRef, Workflow

        legacy = ToolState(params=(("q", "(1, 2)"),))  # pre-canonical encoding
        wf = Workflow("ds", (ModuleRef("m", legacy),), "w")
        spec = WorkflowSpec.from_workflow(wf)
        clone = WorkflowSpec.from_json(spec.to_json())
        assert clone.digest == spec.digest
        assert clone.node(clone.nodes[0].node_id).config() == {"q": (1, 2)}

    def test_roundtrip_through_workflow_and_dag(self):
        reg = ModuleRegistry()
        reg.register_fn("double", lambda x: x * 2)
        reg.register_fn("inc", lambda x, by=1: x + by, by=1)
        spec = WorkflowSpec.from_steps("ds", ["double", ("inc", {"by": 3})], "w")
        wf = spec.to_workflow(reg)
        again = WorkflowSpec.from_workflow(wf)
        assert again.to_workflow().prefix(2).key(True) == wf.prefix(2).key(True)
        dag = spec.to_dag(reg)
        assert WorkflowSpec.from_dag(dag).digest == spec.digest


GALAXY_DOC = {
    "a_galaxy_workflow": "true",
    "name": "rnaseq-qc",
    "steps": {
        "0": {
            "id": 0,
            "type": "data_input",
            "tool_id": None,
            "label": "reads",
            "input_connections": {},
        },
        "1": {
            "id": 1,
            "type": "tool",
            "tool_id": "toolshed.g2.bx.psu.edu/repos/devteam/fastqc/fastqc/0.73",
            "tool_state": '{"limits": null, "__page__": 0, "kmers": 7}',
            "input_connections": {"input_file": {"id": 0, "output_name": "output"}},
        },
        "2": {
            "id": 2,
            "type": "tool",
            "tool_id": "toolshed.g2.bx.psu.edu/repos/pjbriggs/trimmomatic/trimmomatic/0.38",
            "label": "trim",
            "tool_state": '{"window": 4}',
            "input_connections": {"readtype|fastq_in": {"id": 1, "output_name": "html"}},
        },
        "3": {
            "id": 3,
            "type": "tool",
            "tool_id": "multiqc",
            "tool_state": "{}",
            "input_connections": {
                "results": [
                    {"id": 1, "output_name": "text"},
                    {"id": 2, "output_name": "log"},
                ]
            },
        },
    },
}


class TestGalaxyImport:
    def test_import_structure(self):
        spec = WorkflowSpec.from_galaxy(GALAXY_DOC)
        assert spec.dataset_id == "reads"
        assert spec.workflow_id == "rnaseq-qc"
        assert len(spec) == 3  # data_input step is the dataset, not a node
        fastqc = spec.node("1")
        assert fastqc.module_id == "fastqc"  # toolshed id shortened
        assert fastqc.after == ()  # parent was the data input
        assert fastqc.config() == {"limits": None, "kmers": 7}  # __page__ dropped
        assert spec.node("trim").after == ("1",)
        assert spec.node("3").after == ("1", "trim")  # label-renamed parent

    def test_import_roundtrips_as_spec_json(self):
        spec = WorkflowSpec.from_galaxy(json.dumps(GALAXY_DOC))
        clone = WorkflowSpec.from_json(spec.to_json())
        assert clone.digest == spec.digest

    def test_import_rejects_stepless_doc(self):
        with pytest.raises(SpecError, match="no steps"):
            WorkflowSpec.from_galaxy({"name": "empty", "steps": {}})


# -- Client facade ------------------------------------------------------------
def make_client(tmp_path, policy=None, **kw):
    client = Client(
        store=IntermediateStore(tmp_path / "store"),
        policy=policy or TSAR(with_state=True),
        **kw,
    )
    calls = {"double": 0, "inc": 0, "merge": 0}

    @client.module("double")
    def double(x):
        calls["double"] += 1
        return x * 2

    @client.module("inc", by=1)
    def inc(x, by=1):
        calls["inc"] += 1
        return x + by

    @client.module("merge")
    def merge(xs):
        calls["merge"] += 1
        return sum(xs[1:], xs[0])

    return client, calls


class TestClient:
    def test_one_spec_every_engine_run_then_submit(self, tmp_path):
        """Acceptance: a prefix stored via Client.run() (sequential path) is
        reused by Client.submit() of an equivalent DAG spec, with identical
        PrefixKey store keys."""
        client, calls = make_client(tmp_path)
        try:
            spec = WorkflowSpec.from_steps("ds", ["double", ("inc", {"by": 3})], "w1")
            data = jnp.arange(6.0)
            r1 = client.run(spec, data)  # linear -> sequential executor
            assert r1.n_skipped == 0 and calls["double"] == 1
            keys_after_run = set(client.store.records)
            assert keys_after_run == set(spec.prefix_keys(client.registry))

            # an equivalent spec, freshly parsed from JSON, submitted as a DAG
            clone = WorkflowSpec.from_json(spec.to_json())
            r2 = client.submit(clone, data).result(timeout=60)
            assert calls["double"] == 1, "stored prefix must be reused, not recomputed"
            assert r2.n_skipped == 2
            assert set(client.store.records) == keys_after_run  # same identities
            np.testing.assert_array_equal(np.asarray(r1.output), np.asarray(r2.output))
        finally:
            client.close()

    def test_one_spec_every_engine_submit_then_run(self, tmp_path):
        """...and vice versa: artifacts stored by the scheduler are reused by
        the sequential path."""
        client, calls = make_client(tmp_path)
        try:
            spec = WorkflowSpec.from_steps("ds", ["double", ("inc", {"by": 3})], "w1")
            data = jnp.arange(6.0)
            client.submit(spec, data).result(timeout=60)
            n_double = calls["double"]
            r2 = client.run(WorkflowSpec.from_json(spec.to_json()), data)
            assert calls["double"] == n_double  # sequential path loaded, not re-ran
            assert r2.n_skipped == 2
        finally:
            client.close()

    def test_fan_in_spec_runs_through_scheduler(self, tmp_path):
        client, calls = make_client(tmp_path)
        try:
            spec = client.spec("ds", "report")
            spec.add("a", "double")
            spec.add("b", "inc", {"by": 3}, after="a")
            spec.add("c", "inc", {"by": 5}, after="a")
            spec.add("m", "merge", after=("b", "c"))
            r = client.run(spec, jnp.arange(4.0))
            expect = (np.arange(4.0) * 2 + 3) + (np.arange(4.0) * 2 + 5)
            np.testing.assert_allclose(np.asarray(r.output), expect)
            assert calls["double"] == 1  # shared stem computed once
        finally:
            client.close()

    def test_deserialized_spec_reuses_stored_prefix(self, tmp_path):
        """Acceptance: a stored prefix from a deserialized spec is reused by a
        freshly parsed copy (cross-process portability, same-store proxy)."""
        client, calls = make_client(tmp_path)
        try:
            text = WorkflowSpec.from_steps(
                "ds", ["double", ("inc", {"by": 2.5})], "w"
            ).to_json()
            first = WorkflowSpec.from_json(text)
            client.run(first, jnp.arange(4.0))
            again = WorkflowSpec.from_json(text)  # independent parse
            r = client.run(again, jnp.arange(4.0))
            assert r.n_skipped == 2
            assert calls["double"] == 1 and calls["inc"] == 1
        finally:
            client.close()

    def test_prebuilt_store_excludes_store_options(self, tmp_path):
        store = IntermediateStore(tmp_path / "s")
        with pytest.raises(ValueError, match="pre-built store"):
            Client(store=store, eviction="lru")
        with pytest.raises(ValueError, match="pre-built store"):
            Client(store=store, codec="zlib")

    def test_validation_errors_surface(self, tmp_path):
        client, _ = make_client(tmp_path)
        try:
            with pytest.raises(SpecError, match="unknown module"):
                client.run(WorkflowSpec.from_steps("ds", ["mystery"]), jnp.arange(2.0))
            with pytest.raises(ToolStateError):
                client.run(
                    WorkflowSpec.from_steps("ds", [("inc", {"nope": 1})]),
                    jnp.arange(2.0),
                )
        finally:
            client.close()

    def test_stats_span_both_engines(self, tmp_path):
        client, _ = make_client(tmp_path)
        try:
            spec = WorkflowSpec.from_steps("ds", ["double", "inc"], "w")
            client.run(spec, jnp.arange(4.0))  # sequential
            client.submit(spec, jnp.arange(4.0)).result(timeout=60)  # scheduler
            client.drain()
            st = client.stats()
            assert st.runs == 2 and st.failures == 0
            assert st.units_total == 4 and st.units_skipped >= 2
            assert "runs=2" in st.row()
        finally:
            client.close()

    def test_recommend_after_corpus_replay(self, tmp_path):
        """Acceptance: recommend() returns >=1 reusable-prefix suggestion
        after replaying galaxy_ch4_corpus (Ch. 4's recommendation pipeline)."""
        client, _ = make_client(tmp_path, policy=RISP())
        try:
            corpus = galaxy_ch4_corpus()
            assert client.replay(corpus) == len(corpus)
            # compose a partial workflow extending a history-supported prefix
            partial = max(
                (p for p in client.policy.miner.iter_prefixes()
                 if client.policy.miner.support(p) >= 2),
                key=lambda p: p.depth,
            )
            report = client.recommend(partial.dataset_id, partial.modules)
            assert len(report.reusable_prefixes) >= 1
            best = report.best_reuse
            assert best.kind == "reusable_prefix"
            assert best.depth <= partial.depth
            assert best.confidence > 0
            assert "reuse depth" in best.describe()

            # next-module suggestions extend a *shorter* partial chain
            if partial.depth > 1:
                report2 = client.recommend(
                    partial.dataset_id, partial.modules[:-1]
                )
                suggested = [s.module_id for s in report2.next_modules]
                assert partial.modules[-1].module_id in suggested
                confs = [s.confidence for s in report2.next_modules]
                assert confs == sorted(confs, reverse=True)
        finally:
            client.close()

    def test_recommend_empty_partial_suggests_first_module(self, tmp_path):
        client, _ = make_client(tmp_path, policy=RISP())
        try:
            from collections import Counter

            corpus = galaxy_ch4_corpus()
            client.replay(corpus)
            ds = Counter(wf.dataset_id for wf in corpus).most_common(1)[0][0]
            report = client.recommend(ds)
            assert report.depth == 0
            assert report.next_modules, "popular dataset must have first-module rules"
        finally:
            client.close()

    def test_replay_does_not_block_first_real_store(self, tmp_path):
        """Replayed (never-executed) history must not leave phantom 'stored'
        claims that make the first real run skip persisting its artifacts."""
        client, calls = make_client(tmp_path, policy=RISP())
        try:
            spec = WorkflowSpec.from_steps("ds", ["double", "inc"], "w")
            # two replays make D=>double>inc the top rule; PT would "store" it
            client.observe(spec)
            client.observe(spec)
            live = {
                k for k in client.policy.stored if client.store.has(k)
            }
            assert live == set()  # no phantom claims backed by nothing
            r1 = client.run(spec, jnp.arange(4.0))
            assert r1.stored_keys, "first real run must persist the mined prefix"
            r2 = client.run(spec, jnp.arange(4.0))
            assert r2.n_skipped == 2 and calls["double"] == 1
        finally:
            client.close()

    def test_recommend_flags_live_artifacts(self, tmp_path):
        client, _ = make_client(tmp_path)  # TSAR stores everything
        try:
            spec = WorkflowSpec.from_steps("ds", ["double", "inc"], "w")
            client.run(spec, jnp.arange(4.0))
            report = client.recommend(spec)
            assert report.best_reuse is not None
            assert report.best_reuse.stored  # artifact is live in the store
            assert report.best_reuse.depth == 2
        finally:
            client.close()

    def test_recommend_dedupes_next_module_states(self, tmp_path):
        """A frequently re-parameterized module yields ONE next-module
        suggestion (its best state), not top_k copies of itself."""
        client, _ = make_client(tmp_path, policy=RISP(with_state=True))
        try:
            partial = WorkflowSpec.from_steps("ds", ["double"])
            for by in (1, 2, 3, 1):
                client.observe(
                    WorkflowSpec.from_steps("ds", ["double", ("inc", {"by": by})])
                )
            report = client.recommend(partial)
            ids = [s.module_id for s in report.next_modules]
            assert ids == ["inc"]
            assert report.best_next.support == 2  # the repeated by=1 state wins
        finally:
            client.close()

    def test_legacy_front_doors_still_work_alongside(self, tmp_path):
        """Migration contract: the old imperative entry points keep working
        against the same store/policy/registry the Client wired."""
        client, calls = make_client(tmp_path)
        try:
            ex = WorkflowExecutor(
                store=client.store, policy=client.policy, registry=client.registry
            )
            r = ex.run("ds", jnp.arange(4.0), ["double", ("inc", {"by": 3})], "w1")
            assert calls["double"] == 1
            # the Client sees the legacy run's artifacts
            r2 = client.run(
                WorkflowSpec.from_steps("ds", ["double", ("inc", {"by": 3})]),
                jnp.arange(4.0),
            )
            assert r2.n_skipped == 2 and calls["double"] == 1
        finally:
            client.close()
