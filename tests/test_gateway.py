"""Gateway tests: HTTP front door, tenancy, admission control, shutdown.

Each test builds a real ``GatewayServer`` over a loopback port and talks
plain HTTP to it — the error-mapping tests deliberately hammer the server
with malformed input and then prove it still serves valid requests.
"""
from __future__ import annotations

import json
import signal
import subprocess
import sys
import tempfile
import time
import urllib.error
import urllib.request
from pathlib import Path

import pytest

from repro.api import Client, SpecError, WorkflowSpec
from repro.api.spec import check_namespace, namespaced_dataset
from repro.gateway import (
    GatewayServer,
    NamespaceDenied,
    TenancyPolicy,
    TokenAuthenticator,
    private_namespace,
)
from repro.gateway.serve import register_demo_modules
from repro.sched import (
    AdmissionRejected,
    ServiceClosed,
    TenantLedger,
    WorkflowService,
)
from repro.core.risp import make_policy
from repro.core.store import IntermediateStore

TOKENS = {"tok-a": "alice", "tok-b": "bob"}


# -- plain-HTTP helpers -------------------------------------------------------

def _request(base, method, path, token=None, body=None, timeout=30):
    """Returns (status, parsed-JSON body, headers) without raising on 4xx."""
    req = urllib.request.Request(base + path, method=method)
    if token is not None:
        req.add_header("Authorization", f"Bearer {token}")
    data = None
    if body is not None:
        data = body if isinstance(body, bytes) else json.dumps(body).encode()
        req.add_header("Content-Type", "application/json")
    try:
        with urllib.request.urlopen(req, data=data, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read()), dict(resp.headers)
    except urllib.error.HTTPError as e:
        raw = e.read()
        return e.code, (json.loads(raw) if raw else {}), dict(e.headers)


def _register_slow(registry):
    @registry.module("slow", seconds=0.4)
    def slow(xs, seconds=0.4):
        time.sleep(seconds)
        return xs

    return slow


def _chain_doc(dataset="nums", steps=("normalize", "scale", "stats")):
    return WorkflowSpec.from_steps(dataset, list(steps)).to_dict()


def _wait_done(base, token, run_id, deadline_s=30.0):
    deadline = time.monotonic() + deadline_s
    while time.monotonic() < deadline:
        st, doc, _ = _request(base, "GET", f"/v1/runs/{run_id}", token)
        assert st == 200, doc
        if doc["status"] in ("done", "failed"):
            return doc
        time.sleep(0.02)
    raise AssertionError(f"run {run_id} never finished")


@pytest.fixture()
def gateway():
    client = Client(max_pending=16)
    register_demo_modules(client.registry)
    _register_slow(client.registry)
    gw = GatewayServer(client, TokenAuthenticator(TOKENS))
    gw.start()
    yield gw
    gw.close()
    client.close()


# -- namespace plumbing (api.spec) -------------------------------------------

class TestNamespaces:
    def test_namespace_roundtrips_and_changes_digest(self):
        spec = WorkflowSpec.from_steps("ds", ["a", "b"])
        ns = spec.with_namespace("tenant:alice")
        assert ns.effective_dataset_id == "tenant:alice/ds"
        assert ns.digest != spec.digest
        again = WorkflowSpec.from_json(ns.to_json())
        assert again.namespace == "tenant:alice"
        assert again.digest == ns.digest
        # un-namespaced documents keep their legacy digest + wire format
        assert "namespace" not in spec.to_dict()
        assert WorkflowSpec.from_json(spec.to_json()).digest == spec.digest

    def test_namespace_charset_enforced(self):
        with pytest.raises(SpecError):
            check_namespace("bad/ns")
        with pytest.raises(SpecError):
            WorkflowSpec("ds", namespace="a b")
        assert namespaced_dataset("", "ds") == "ds"
        assert namespaced_dataset("shared", "ds") == "shared/ds"

    def test_prefix_keys_are_namespaced(self):
        spec = WorkflowSpec.from_steps("ds", ["a", "b"]).with_namespace("shared")
        for key in spec.prefix_keys():
            assert key.startswith("shared/ds::")

    def test_tenancy_policy_resolution(self):
        pol = TenancyPolicy(("shared", "commons"))
        assert pol.resolve("alice", None) == private_namespace("alice")
        assert pol.resolve("alice", "shared") == "shared"
        assert pol.resolve("alice", "commons") == "commons"
        assert pol.resolve("alice", "tenant:alice") == "tenant:alice"
        with pytest.raises(NamespaceDenied):
            pol.resolve("bob", "tenant:alice")
        with pytest.raises(NamespaceDenied):
            pol.resolve("bob", "elsewhere")

    def test_client_default_namespace(self):
        with Client(namespace="tenant:carol") as client:
            register_demo_modules(client.registry)
            spec = WorkflowSpec.from_steps("nums", ["normalize", "scale"])
            for _ in range(3):  # enough history for the policy to store
                client.run(spec, [1.0, 2.0])
            keys = list(client.store.records)
            assert keys and all(k.startswith("tenant:carol/nums::") for k in keys)
            # a spec that carries its own namespace wins over the default
            shared = spec.with_namespace("shared")
            for _ in range(3):
                client.run(shared, [1.0, 2.0])
            assert any(k.startswith("shared/nums::") for k in client.store.records)


# -- HTTP surface -------------------------------------------------------------

class TestHttpSurface:
    def test_healthz_unauthenticated(self, gateway):
        st, doc, _ = _request(gateway.url, "GET", "/healthz")
        assert st == 200 and doc["ok"] is True and doc["draining"] is False

    def test_auth_required(self, gateway):
        st, doc, hdrs = _request(gateway.url, "GET", "/v1/stats")
        assert st == 401 and doc["error"] == "unauthorized"
        assert "WWW-Authenticate" in hdrs
        st, doc, _ = _request(gateway.url, "GET", "/v1/stats", token="nope")
        assert st == 401
        st, _, _ = _request(gateway.url, "GET", "/v1/stats", token="tok-a")
        assert st == 200

    def test_submit_async_then_poll(self, gateway):
        st, doc, _ = _request(
            gateway.url, "POST", "/v1/workflows", "tok-a",
            {"spec": _chain_doc(), "data": [1, 2, 3]},
        )
        assert st == 202 and doc["status"] in ("pending", "running", "done")
        assert doc["namespace"] == "tenant:alice"
        final = _wait_done(gateway.url, "tok-a", doc["run_id"])
        assert final["status"] == "done"
        res = final["result"]
        assert res["n_nodes"] == 3
        assert res["output"]["n"] == 3

    def test_submit_wait_inline(self, gateway):
        st, doc, _ = _request(
            gateway.url, "POST", "/v1/workflows", "tok-a",
            {"spec": _chain_doc(), "data": [1, 2, 3], "wait": True},
        )
        assert st == 200 and doc["status"] == "done"

    def test_events_stream_reaches_terminal(self, gateway):
        st, doc, _ = _request(
            gateway.url, "POST", "/v1/workflows", "tok-a",
            {"spec": _chain_doc(), "data": [1, 2, 3]},
        )
        rid = doc["run_id"]
        req = urllib.request.Request(gateway.url + f"/v1/runs/{rid}/events")
        req.add_header("Authorization", "Bearer tok-a")
        with urllib.request.urlopen(req, timeout=30) as resp:
            assert resp.headers.get("Content-Type") == "application/x-ndjson"
            events = [json.loads(line) for line in resp.read().splitlines()]
        names = [e["event"] for e in events]
        assert names[0] == "accepted"
        assert names[-1] in ("finished", "failed")
        assert all(e["run_id"] == rid for e in events)

    def test_runs_are_tenant_scoped(self, gateway):
        _, doc, _ = _request(
            gateway.url, "POST", "/v1/workflows", "tok-a",
            {"spec": _chain_doc(), "data": [1, 2, 3], "wait": True},
        )
        rid = doc["run_id"]
        st, _, _ = _request(gateway.url, "GET", f"/v1/runs/{rid}", "tok-b")
        assert st == 404  # foreign run ids look unknown, not forbidden
        st, _, _ = _request(gateway.url, "GET", f"/v1/runs/{rid}/events", "tok-b")
        assert st == 404
        st, _, _ = _request(gateway.url, "GET", f"/v1/runs/{rid}", "tok-a")
        assert st == 200

    def test_recommend_endpoint(self, gateway):
        for _ in range(3):
            _request(
                gateway.url, "POST", "/v1/workflows", "tok-a",
                {"spec": _chain_doc(), "data": [1, 2, 3],
                 "namespace": "shared", "wait": True},
            )
        st, doc, _ = _request(
            gateway.url, "GET",
            "/v1/recommend?dataset=nums&modules=normalize&namespace=shared",
            "tok-a",
        )
        assert st == 200
        assert doc["dataset_id"] == "shared/nums"
        assert doc["next_modules"], doc
        assert doc["next_modules"][0]["module_id"] == "scale"

    def test_stats_endpoint(self, gateway):
        _request(
            gateway.url, "POST", "/v1/workflows", "tok-a",
            {"spec": _chain_doc(), "data": [1, 2, 3], "wait": True},
        )
        st, doc, _ = _request(gateway.url, "GET", "/v1/stats", "tok-a")
        assert st == 200
        assert doc["fabric"]["runs"] >= 1
        assert doc["tenant"]["alice"]["runs_total"] >= 1
        assert doc["gateway"]["accepted"] >= 1


# -- error mapping: the server must survive every malformed input -------------

class TestErrorMapping:
    def test_malformed_and_invalid_requests(self, gateway):
        base = gateway.url
        cases = []
        # malformed JSON
        cases.append(_request(base, "POST", "/v1/workflows", "tok-a", b"{nope"))
        # body not an object
        cases.append(_request(base, "POST", "/v1/workflows", "tok-a", b"[1,2]"))
        # spec not an object
        cases.append(
            _request(base, "POST", "/v1/workflows", "tok-a", {"spec": 7})
        )
        # unknown module
        bad = {"dataset_id": "d", "nodes": [{"id": "x", "module": "nope"}]}
        cases.append(
            _request(base, "POST", "/v1/workflows", "tok-a", {"spec": bad})
        )
        # cycle
        cyc = {
            "dataset_id": "d",
            "nodes": [
                {"id": "a", "module": "normalize", "after": ["b"]},
                {"id": "b", "module": "normalize", "after": ["a"]},
            ],
        }
        cases.append(
            _request(base, "POST", "/v1/workflows", "tok-a", {"spec": cyc})
        )
        # missing dataset_id
        cases.append(
            _request(base, "POST", "/v1/workflows", "tok-a", {"spec": {"nodes": []}})
        )
        # empty spec
        cases.append(
            _request(base, "POST", "/v1/workflows", "tok-a",
                     {"spec": {"dataset_id": "d", "nodes": []}})
        )
        # unknown run + unknown route
        cases.append(_request(base, "GET", "/v1/runs/r-missing", "tok-a"))
        cases.append(_request(base, "GET", "/v1/nothing", "tok-a"))
        # recommend without dataset
        cases.append(_request(base, "GET", "/v1/recommend", "tok-a"))

        for st, doc, _ in cases:
            assert 400 <= st < 500, (st, doc)
            assert "error" in doc and doc["message"], doc
        statuses = [st for st, _, _ in cases]
        assert statuses.count(422) >= 3  # validation failures are structured
        assert 400 in statuses and 404 in statuses

        # unknown-module message names the module and the known universe
        st, doc, _ = _request(
            base, "POST", "/v1/workflows", "tok-a",
            {"spec": {"dataset_id": "d", "nodes": [{"id": "x", "module": "nope"}]}},
        )
        assert st == 422 and "nope" in doc["message"]

        # ... and after all that abuse the server still works
        st, doc, _ = _request(base, "GET", "/healthz")
        assert st == 200
        st, doc, _ = _request(
            base, "POST", "/v1/workflows", "tok-a",
            {"spec": _chain_doc(), "data": [1, 2, 3], "wait": True},
        )
        assert st == 200 and doc["status"] == "done"

    def test_oversized_body_rejected(self):
        client = Client()
        register_demo_modules(client.registry)
        gw = GatewayServer(
            client, TokenAuthenticator(TOKENS), max_body_bytes=2048
        )
        gw.start()
        try:
            huge = json.dumps({"spec": _chain_doc(), "pad": "x" * 4096}).encode()
            st, doc, _ = _request(gw.url, "POST", "/v1/workflows", "tok-a", huge)
            assert st == 413 and doc["error"] == "too_large"
            st, _, _ = _request(gw.url, "GET", "/healthz")
            assert st == 200
        finally:
            gw.close()
            client.close()


# -- tenancy + reuse end to end ----------------------------------------------

class TestCrossTenantReuse:
    def test_shared_namespace_reuses_private_never_leaks(self, gateway):
        """Acceptance: tenant B's shared-namespace run reuses tenant A's
        intermediates (compute counters prove it); private artifacts are
        invisible across tenants."""
        base = gateway.url
        body = {"spec": _chain_doc(), "data": [1, 2, 3],
                "namespace": "shared", "wait": True}
        # warm: the miner needs history before the policy stores, and one
        # more run to persist the prefix
        stored_total = 0
        for _ in range(3):
            st, doc, _ = _request(base, "POST", "/v1/workflows", "tok-a", body)
            assert st == 200 and doc["status"] == "done"
            stored_total += len(doc["result"]["stored_keys"])
        assert stored_total >= 1
        # tenant B, same public prefix: zero computes, all skipped
        st, doc, _ = _request(base, "POST", "/v1/workflows", "tok-b", body)
        assert st == 200
        res = doc["result"]
        assert res["n_computed"] == 0 and res["n_skipped"] == res["n_nodes"]

        # the artifacts live under the shared namespace, not any tenant's
        store = gateway.client.store
        shared_keys = [k for k in store.records if k.startswith("shared/")]
        assert shared_keys
        assert not any(k.startswith("tenant:") for k in store.records)

        # private runs do NOT see shared (or each other's) artifacts
        priv = {"spec": _chain_doc(), "data": [1, 2, 3], "wait": True}
        st, doc, _ = _request(base, "POST", "/v1/workflows", "tok-b", priv)
        assert st == 200
        assert doc["namespace"] == "tenant:bob"
        assert doc["result"]["n_computed"] == doc["result"]["n_nodes"]

    def test_private_namespace_keys_disjoint(self, gateway):
        base = gateway.url
        body = {"spec": _chain_doc(), "data": [1, 2, 3], "wait": True}
        for _ in range(3):  # far enough to store under alice's namespace
            _request(base, "POST", "/v1/workflows", "tok-a", body)
        store = gateway.client.store
        alice_keys = [k for k in store.records if k.startswith("tenant:alice/")]
        assert alice_keys
        # bob's identical private pipeline starts cold
        st, doc, _ = _request(base, "POST", "/v1/workflows", "tok-b", body)
        assert doc["result"]["n_computed"] == doc["result"]["n_nodes"]
        assert not any(k.startswith("tenant:bob/") and k in alice_keys
                       for k in store.records)

    def test_foreign_private_namespace_403(self, gateway):
        st, doc, _ = _request(
            gateway.url, "POST", "/v1/workflows", "tok-b",
            {"spec": _chain_doc(), "data": [1], "namespace": "tenant:alice"},
        )
        assert st == 403 and doc["error"] == "namespace_denied"


# -- admission control --------------------------------------------------------

class TestAdmission:
    def _slow_gateway(self, **kw):
        client = Client(max_workers=1, max_concurrent_runs=1,
                        max_pending=kw.pop("max_pending", 2))
        _register_slow(client.registry)
        gw = GatewayServer(client, TokenAuthenticator(TOKENS), **kw)
        gw.start()
        return gw, client

    def test_saturation_answers_429_and_loses_nothing(self):
        gw, client = self._slow_gateway(max_pending=2)
        try:
            body = {
                "spec": WorkflowSpec.from_steps(
                    "d", [("slow", {"seconds": 0.3})]
                ).to_dict(),
                "data": [1],
            }
            accepted, rejected = [], 0
            for _ in range(6):
                st, doc, hdrs = _request(gw.url, "POST", "/v1/workflows",
                                         "tok-a", body)
                if st == 202:
                    accepted.append(doc["run_id"])
                else:
                    assert st == 429, (st, doc)
                    assert doc["error"] in ("saturated", "quota_exceeded")
                    assert int(hdrs["Retry-After"]) >= 1
                    rejected += 1
            assert rejected >= 1 and accepted
            # zero lost accepted runs: every 202 reaches "done"
            for rid in accepted:
                assert _wait_done(gw.url, "tok-a", rid)["status"] == "done"
            st, doc, _ = _request(gw.url, "GET", "/v1/stats", "tok-a")
            assert doc["fabric"]["rejected_runs"] + doc["tenant"]["alice"][
                "rejected"] >= rejected
        finally:
            gw.close()
            client.close()

    def test_per_tenant_inflight_quota(self):
        gw, client = self._slow_gateway(max_pending=8,
                                        max_inflight_per_tenant=1)
        try:
            body = {
                "spec": WorkflowSpec.from_steps(
                    "d", [("slow", {"seconds": 0.5})]
                ).to_dict(),
                "data": [1],
            }
            st1, doc1, _ = _request(gw.url, "POST", "/v1/workflows", "tok-a", body)
            assert st1 == 202
            st2, doc2, _ = _request(gw.url, "POST", "/v1/workflows", "tok-a", body)
            assert st2 == 429 and doc2["error"] == "quota_exceeded"
            # another tenant is unaffected by alice's quota
            st3, doc3, _ = _request(gw.url, "POST", "/v1/workflows", "tok-b", body)
            assert st3 == 202
            _wait_done(gw.url, "tok-a", doc1["run_id"])
            _wait_done(gw.url, "tok-b", doc3["run_id"])
            # slot released: alice may submit again
            st4, doc4, _ = _request(gw.url, "POST", "/v1/workflows", "tok-a", body)
            assert st4 == 202
            _wait_done(gw.url, "tok-a", doc4["run_id"])
        finally:
            gw.close()
            client.close()

    def test_bytes_quota_billed_and_credited(self):
        ledger = TenantLedger()
        ledger.charge_stored("alice", "k1", 1000)
        ledger.charge_stored("alice", "k2", 500)
        assert ledger.bytes_stored("alice") == 1500
        # re-billing a key to another tenant moves the bytes
        ledger.charge_stored("bob", "k1", 800)
        assert ledger.bytes_stored("alice") == 500
        assert ledger.bytes_stored("bob") == 800
        # eviction credits the billed owner; unknown keys are ignored
        ledger.credit_evicted("k1")
        ledger.credit_evicted("never-seen")
        assert ledger.bytes_stored("bob") == 0
        assert ledger.snapshot("alice")["keys_stored"] == 1

    def test_bytes_quota_rejects_submissions(self, gateway):
        gateway.admission.max_bytes_per_tenant = 1
        gateway.ledger.charge_stored("alice", "some/key", 10)
        try:
            st, doc, _ = _request(
                gateway.url, "POST", "/v1/workflows", "tok-a",
                {"spec": _chain_doc(), "data": [1, 2, 3]},
            )
            assert st == 429 and doc["error"] == "quota_exceeded"
            assert "quota" in doc["message"]
            # eviction frees the quota again
            gateway.ledger.credit_evicted("some/key")
            st, _, _ = _request(
                gateway.url, "POST", "/v1/workflows", "tok-a",
                {"spec": _chain_doc(), "data": [1, 2, 3], "wait": True},
            )
            assert st == 200
        finally:
            gateway.admission.max_bytes_per_tenant = None


# -- service-level regression: bounded pending, no silent queueing ------------

class TestServiceAdmission:
    def _service(self, max_pending):
        store = IntermediateStore(tempfile.mkdtemp(prefix="repro-gwtest-"))
        policy = make_policy("PT", with_state=True)
        svc = WorkflowService(
            store, policy, max_workers=1, max_concurrent_runs=1,
            max_pending=max_pending,
        )
        svc.register_fn("slow", lambda xs: (time.sleep(0.3), xs)[1])
        return svc

    def test_saturation_rejects_rather_than_accumulates(self):
        svc = self._service(max_pending=2)
        try:
            dag = svc.dag("d")
            dag.chain(["slow"])
            futs = [svc.submit(dag, [1]), svc.submit(dag, [1])]
            with pytest.raises(AdmissionRejected) as exc:
                svc.submit(dag, [1])
            assert exc.value.pending == 2 and exc.value.max_pending == 2
            assert svc.pending_runs == 2  # nothing accumulated
            assert svc.rejected_runs == 1
            for f in futs:
                f.result(timeout=30)
            # capacity freed: accepted again
            svc.submit(dag, [1]).result(timeout=30)
        finally:
            svc.close()

    def test_unbounded_default_unchanged(self):
        svc = self._service(max_pending=None)
        try:
            dag = svc.dag("d")
            dag.chain(["slow"])
            futs = [svc.submit(dag, [1]) for _ in range(5)]
            for f in futs:
                f.result(timeout=30)
        finally:
            svc.close()

    def test_on_state_callbacks_fire_in_order(self):
        svc = self._service(max_pending=None)
        try:
            dag = svc.dag("d")
            dag.chain(["slow"])
            states: list[str] = []
            svc.submit(dag, [1], on_state=states.append).result(timeout=30)
            assert states == ["started", "finished"]
        finally:
            svc.close()

    def test_submit_after_shutdown_raises_service_closed(self):
        svc = self._service(max_pending=None)
        dag = svc.dag("d")
        dag.chain(["slow"])
        fut = svc.submit(dag, [1])
        svc.begin_shutdown()
        with pytest.raises(ServiceClosed):
            svc.submit(dag, [1])
        # the in-flight run still completes: drain, don't drop
        assert fut.result(timeout=30) is not None
        svc.close()
        svc.close()  # idempotent


# -- graceful shutdown ---------------------------------------------------------

class TestShutdown:
    def test_client_close_idempotent(self):
        client = Client()
        client.close()
        client.close()
        with Client() as c2:
            c2.close()  # __exit__ will close again: must not raise

    def test_gateway_drains_inflight_and_503s_new(self):
        client = Client(max_workers=1, max_pending=8)
        _register_slow(client.registry)
        gw = GatewayServer(client, TokenAuthenticator(TOKENS))
        gw.start()
        body = {
            "spec": WorkflowSpec.from_steps(
                "d", [("slow", {"seconds": 0.5})]
            ).to_dict(),
            "data": [1],
        }
        st, doc, _ = _request(gw.url, "POST", "/v1/workflows", "tok-a", body)
        assert st == 202
        gw.begin_shutdown()
        # new submissions: structured 503 + Retry-After
        st2, doc2, hdrs = _request(gw.url, "POST", "/v1/workflows", "tok-a", body)
        assert st2 == 503 and doc2["error"] == "draining"
        assert "Retry-After" in hdrs
        # health reflects draining; status stays readable during the drain
        st3, health, _ = _request(gw.url, "GET", "/healthz")
        assert st3 == 200 and health["draining"] is True
        final = _wait_done(gw.url, "tok-a", doc["run_id"])
        assert final["status"] == "done"  # accepted run was not dropped
        gw.close()
        gw.close()  # idempotent
        client.close()

    def test_cli_sigterm_graceful(self, tmp_path: Path):
        repo_src = str(Path(__file__).resolve().parent.parent / "src")
        proc = subprocess.Popen(
            [
                sys.executable, "-m", "repro.gateway.serve",
                "--root", str(tmp_path / "store"),
                "--port", "0",
                "--token", "t=alice",
                "--demo-modules",
            ],
            stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT,
            text=True,
            env={"PYTHONPATH": repo_src, "PATH": "/usr/bin:/bin"},
        )
        try:
            line = proc.stdout.readline()
            assert "gateway listening on http://" in line, line
            base = line.split("listening on ")[1].split()[0]
            st, doc, _ = _request(base, "GET", "/healthz", timeout=10)
            assert st == 200 and doc["ok"]
            st, doc, _ = _request(
                base, "POST", "/v1/workflows", "t",
                {"spec": _chain_doc(), "data": [1, 2, 3], "wait": True},
                timeout=30,
            )
            assert st == 200 and doc["status"] == "done"
            proc.send_signal(signal.SIGTERM)
            out, _ = proc.communicate(timeout=30)
            assert proc.returncode == 0, out
            assert "gateway stopped" in out
        finally:
            if proc.poll() is None:
                proc.kill()
                proc.communicate(timeout=10)


# -- provenance-catalog browse (ISSUE 8) --------------------------------------

class TestArtifactsEndpoint:
    def _seed(self, gw, token, factor, namespace=None, runs=2):
        """Run the nums chain until the policy admits it (PT: support >= 2)."""
        spec = WorkflowSpec.from_steps(
            "nums", ["normalize", ("scale", {"factor": factor})]
        ).to_dict()
        body = {"spec": spec, "data": [1.0, 2.0, 3.0], "wait": True}
        if namespace is not None:
            body["namespace"] = namespace
        for _ in range(runs):
            st, doc, _ = _request(gw.url, "POST", "/v1/workflows", token, body)
            assert st == 200 and doc["status"] == "done", doc

    def test_artifacts_are_tenant_scoped(self, gateway):
        self._seed(gateway, "tok-a", factor=2.0)
        st, doc, _ = _request(gateway.url, "GET", "/v1/artifacts?module=scale", "tok-a")
        assert st == 200
        assert doc["namespace"] == "tenant:alice"
        assert doc["count"] >= 1
        art = doc["artifacts"][0]
        assert art["modules"][-1] == "scale"
        assert art["params"][-1] == {"factor": 2.0}
        assert art["key"].startswith("tenant:alice/nums::")
        # bob's private view is empty; alice's artifacts are invisible to him
        st, doc, _ = _request(gateway.url, "GET", "/v1/artifacts?module=scale", "tok-b")
        assert st == 200 and doc["count"] == 0
        # a foreign private namespace is a 403, not an empty answer
        st, doc, _ = _request(
            gateway.url, "GET", "/v1/artifacts?namespace=tenant:alice", "tok-b"
        )
        assert st == 403 and doc["error"] == "namespace_denied"

    def test_artifacts_param_filter_is_typed(self, gateway):
        self._seed(gateway, "tok-a", factor=2.0)
        self._seed(gateway, "tok-a", factor=3.0)
        st, doc, _ = _request(
            gateway.url, "GET", "/v1/artifacts?module=scale&param.factor=2.0", "tok-a"
        )
        assert st == 200 and doc["count"] == 1
        assert doc["artifacts"][0]["params"][-1] == {"factor": 2.0}
        st, doc, _ = _request(
            gateway.url, "GET", "/v1/artifacts?module=scale&param.factor=9.9", "tok-a"
        )
        assert st == 200 and doc["count"] == 0
        # filters without a module anchor are a structured 400
        st, doc, _ = _request(
            gateway.url, "GET", "/v1/artifacts?param.factor=2.0", "tok-a"
        )
        assert st == 400 and doc["error"] == "bad_request"
        st, doc, _ = _request(
            gateway.url, "GET", "/v1/artifacts?module=scale&limit=nope", "tok-a"
        )
        assert st == 400

    def test_shared_namespace_is_browsable_cross_tenant(self, gateway):
        self._seed(gateway, "tok-a", factor=2.0, namespace="shared")
        st, doc, _ = _request(
            gateway.url, "GET", "/v1/artifacts?module=scale&namespace=shared", "tok-b"
        )
        assert st == 200 and doc["namespace"] == "shared" and doc["count"] >= 1
        assert all(
            a["key"].startswith("shared/") for a in doc["artifacts"]
        )

    def test_artifacts_never_report_evicted(self, gateway):
        self._seed(gateway, "tok-a", factor=2.0)
        st, doc, _ = _request(gateway.url, "GET", "/v1/artifacts?module=scale", "tok-a")
        assert doc["count"] >= 1
        for art in doc["artifacts"]:
            gateway.client.store.evict(art["key"])
        st, doc, _ = _request(gateway.url, "GET", "/v1/artifacts?module=scale", "tok-a")
        assert st == 200 and doc["count"] == 0, doc

    def test_recommend_surfaces_near_misses(self, gateway):
        self._seed(gateway, "tok-a", factor=3.0)
        # the recommend chain resolves the registry default factor=2.0 —
        # one param away from the stored factor=3.0 artifact
        st, doc, _ = _request(
            gateway.url, "GET", "/v1/recommend?dataset=nums&modules=normalize,scale",
            "tok-a",
        )
        assert st == 200, doc
        assert doc["near_misses"], doc
        nm = doc["near_misses"][0]
        assert nm["kind"] == "near_miss"
        assert "scale.factor=3.0 (yours 2.0)" == nm["note"]
