"""Provenance catalog: records, index, facade, Client.find, near misses.

ISSUE 8's tentpole contract, unit-to-integration: canonical record/query
documents, the posting-list index, event-driven consistency with the store
(publish on admission, discard on eviction — never a scan of ``index.json``),
the remote op family, and the two satellite fixes that ride along (shared
``index.json`` re-parse skip, ``ToolState.to_config`` decode cache).
"""
import json
import time

import pytest

import jax.numpy as jnp

from repro.api import Client
from repro.catalog import (
    Catalog,
    CatalogIndex,
    CatalogQuery,
    CatalogRecord,
    rank_key,
    record_for_prefix,
    split_namespaced_dataset,
)
from repro.core import IntermediateStore, LocalFSBackend, MemoryBackend
from repro.core.workflow import ModuleRef, PrefixKey, ToolState, encode_param
from repro.net import RemoteBackend, StoreServer


def _prefix(dataset="ds", chain=(("load", {"scale": 2}), ("norm", {"mode": "z"}))):
    refs = tuple(
        ModuleRef(m, ToolState.from_config(cfg)) for m, cfg in chain
    )
    return PrefixKey(dataset, refs)


def _rec(dataset="ds", chain=(("load", {"scale": 2}), ("norm", {"mode": "z"})),
         **stats):
    p = _prefix(dataset, chain)
    return record_for_prefix(p, p.key(True), **stats)


# -- records / documents -------------------------------------------------------
def test_split_namespaced_dataset():
    assert split_namespaced_dataset("alice/ds1") == ("alice", "ds1")
    assert split_namespaced_dataset("ds1") == ("", "ds1")
    # only the FIRST separator splits: datasets may contain '/'
    assert split_namespaced_dataset("a/b/c") == ("a", "b/c")


def test_record_for_prefix_and_roundtrip():
    rec = _rec("alice/ds1", nbytes=10, n_loads=3)
    assert rec.namespace == "alice"
    assert rec.dataset == "ds1"
    assert rec.dataset_id == "alice/ds1"
    assert rec.modules == ("load", "norm")
    assert rec.module == "norm"
    assert rec.depth == 2
    # params are stored encoded, decoded on demand, typed
    assert rec.params(0) == {"scale": 2}
    assert rec.params() == {"mode": "z"}
    # document round trip is exact
    back = CatalogRecord.from_doc(json.loads(json.dumps(rec.to_doc())))
    assert back == rec
    # PrefixKey reconstruction reproduces the store key
    assert back.prefix_key().key(True) == rec.key


def test_query_build_encodes_typed_params():
    q = CatalogQuery.build(module="load", params={"scale": 2})
    assert q.params == {"scale": encode_param(2)}
    rec_int = _rec(chain=(("load", {"scale": 2}),))
    rec_str = _rec(chain=(("load", {"scale": "2"}),))
    assert q.matches(rec_int)
    assert not q.matches(rec_str), "31 != '31': typing is part of identity"
    with pytest.raises(ValueError, match="module"):
        CatalogQuery.build(params={"scale": 2})


def test_query_matching_positions_and_scopes():
    rec = _rec("alice/ds1")
    assert CatalogQuery.build(module="norm").matches(rec)
    assert not CatalogQuery.build(module="load").matches(rec)
    assert CatalogQuery.build(module="load", any_position=True).matches(rec)
    assert CatalogQuery.build(namespace="alice").matches(rec)
    assert not CatalogQuery.build(namespace="").matches(rec)
    assert CatalogQuery.build(dataset="ds1").matches(rec)
    assert not CatalogQuery.build(dataset="other").matches(rec)
    # repeated module id: params anchor to SOME position with that module
    twice = _rec(chain=(("f", {"k": 1}), ("f", {"k": 2})))
    assert CatalogQuery.build(module="f", params={"k": 1}, any_position=True).matches(twice)
    assert not CatalogQuery.build(module="f", params={"k": 1}).matches(twice)
    assert CatalogQuery.build(module="f", params={"k": 2}).matches(twice)


def test_rank_key_orders_loads_depth_recency():
    a = _rec("d1", (("m", {"k": 1}),), n_loads=5)
    b = _rec("d2", (("m", {"k": 1}), ("m2", {})), n_loads=1, last_used_at=100.0)
    c = _rec("d3", (("m", {"k": 1}),), n_loads=1, last_used_at=50.0)
    assert sorted([c, b, a], key=rank_key) == [a, b, c]


# -- index ---------------------------------------------------------------------
def test_index_upsert_touch_discard():
    idx = CatalogIndex()
    rec = _rec(n_loads=1, last_used_at=10.0)
    idx.upsert(rec)
    assert len(idx) == 1 and rec.key in idx
    # re-publish with staler stats keeps the best ones
    idx.upsert(_rec(n_loads=0, last_used_at=5.0))
    assert idx.get(rec.key).n_loads == 1
    assert idx.touch(rec.key, last_used_at=20.0, n_loads=4)
    assert idx.get(rec.key).n_loads == 4
    assert idx.get(rec.key).last_used_at == 20.0
    assert not idx.touch("missing", last_used_at=1.0, n_loads=1)
    assert idx.discard(rec.key)
    assert not idx.discard(rec.key), "discard is idempotent"
    assert len(idx) == 0
    assert idx.query(CatalogQuery.build(module="norm")) == []


def test_index_query_uses_postings_but_stays_exact():
    idx = CatalogIndex()
    for i in range(20):
        idx.upsert(_rec(f"ds{i}", (("load", {"scale": i}), ("norm", {"mode": "z"}))))
    hits = idx.query(CatalogQuery.build(module="load", params={"scale": 7},
                                        any_position=True))
    assert [h.params(0) for h in hits] == [{"scale": 7}]
    assert idx.query(CatalogQuery.build(module="norm", limit=5)) == sorted(
        idx.query(CatalogQuery.build(module="norm", limit=100)), key=rank_key
    )[:5]
    assert idx.query(CatalogQuery.build(dataset="ds3"))[0].dataset == "ds3"


def test_index_snapshot_load_and_prune():
    idx = CatalogIndex()
    idx.upsert(_rec("a/ds"))
    idx.upsert(_rec("b/ds"))
    docs = idx.snapshot()
    fresh = CatalogIndex()
    fresh.load(docs + [{"broken": True}, 42])  # malformed entries are skipped
    assert len(fresh) == 2
    keep = {r.key for r in fresh.query(CatalogQuery.build(namespace="a"))}
    fresh.prune(lambda k: k in keep)
    assert len(fresh) == 1
    assert fresh.query(CatalogQuery.build(namespace="b")) == []


# -- facade: local persistence + verification ----------------------------------
def test_catalog_persists_and_reloads(tmp_path):
    backend = LocalFSBackend(tmp_path)
    cat = Catalog(backend)
    assert cat.persist
    rec = cat.publish(_prefix("alice/ds1"), _prefix("alice/ds1").key(True))
    cat.flush()
    reborn = Catalog(LocalFSBackend(tmp_path))
    assert [r.key for r in reborn.find(module="norm")] == [rec.key]
    # discard + flush survives a reload too
    reborn.discard(rec.key)
    reborn.flush()
    assert Catalog(LocalFSBackend(tmp_path)).find(module="norm") == []


def test_verify_present_drops_and_prunes(tmp_path):
    cat = Catalog(LocalFSBackend(tmp_path))
    a = cat.publish(_prefix("ds1"), "k-a")
    b = cat.publish(_prefix("ds2"), "k-b")
    c = cat.publish(_prefix("ds3"), "k-c")
    kept = cat.verify_present(
        [a, b, c], {"k-a": "present", "k-b": "absent", "k-c": "unreachable"}
    )
    assert [r.key for r in kept] == ["k-a"]
    # authoritative absence pruned the index; unreachable stayed indexed
    assert "k-b" not in cat.index
    assert "k-c" in cat.index


# -- satellite: shared index.json re-parse skip --------------------------------
def test_shared_index_skips_reparse_when_bytes_unchanged():
    store = IntermediateStore(backend=MemoryBackend())
    store.backend.write_meta(
        "index.json", json.dumps({"k": {"key": "k", "nbytes_raw": 4,
                                        "nbytes_disk": 4, "save_s": 0.1}})
    )
    with store._lock:
        first = store._shared_index()
        # force TTL expiry; the meta bytes have NOT changed
        ts, raw, parsed = store._shared_index_cache
        store._shared_index_cache = (ts - 1e6, raw, parsed)
        again = store._shared_index()
        assert again is first, "unchanged bytes must reuse the cached parse"
        # a real change does re-parse
        store.backend.write_meta("index.json", json.dumps({}))
        ts, raw, parsed = store._shared_index_cache
        store._shared_index_cache = (ts - 1e6, raw, parsed)
        changed = store._shared_index()
        assert changed == {} and changed is not first


# -- satellite: ToolState decode cache -----------------------------------------
def test_toolstate_to_config_caches_decode():
    ts = ToolState.from_config({"a": (1, 2), "b": 3.5, "c": "x"})
    one = ts.to_config()
    assert one == {"a": (1, 2), "b": 3.5, "c": "x"}
    cached = ts._decoded
    two = ts.to_config()
    assert ts._decoded is cached, "decode runs once per instance"
    assert two == one and two is not one, "callers get independent copies"
    two["a"] = None
    assert ts.to_config() == one


# -- client integration: publish/find/evict/near-miss --------------------------
def _client(tmp_path, **kw):
    c = Client(str(tmp_path / "store"), **kw)
    c.register_fn("load", lambda d, scale=1: [x * scale for x in d], scale=1)
    c.register_fn("norm", lambda d, mode="z": d, mode="z")
    return c


def _run_chain(c, scale, mode="z", dataset="ds1", times=2):
    for _ in range(times):  # PT admits at support >= 2
        spec = c.spec(dataset)
        spec.chain([("load", {"scale": scale}), ("norm", {"mode": mode})])
        r = c.run(spec, [1, 2, 3])
    return r


def test_client_find_in_process(tmp_path):
    c = _client(tmp_path, namespace="alice")
    try:
        _run_chain(c, scale=2)
        _run_chain(c, scale=3)
        hits = c.find(module="norm", params={"mode": "z"})
        assert len(hits) == 2
        assert all(h.namespace == "alice" for h in hits)
        assert {h.params(0)["scale"] for h in hits} == {2, 3}
        # terminal-module anchoring: 'load' produced no terminal artifact here
        assert c.find(module="load", params={"scale": 2}) == []
        assert len(c.find(module="load", any_position=True)) == 2
        # namespace scoping: the bound namespace is the default scope
        assert c.find(module="norm", namespace="bob") == []
        assert len(c.find(module="norm", namespace="*")) == 2
    finally:
        c.close()


def test_client_find_never_reports_evicted(tmp_path):
    c = _client(tmp_path)
    try:
        _run_chain(c, scale=2)
        hits = c.find(module="norm")
        assert len(hits) == 1
        key = hits[0].key
        c.store.evict(key)
        assert c.find(module="norm") == [], "zero-phantom: evicted => invisible"
        assert key not in c.catalog.index
    finally:
        c.close()


def test_catalog_survives_client_restart_local(tmp_path):
    c = _client(tmp_path)
    try:
        _run_chain(c, scale=2)
    finally:
        c.close()
    c2 = Client(str(tmp_path / "store"))
    try:
        hits = c2.find(module="norm")
        assert len(hits) == 1, "catalog.json persists across client restarts"
    finally:
        c2.close()


def test_recommender_near_misses(tmp_path):
    c = _client(tmp_path, namespace="alice")
    try:
        _run_chain(c, scale=2)
        _run_chain(c, scale=3)
        _run_chain(c, scale=3, mode="minmax")
        spec = c.spec("ds1")
        spec.chain([("load", {"scale": 7}), ("norm", {"mode": "z"})])
        report = c.recommend(spec)
        # scale=2 and scale=3 stored chains differ from scale=7 by exactly
        # the one param; the (3, minmax) chain differs by two and is excluded
        notes = [s.note for s in report.near_misses]
        assert len(notes) == 2
        assert all("load.scale=" in n and "(yours 7)" in n for n in notes)
        assert all(s.kind == "near_miss" for s in report.near_misses)
        # an exact stored match is a reuse hit, not a near miss; the
        # (scale=3, minmax) chain differs by TWO params and is excluded too
        spec2 = c.spec("ds1")
        spec2.chain([("load", {"scale": 2}), ("norm", {"mode": "z"})])
        exact = c.recommend(spec2)
        assert [s.note for s in exact.near_misses] == ["load.scale=3 (yours 2)"]
    finally:
        c.close()


def test_near_miss_requires_single_diff():
    from repro.api.recommend import Recommender

    own = [{"a": encode_param(1), "b": encode_param(2)}]
    same = [{"a": encode_param(1), "b": encode_param(2)}]
    one = [{"a": encode_param(9), "b": encode_param(2)}]
    two = [{"a": encode_param(9), "b": encode_param(8)}]
    missing = [{"a": encode_param(1)}]
    assert Recommender._one_param_diff(own, same, ("m",)) is None
    assert "m.a=9 (yours 1)" == Recommender._one_param_diff(own, one, ("m",))
    assert Recommender._one_param_diff(own, two, ("m",)) is None
    assert "m.b=unset (yours 2)" == Recommender._one_param_diff(own, missing, ("m",))


# -- remote: server op family + cross-client durability ------------------------
@pytest.fixture()
def server(tmp_path):
    srv = StoreServer(LocalFSBackend(tmp_path / "pool")).start()
    yield srv
    srv.stop()


def test_server_catalog_ops(server):
    rb = RemoteBackend(f"127.0.0.1:{server.port}")
    try:
        rec = _rec("alice/ds1", n_loads=2)
        assert rb.catalog_put(rec.to_doc())
        assert server.stats()["catalog_records"] == 1
        out = rb.catalog_query(CatalogQuery.build(module="norm").to_doc())
        assert [d["key"] for d in out] == [rec.key]
        assert rb.catalog_query(CatalogQuery.build(module="other").to_doc()) == []
        assert rb.catalog_remove(rec.key)
        assert server.stats()["catalog_records"] == 0
    finally:
        rb.close()


def test_server_delete_prunes_catalog(server):
    rb = RemoteBackend(f"127.0.0.1:{server.port}")
    store = IntermediateStore(backend=rb)
    try:
        p = _prefix("ds1")
        key = p.key(True)
        store.put(key, jnp.ones((4,)))
        assert rb.catalog_put(record_for_prefix(p, key).to_doc())
        store.evict(key)  # -> backend.delete -> server-side catalog prune
        assert server.catalog.get(key) is None
    finally:
        rb.close()


def test_server_catalog_persists_across_restart(tmp_path):
    backend_dir = tmp_path / "pool"
    srv = StoreServer(LocalFSBackend(backend_dir)).start()
    rb = RemoteBackend(f"127.0.0.1:{srv.port}")
    rec = _rec("ds-persist")
    try:
        assert rb.catalog_put(rec.to_doc())
    finally:
        rb.close()
        srv.stop()  # flushes catalog.json
    # the restarted server prunes entries whose blob is gone
    srv2 = StoreServer(LocalFSBackend(backend_dir)).start()
    try:
        assert len(srv2.catalog) == 0, "blobless records are pruned at load"
    finally:
        srv2.stop()


def test_server_catalog_restart_keeps_live_records(tmp_path):
    backend_dir = tmp_path / "pool"
    srv = StoreServer(LocalFSBackend(backend_dir)).start()
    rb = RemoteBackend(f"127.0.0.1:{srv.port}")
    store = IntermediateStore(backend=rb)
    p = _prefix("ds1")
    key = p.key(True)
    try:
        store.put(key, jnp.ones((4,)))
        assert rb.catalog_put(record_for_prefix(p, key).to_doc())
    finally:
        rb.close()
        srv.stop()
    srv2 = StoreServer(LocalFSBackend(backend_dir)).start()
    rb2 = RemoteBackend(f"127.0.0.1:{srv2.port}")
    try:
        out = rb2.catalog_query(CatalogQuery.build(module="norm").to_doc())
        assert [d["key"] for d in out] == [key]
    finally:
        rb2.close()
        srv2.stop()


def test_remote_backend_degrades_without_catalog_support(server):
    rb = RemoteBackend(f"127.0.0.1:{server.port}")
    try:
        # simulate an old server: force the negotiation flag
        rb._server_catalog = False
        assert not rb.catalog_put(_rec().to_doc())
        assert rb.catalog_query(CatalogQuery.build(module="m").to_doc()) is None
        assert not rb.catalog_remove("k")
    finally:
        rb.close()


def test_client_remote_catalog_survives_client_churn(server, tmp_path):
    url = f"127.0.0.1:{server.port}"
    c = Client(store_url=url, namespace="alice")
    c.register_fn("load", lambda d, scale=1: [x * scale for x in d], scale=1)
    c.register_fn("norm", lambda d, mode="z": d, mode="z")
    try:
        _run_chain(c, scale=2)
    finally:
        c.close()
    # a brand-new client (empty local index) answers from the server's index
    c2 = Client(store_url=url, namespace="alice")
    try:
        hits = c2.find(module="norm")
        assert len(hits) == 1
        assert c2.catalog.remote_queries == 1
        assert len(c2.catalog.index) == 0
    finally:
        c2.close()


# -- cross-namespace dedup report ----------------------------------------------
def _tenant_rec(ns, dataset="ds", chain=(("load", {"scale": 2}),), **stats):
    return _rec(f"{ns}/{dataset}", chain, **stats)


def test_dedup_report_groups_identical_chains_across_tenants():
    from repro.catalog.query import dedup_report

    a = _tenant_rec("tenant:a", nbytes=100, n_loads=5)
    b = _tenant_rec("tenant:b", nbytes=100, n_loads=1)
    c = _tenant_rec("tenant:c", nbytes=100)
    # same chain but different params: NOT a duplicate
    other = _tenant_rec("tenant:b", chain=(("load", {"scale": 3}),), nbytes=50)
    # duplicated only within one tenant: NOT a cross-namespace candidate
    solo = _tenant_rec("tenant:a", dataset="ds2", nbytes=10)
    report = dedup_report([a, b, c, other, solo])
    assert len(report) == 1
    entry = report[0]
    assert entry["namespaces"] == ["tenant:a", "tenant:b", "tenant:c"]
    assert entry["n_copies"] == 3
    assert entry["keep"] == a.key, "most-reused copy is kept"
    assert entry["promote_to"] == "shared"
    assert entry["reclaimable_bytes"] == 200
    assert entry["total_loads"] == 6
    assert entry["params"] == {"scale": 2}


def test_dedup_report_tenant_only_toggle():
    from repro.catalog.query import dedup_report

    shared = _rec("shared/ds", (("load", {"scale": 2}),), nbytes=10)
    tenant = _tenant_rec("tenant:a", nbytes=10)
    assert dedup_report([shared, tenant]) == []
    full = dedup_report([shared, tenant], tenant_only=False)
    assert len(full) == 1
    assert full[0]["namespaces"] == ["shared", "tenant:a"]


def test_dedup_cli_end_to_end(tmp_path, capsys):
    from repro.catalog.query import main as query_main

    cat = Catalog(LocalFSBackend(tmp_path))
    for ns, loads in (("tenant:a", 3), ("tenant:b", 0)):
        p = _prefix(f"{ns}/ds")
        cat.publish(p, p.key(True))
        rec = cat.index.get(p.key(True))
        rec.nbytes, rec.n_loads = 40, loads
    cat.flush()
    assert query_main(["--root", str(tmp_path), "--dedup", "--json"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert len(report) == 1
    assert report[0]["reclaimable_bytes"] == 40
    assert report[0]["namespaces"] == ["tenant:a", "tenant:b"]
    # human output mode runs clean too
    assert query_main(["--root", str(tmp_path), "--dedup"]) == 0
    out, err = capsys.readouterr()
    assert "tenant:a,tenant:b" in out
    assert "40 byte(s) reclaimable" in err
