"""Property/fuzz tests for the wire protocol (ISSUE 6 satellite).

Two families:

* **round-trip** — any JSON-object header and any payload (0 bytes, chunk
  boundaries ±1, multi-chunk) must survive ``send_* -> recv_*`` bit-exact,
  one-shot and chunked alike;
* **adversarial bytes** — malformed, truncated, and oversized-length-prefix
  frames must raise *typed* errors (``ProtocolError``/``ConnectionClosed``)
  promptly, never hang waiting for bytes that cannot come and never allocate
  a buffer an attacker named in a length prefix.

Property tests run under hypothesis when installed and skip cleanly when not
(see ``tests/_hypothesis_compat.py``); the example-based edge cases below
them always run.
"""
import socket
import struct
import threading

import pytest

from repro.net import protocol as P
from tests._hypothesis_compat import HealthCheck, given, settings, st


def _pair():
    a, b = socket.socketpair()
    a.settimeout(5)
    b.settimeout(5)
    return a, b


def _roundtrip_frame(header, payload):
    a, b = _pair()
    try:
        P.send_frame(a, header, payload)
        got_header, got_payload = P.recv_frame(b)
        return got_header, got_payload
    finally:
        a.close()
        b.close()


def _roundtrip_stream(data, chunk_bytes):
    """Stream ``data`` through a socketpair with a sender thread (streams can
    exceed the kernel socket buffer, so one thread cannot do both ends)."""
    a, b = _pair()
    sent: dict = {}

    def send():
        try:
            sent["digest"] = P.send_blob_stream(a, data, chunk_bytes)
        finally:
            a.close()

    t = threading.Thread(target=send, daemon=True)
    t.start()
    try:
        buf, folded, end = P.recv_blob_stream(b, len(data))
    finally:
        b.close()
        t.join(timeout=5)
    return bytes(buf), folded, end, sent.get("digest")


# -- property tests (hypothesis) ----------------------------------------------
_json_scalars = st.one_of(
    st.none(),
    st.booleans(),
    st.integers(min_value=-(2**53), max_value=2**53),
    st.text(max_size=40),
)


@settings(max_examples=50, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    header=st.dictionaries(st.text(max_size=20), _json_scalars, max_size=8),
    payload=st.binary(max_size=8192),
)
def test_frame_roundtrip_property(header, payload):
    got_header, got_payload = _roundtrip_frame(header, payload)
    assert got_header == header
    assert got_payload == payload


@settings(max_examples=25, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    size=st.integers(min_value=0, max_value=5000),
    chunk_bytes=st.integers(min_value=1, max_value=1024),
)
def test_stream_roundtrip_property(size, chunk_bytes):
    data = bytes(i % 251 for i in range(size))
    buf, folded, end, declared = _roundtrip_stream(data, chunk_bytes)
    assert buf == data
    assert folded == declared == end["digest"] == P.digest(data)


@settings(max_examples=50, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(junk=st.binary(min_size=0, max_size=64))
def test_truncated_frames_raise_typed_errors(junk):
    """Any byte prefix shorter than a full frame must end in ConnectionClosed
    (empty) or ProtocolError (partial) — never a hang, never a crash."""
    a, b = _pair()
    try:
        a.sendall(junk)
        a.close()
        with pytest.raises(P.ProtocolError):  # ConnectionClosed subclasses it
            while True:
                P.recv_frame(b)
    finally:
        b.close()


# -- example-based edge cases --------------------------------------------------
def test_frame_roundtrip_zero_and_boundaries():
    for n in (0, 1, P.DEFAULT_CHUNK_BYTES // 1024):
        header, payload = _roundtrip_frame({"op": "x", "n": n}, b"q" * n)
        assert header == {"op": "x", "n": n}
        assert payload == b"q" * n


@pytest.mark.parametrize("delta", [-1, 0, 1])
def test_stream_chunk_boundary_plus_minus_one(delta):
    chunk = 1024
    for chunks in (1, 3):
        size = chunks * chunk + delta
        data = bytes(i % 256 for i in range(size))
        buf, folded, end, declared = _roundtrip_stream(data, chunk)
        assert buf == data
        assert folded == declared


def test_stream_overlapped_fold_matches_inline():
    """The worker-thread fold (multi-core receive path) must produce the
    same digest as the inline fold, including on torn/aborted streams."""
    data = bytes(i % 256 for i in range(3 * 1024 + 1))
    for overlap in (True, False):
        a, b = _pair()
        sent: dict = {}

        def send():
            try:
                sent["digest"] = P.send_blob_stream(a, data, 1024)
            finally:
                a.close()

        t = threading.Thread(target=send, daemon=True)
        t.start()
        try:
            buf, folded, end = P.recv_blob_stream(
                b, len(data), overlap_fold=overlap
            )
        finally:
            b.close()
            t.join(timeout=5)
        assert bytes(buf) == data
        assert folded == sent["digest"] == P.digest(data)


def test_stream_overlapped_fold_cleans_up_on_error():
    """A truncated stream must not leak the folder's worker thread."""
    import threading as _threading

    a, b = _pair()
    a.sendall(struct.pack(">IQ", len(b'{"c":1}'), 100) + b'{"c":1}' + b"y" * 40)
    a.close()
    before = _threading.active_count()
    try:
        with pytest.raises(P.ProtocolError):
            P.recv_blob_stream(b, 100, overlap_fold=True)
    finally:
        b.close()
    assert _threading.active_count() <= before


def test_zero_byte_stream():
    buf, folded, end, declared = _roundtrip_stream(b"", 1024)
    assert buf == b""
    assert folded == declared == P.digest(b"")


def test_clean_eof_is_connection_closed():
    a, b = _pair()
    a.close()
    try:
        with pytest.raises(P.ConnectionClosed):
            P.recv_frame(b)
    finally:
        b.close()


def test_eof_inside_prefix_is_protocol_error_not_closed():
    a, b = _pair()
    a.sendall(b"\x00\x00")  # 2 of the 12 prefix bytes
    a.close()
    try:
        with pytest.raises(P.ProtocolError) as ei:
            P.recv_frame(b)
        assert not isinstance(ei.value, P.ConnectionClosed)
    finally:
        b.close()


def test_oversized_header_length_prefix_rejected_without_allocation():
    """A hostile length prefix must be rejected from the 12 prefix bytes
    alone — the receiver must never try to allocate or await the bytes."""
    a, b = _pair()
    a.sendall(struct.pack(">IQ", P.MAX_HEADER_BYTES + 1, 0))
    try:
        with pytest.raises(P.ProtocolError, match="out of range"):
            P.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_oversized_payload_length_prefix_rejected():
    a, b = _pair()
    a.sendall(struct.pack(">IQ", 2, P.MAX_PAYLOAD_BYTES + 1) + b"{}")
    try:
        with pytest.raises(P.ProtocolError, match="out of range"):
            P.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_unparseable_header_is_protocol_error():
    a, b = _pair()
    bad = b"not json!"
    a.sendall(struct.pack(">IQ", len(bad), 0) + bad)
    try:
        with pytest.raises(P.ProtocolError, match="unparseable"):
            P.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_non_object_header_is_protocol_error():
    a, b = _pair()
    bad = b"[1,2,3]"
    a.sendall(struct.pack(">IQ", len(bad), 0) + bad)
    try:
        with pytest.raises(P.ProtocolError, match="must be an object"):
            P.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_recv_frame_into_rejects_payload_beyond_window():
    """A stream receiver's bounded buffer is the memory ceiling: a chunk
    bigger than the remaining window must be refused, not grown into."""
    a, b = _pair()
    P.send_frame(a, {"c": 1}, b"x" * 100)
    try:
        buf = bytearray(10)
        with pytest.raises(P.ProtocolError, match="receive window"):
            P.recv_frame_into(b, memoryview(buf))
    finally:
        a.close()
        b.close()


def test_send_chunk_rejects_oversize():
    a, b = _pair()
    try:
        with pytest.raises(P.ProtocolError, match="MAX_CHUNK_BYTES"):
            # a lying length is enough — no giant buffer needed
            P.send_chunk_prefix(a, P.MAX_CHUNK_BYTES + 1)
    finally:
        a.close()
        b.close()


def test_stream_ended_early_is_protocol_error():
    a, b = _pair()
    P.send_chunk(a, b"x" * 10)
    P.send_stream_end(a, digest_hex=P.digest(b"x" * 10))
    try:
        with pytest.raises(P.ProtocolError, match="ended early"):
            P.recv_blob_stream(b, 20)  # announced 20, sent 10
    finally:
        a.close()
        b.close()


def test_stream_overrun_is_protocol_error():
    a, b = _pair()
    P.send_chunk(a, b"x" * 30)  # announced 20, sent 30
    try:
        with pytest.raises(P.ProtocolError):
            P.recv_blob_stream(b, 20)
    finally:
        a.close()
        b.close()


def test_stream_abort_frame_surfaces_to_caller():
    a, b = _pair()
    P.send_chunk(a, b"x" * 5)
    P.send_stream_end(a, abort=True, error="disk on fire", kind="server")
    try:
        buf, folded, end = P.recv_blob_stream(b, 20)
        assert end.get("abort")
        assert end.get("error") == "disk on fire"
    finally:
        a.close()
        b.close()


def test_torn_stream_mid_chunk_is_protocol_error():
    a, b = _pair()
    # frame prefix promises 100 payload bytes; only 40 arrive before EOF
    a.sendall(struct.pack(">IQ", len(b'{"c":1}'), 100) + b'{"c":1}' + b"y" * 40)
    a.close()
    try:
        with pytest.raises(P.ProtocolError, match="truncated"):
            P.recv_blob_stream(b, 100)
    finally:
        b.close()


def test_header_too_large_to_send():
    a, b = _pair()
    try:
        with pytest.raises(P.ProtocolError, match="header too large"):
            P.send_frame(a, {"k": "v" * (P.MAX_HEADER_BYTES + 1)})
    finally:
        a.close()
        b.close()
