"""Checkpoint/restore, elastic reshard, fault-tolerant driver, serve engine."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.data import make_batch
from repro.models.layers import init_params
from repro.optim import AdamWConfig
from repro.runtime import TrainDriver
from repro.train import build_param_specs, build_train_step, make_train_state

CELL = ShapeCell("t", "train", {"seq_len": 16, "global_batch": 4})


def tiny_state(arch="tinyllama-1.1b"):
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), build_param_specs(cfg, CELL), cfg.dtype)
    return cfg, make_train_state(params)


def test_checkpoint_roundtrip(tmp_path):
    cfg, state = tiny_state()
    mgr = CheckpointManager(tmp_path / "ck")
    info = mgr.save(5, state)
    assert info.nbytes > 0
    step, restored = mgr.restore()
    assert step == 5
    for a, b in zip(
        jax.tree_util.tree_leaves(state), jax.tree_util.tree_leaves(restored)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_policy(tmp_path):
    cfg, state = tiny_state()
    mgr = CheckpointManager(tmp_path / "ck", keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.full((4,), s)})
    assert [c["step"] for c in mgr.checkpoints] == [3, 4]
    step, st = mgr.restore(3)
    np.testing.assert_array_equal(np.asarray(st["x"]), 3.0)


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path / "ck", async_save=True)
    info = mgr.save(1, {"x": jnp.arange(8.0)})
    assert info.async_pending
    mgr.wait()
    step, st = mgr.restore()
    assert step == 1
    np.testing.assert_array_equal(np.asarray(st["x"]), np.arange(8.0))


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto explicit (different) shardings — elastic scaling path."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(tmp_path / "ck")
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data"))}
    step, restored = mgr.restore(shardings=shardings)
    assert restored["w"].sharding == shardings["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))


def test_train_driver_failure_recovery(tmp_path):
    cfg, state = tiny_state()
    step_fn = build_train_step(cfg, CELL, AdamWConfig(warmup_steps=1, total_steps=20))
    batches = {s: make_batch(cfg, CELL, seed=s) for s in range(12)}
    driver = TrainDriver(
        train_step=step_fn,
        make_batch=lambda s: batches[s],
        ckpt=CheckpointManager(tmp_path / "ck", keep=2),
        ckpt_every=4,
        fail_at_steps=(6,),
    )
    final_state, log = driver.run(state, 10)
    restarts = [e for e in log if e.get("event") == "restart"]
    assert len(restarts) == 1 and restarts[0]["from_step"] == 4
    steps_run = [e["step"] for e in log if "step" in e]
    assert steps_run[-1] == 10
    # steps 5,6 ran twice (recovery re-execution from the checkpoint)
    assert steps_run.count(5) == 2 and steps_run.count(6) == 2
    losses = [e["loss"] for e in log if "loss" in e]
    assert all(np.isfinite(l) for l in losses)


def test_serve_engine_prefix_reuse():
    from repro.core.registry import ModuleRegistry
    from repro.serve import ServeEngine

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = init_params(jax.random.PRNGKey(1), build_param_specs(cfg, CELL), cfg.dtype)
    registry = ModuleRegistry()
    eng = ServeEngine(cfg, params, max_len=128, chunk=8, registry=registry)
    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab, size=24).tolist()  # shared system prompt
    outs = []
    stats = []
    for i in range(4):
        user = rng.integers(0, cfg.vocab, size=8).tolist()
        toks, st = eng.generate(system + user, max_new_tokens=3)
        outs.append(toks)
        stats.append(st)
    # by the 3rd repeat of the system prompt, RISP must be skipping its chunks
    assert stats[0].chunks_skipped == 0
    assert any(s.chunks_skipped >= 2 for s in stats[2:]), [
        (s.chunks_skipped, s.n_chunks) for s in stats
    ]
    assert eng.n_snapshots >= 1
    # observed chunk modules land in the shared registry (non-executable)
    assert len(registry) >= stats[0].n_chunks
    import pytest

    with pytest.raises(NotImplementedError, match="observed"):
        next(iter(registry.values())).fn(None)


def test_serve_engine_reuse_matches_cold():
    """Generation with a reused prefix must equal cold generation."""
    from repro.core.risp import TSAR
    from repro.serve import ServeEngine

    cfg = get_config("gemma3-4b", smoke=True)  # exercises local:global decode
    params = init_params(jax.random.PRNGKey(2), build_param_specs(cfg, CELL), cfg.dtype)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, size=16).tolist()

    cold = ServeEngine(cfg, params, max_len=64, chunk=8)
    ref, st_cold = cold.generate(prompt, max_new_tokens=4)

    eng = ServeEngine(cfg, params, max_len=64, chunk=8, policy=TSAR())
    first, _ = eng.generate(prompt, max_new_tokens=4)
    again, st = eng.generate(prompt, max_new_tokens=4)
    assert st.chunks_skipped == st.n_chunks  # full-prefix hit
    assert first == ref
    assert again == ref
