"""Rule mining against the thesis' worked examples (Ch. 4.3, Fig 4.1/5.1)."""
import pytest

from repro.core import RISP, RuleMiner, Workflow
from repro.core.workflow import ModuleRef, ToolState

# Fig 4.1: four pipelines.
#   P1: D1 -> M1 M2 M3 M4
#   P2: D2 -> M2 M5 M8
#   P3: D1 -> M1 M2 M3 M6
#   P4: D1 -> M1 M2 M7 M8   (pipeline under progress)
P1 = Workflow.build("D1", ["M1", "M2", "M3", "M4"], "P1")
P2 = Workflow.build("D2", ["M2", "M5", "M8"], "P2")
P3 = Workflow.build("D1", ["M1", "M2", "M3", "M6"], "P3")
P4 = Workflow.build("D1", ["M1", "M2", "M7", "M8"], "P4")


def miner_with_all():
    m = RuleMiner()
    for wf in (P1, P2, P3, P4):
        m.add(wf)
    return m


def test_ten_distinct_rules():
    # Thesis: "From all four pipelines in Fig. 4.1, we get ten distinct
    # association rules."
    assert miner_with_all().n_distinct_rules == 10


def test_supports_match_thesis():
    m = miner_with_all()
    assert m.support(P1.prefix(1)) == 3  # D1=>M1
    assert m.support(P1.prefix(2)) == 3  # D1=>[M1,M2]
    assert m.support(P1.prefix(3)) == 2  # D1=>[M1,M2,M3]
    assert m.dataset_support("D1") == 3
    assert m.dataset_support("D2") == 1


def test_confidences_match_thesis():
    m = miner_with_all()
    assert m.rule(P1.prefix(1)).confidence == pytest.approx(1.0)
    assert m.rule(P1.prefix(2)).confidence == pytest.approx(1.0)
    assert m.rule(P1.prefix(3)).confidence == pytest.approx(2 / 3)
    # rules from P4: conf 1, 1, 1/3, 1/3
    rules = m.rules_for(P4)
    assert [pytest.approx(r.confidence) for r in rules] == [1.0, 1.0, 1 / 3, 1 / 3]


def test_risp_recommends_m2_output():
    # Thesis Ch. 4.3.3: "from the fourth pipeline, we recommend to store the
    # result obtained from module M2."
    pol = RISP()
    for wf in (P1, P2, P3):
        pol.step(wf)
    rec = pol.step(P4)
    assert rec.store, "P4 must admit a store"
    chosen = rec.store[0]
    assert chosen.depth == 2
    assert [m.module_id for m in chosen.modules] == ["M1", "M2"]


def test_adaptive_risp_state_mismatch_blocks_deeper_rule():
    # Ch. 5 example (Fig 5.1): same module sequence but M3 runs with config
    # C3' in the 4th pipeline -> the M1,M2,M3 rule must not match; the
    # recommendation stays at M2.
    c = {"M1": {"p": 1}, "M2": {"p": 2}, "M3": {"p": 3}}
    w1 = Workflow.build(
        "D1", [("M1", c["M1"]), ("M2", c["M2"]), ("M3", c["M3"]), ("M4", None)], "W1"
    )
    w3 = Workflow.build(
        "D1", [("M1", c["M1"]), ("M2", c["M2"]), ("M3", c["M3"]), ("M6", None)], "W3"
    )
    w4 = Workflow.build(
        "D1",
        [("M1", c["M1"]), ("M2", c["M2"]), ("M3", {"p": 99}), ("M6", None)],
        "W4",
    )
    pol = RISP(with_state=True)
    pol.step(w1)
    pol.step(w3)
    rec = pol.step(w4)
    chosen = rec.store[0]
    assert chosen.depth == 2, "state-mismatched M3 must not extend the rule"
    assert [m.module_id for m in chosen.modules] == ["M1", "M2"]


def test_tool_state_digest_stability():
    a = ToolState.from_config({"x": 1, "y": "z"})
    b = ToolState.from_config({"y": "z", "x": 1})
    assert a.digest == b.digest
    c = ToolState.from_config({"x": 2, "y": "z"})
    assert a.digest != c.digest


def test_prefix_keys_distinguish_state_only_when_asked():
    r1 = ModuleRef("M1", ToolState.from_config({"a": 1}))
    r2 = ModuleRef("M1", ToolState.from_config({"a": 2}))
    w1 = Workflow("D", (r1,))
    w2 = Workflow("D", (r2,))
    assert w1.prefix(1).key(False) == w2.prefix(1).key(False)
    assert w1.prefix(1).key(True) != w2.prefix(1).key(True)
