"""repro.obs unit tests: registry, tracing, logging, naming/alias contracts.

The alias-pinning test is the satellite contract of the observability PR:
every legacy stats key in ``repro/obs/naming.py`` must keep resolving to a
canonical registry metric that actually exists on the live surfaces, so a
rename in either place fails here first.
"""
from __future__ import annotations

import io
import json
import logging
import threading

import pytest

from repro.api import Client, WorkflowSpec
from repro.core import MemoryBackend
from repro.gateway import GatewayServer, TokenAuthenticator
from repro.gateway.serve import register_demo_modules
from repro.net import RemoteBackend, StoreServer
from repro.obs import tracing
from repro.obs.logging import configure_logging, get_logger
from repro.obs.metrics import (
    ALLOWED_LABELS,
    DEFAULT_BUCKETS,
    MetricsRegistry,
    lint_doc,
    lint_registry,
    merge_docs,
    render_prometheus,
)
from repro.obs.naming import ALIASES
from repro.obs.trace import build_trace, critical_path, render_trace
from repro.obs.tracing import (
    NOOP_SPAN,
    TraceContext,
    configure_tracing,
    current_traceparent,
    iter_spans,
    span,
)


@pytest.fixture()
def traced(tmp_path):
    """Enable span recording into a temp dir; always disable afterwards."""
    d = str(tmp_path / "traces")
    configure_tracing(d, "test")
    yield d
    configure_tracing(None)


@pytest.fixture(autouse=True)
def _tracing_off_by_default():
    yield
    configure_tracing(None)


# -- registry -----------------------------------------------------------------

class TestRegistry:
    def test_counter_inc_and_value(self):
        reg = MetricsRegistry()
        c = reg.counter("repro_x_things_total", "things")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5

    def test_labeled_children_are_distinct(self):
        reg = MetricsRegistry()
        fam = reg.counter("repro_x_ops_total", "ops", ("op",))
        fam.labels(op="get").inc(3)
        fam.labels(op="put").inc()
        got = {s["labels"]["op"]: s["value"] for s in fam.series()}
        assert got == {"get": 3, "put": 1}

    def test_reregistration_is_idempotent_but_mismatch_raises(self):
        reg = MetricsRegistry()
        a = reg.counter("repro_x_n_total", "n")
        assert reg.counter("repro_x_n_total", "n") is a
        with pytest.raises(ValueError):
            reg.gauge("repro_x_n_total", "n")
        with pytest.raises(ValueError):
            reg.counter("repro_x_n_total", "n", ("op",))

    def test_gauge_set_function_is_sampled_live(self):
        reg = MetricsRegistry()
        box = {"v": 1.0}
        reg.gauge("repro_x_depth", "d").unlabeled.set_function(lambda: box["v"])
        assert reg.gauge("repro_x_depth").value == 1
        box["v"] = 7.0
        assert reg.gauge("repro_x_depth").value == 7

    def test_gauge_dead_callback_reads_nan_not_raise(self):
        reg = MetricsRegistry()
        reg.gauge("repro_x_bad", "d").unlabeled.set_function(
            lambda: 1 / 0
        )
        doc = reg.to_doc()
        assert doc["repro_x_bad"]["series"][0]["value"] is None

    def test_histogram_buckets_and_snapshot(self):
        reg = MetricsRegistry()
        h = reg.histogram("repro_x_wait_seconds", "w", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 5.0):
            h.observe(v)
        snap = h.unlabeled.snapshot()
        assert snap["counts"] == [1, 1, 1] and snap["count"] == 3
        assert snap["sum"] == pytest.approx(5.55)

    def test_thread_safety_under_contention(self):
        reg = MetricsRegistry()
        c = reg.counter("repro_x_hits_total", "h")

        def work():
            for _ in range(2000):
                c.inc()

        threads = [threading.Thread(target=work) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.value == 16000


class TestMergeAndRender:
    def test_merge_adds_counters_and_histograms_elementwise(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        for reg, n in ((a, 2), (b, 5)):
            reg.counter("repro_x_n_total", "n").inc(n)
            h = reg.histogram("repro_x_t_seconds", "t")
            h.observe(0.01)
        doc = merge_docs([a.to_doc(), b.to_doc()])
        assert doc["repro_x_n_total"]["series"][0]["value"] == 7
        assert doc["repro_x_t_seconds"]["series"][0]["hist"]["count"] == 2

    def test_extra_labels_keep_per_process_gauges_apart(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.gauge("repro_x_uptime_seconds", "u").unlabeled.set(10)
        b.gauge("repro_x_uptime_seconds", "u").unlabeled.set(20)
        doc = merge_docs(
            [a.to_doc(), b.to_doc()],
            [{"shard": "h:1"}, {"shard": "h:2"}],
        )
        series = {
            s["labels"]["shard"]: s["value"]
            for s in doc["repro_x_uptime_seconds"]["series"]
        }
        assert series == {"h:1": 10, "h:2": 20}

    def test_merge_skips_none_docs(self):
        reg = MetricsRegistry()
        reg.counter("repro_x_n_total", "n").inc()
        doc = merge_docs([None, reg.to_doc(), {}], [None, {"shard": "s"}, None])
        assert doc["repro_x_n_total"]["series"][0]["value"] == 1

    def test_render_prometheus_text_shape(self):
        reg = MetricsRegistry()
        reg.counter("repro_x_ops_total", "ops", ("op",)).labels(op="get").inc(4)
        reg.histogram("repro_x_t_seconds", "t", buckets=(1.0,)).observe(0.5)
        text = render_prometheus(reg.to_doc())
        assert "# TYPE repro_x_ops_total counter" in text
        assert 'repro_x_ops_total{op="get"} 4' in text
        assert 'repro_x_t_seconds_bucket{le="1"} 1' in text
        assert 'repro_x_t_seconds_bucket{le="+Inf"} 1' in text
        assert "repro_x_t_seconds_count 1" in text


class TestLint:
    def test_clean_registry_passes(self):
        reg = MetricsRegistry()
        reg.counter("repro_store_puts_total", "puts")
        reg.gauge("repro_store_disk_bytes", "bytes", ("shard",))
        reg.histogram("repro_run_seconds", "wall")
        assert lint_registry(reg) == []

    def test_violations_are_reported(self):
        doc = {
            "bad_name": {"type": "counter", "help": "h", "labels": [], "series": []},
            "repro_x_hits": {"type": "counter", "help": "h", "labels": [], "series": []},
            "repro_x_t_ms": {"type": "histogram", "help": "h", "labels": [], "series": []},
            "repro_x_ok_total": {
                "type": "counter", "help": "", "labels": ["weird"], "series": [],
            },
        }
        problems = "\n".join(lint_doc(doc))
        assert "bad_name" in problems
        assert "must end in _total" in problems
        assert "_seconds/_bytes" in problems
        assert "weird" in problems and "missing help" in problems


# -- tracing ------------------------------------------------------------------

class TestTracing:
    def test_traceparent_roundtrip(self):
        ctx = TraceContext.new()
        back = TraceContext.from_traceparent(ctx.to_traceparent())
        assert back is not None
        assert (back.trace_id, back.span_id) == (ctx.trace_id, ctx.span_id)

    @pytest.mark.parametrize(
        "header",
        [
            None, "", "garbage", "00-short-short",
            "00-" + "0" * 32 + "-" + "1" * 16 + "-01",
            "00-" + "1" * 32 + "-" + "0" * 16 + "-01",
            "00-" + "z" * 32 + "-" + "1" * 16 + "-01",
        ],
    )
    def test_from_traceparent_rejects_malformed(self, header):
        assert TraceContext.from_traceparent(header) is None

    def test_disabled_tracing_is_noop_and_wire_silent(self):
        configure_tracing(None)
        sp = span("x")
        assert sp is NOOP_SPAN
        with sp:
            sp.set(a=1)
            sp.rename("y")
            assert current_traceparent() is None

    def test_spans_record_ndjson_and_stitch(self, traced):
        with span("outer", kind="run", workflow="wf") as outer:
            with span("inner", op="get") as inner:
                assert current_traceparent() == (
                    f"00-{inner.trace_id}-{inner.span_id}-01"
                )
        recs = list(iter_spans(traced))
        by_name = {r["name"]: r for r in recs}
        assert by_name["inner"]["parent"] == by_name["outer"]["span"]
        assert by_name["inner"]["trace"] == by_name["outer"]["trace"]
        assert by_name["outer"]["attrs"]["workflow"] == "wf"
        assert by_name["outer"]["svc"] == "test"

    def test_svc_override_per_span(self, traced):
        with span("a", svc="shard1"):
            pass
        recs = list(iter_spans(traced))
        assert recs[0]["svc"] == "shard1"

    def test_adopting_an_inbound_context(self, traced):
        ctx = TraceContext.new()
        with span("server-side", parent=ctx):
            pass
        rec = next(iter(iter_spans(traced)))
        assert rec["trace"] == ctx.trace_id and rec["parent"] == ctx.span_id

    def test_exception_marks_error(self, traced):
        with pytest.raises(RuntimeError):
            with span("boom"):
                raise RuntimeError("x")
        rec = next(iter(iter_spans(traced)))
        assert rec["attrs"]["error"] == "RuntimeError"

    def test_activate_carries_context_across_threads(self, traced):
        with span("parent") as parent:
            ctx = TraceContext(parent.trace_id, parent.span_id)

        def worker():
            with tracing.activate(ctx):
                with span("child"):
                    pass

        t = threading.Thread(target=worker)
        t.start()
        t.join()
        by_name = {r["name"]: r for r in iter_spans(traced)}
        assert by_name["child"]["parent"] == by_name["parent"]["span"]


# -- logging ------------------------------------------------------------------

class TestLogging:
    def test_human_format_stamps_trace_and_baggage(self, traced):
        buf = io.StringIO()
        configure_logging("info", stream=buf)
        log = get_logger("unit")
        with span("s") as sp:
            with tracing.bind(run_id="r-1", tenant="alice"):
                log.info("hello")
        line = buf.getvalue()
        assert sp.trace_id in line and "r-1" in line and "alice" in line
        assert "repro.unit" in line

    def test_json_lines_parse(self):
        buf = io.StringIO()
        configure_logging("info", json_lines=True, stream=buf)
        with tracing.bind(run_id="r-2"):
            get_logger("unit").warning("w %d", 7)
        doc = json.loads(buf.getvalue())
        assert doc["msg"] == "w 7" and doc["run_id"] == "r-2"
        assert doc["level"] == "warning" and doc["trace_id"] == "-"

    def test_reconfigure_replaces_handler_not_stacks(self):
        b1, b2 = io.StringIO(), io.StringIO()
        configure_logging("info", stream=b1)
        configure_logging("info", stream=b2)
        get_logger("unit").info("once")
        assert b1.getvalue() == "" and b2.getvalue().count("once") == 1

    def test_bad_level_raises(self):
        with pytest.raises(ValueError):
            configure_logging("loud")


# -- trace CLI ----------------------------------------------------------------

def _mk_span(trace, sid, parent, name, start, dur, svc="svc", **attrs):
    return {
        "trace": trace, "span": sid, "parent": parent, "name": name,
        "kind": "x", "svc": svc, "pid": 1, "start": start, "dur": dur,
        "attrs": attrs,
    }


class TestTraceCLI:
    def test_build_critical_path_and_rollup(self):
        spans = [
            _mk_span("t1", "a", None, "run", 0.0, 1.0),
            _mk_span("t1", "b", "a", "fast", 0.0, 0.2, saved_s=0.15),
            _mk_span("t1", "c", "a", "slow", 0.1, 0.9),
            _mk_span("t1", "d", "c", "leaf", 0.2, 0.7),
            _mk_span("t2", "e", None, "other", 0.0, 0.1),
        ]
        tree = build_trace(spans, "t1")
        assert set(tree["spans"]) == {"a", "b", "c", "d"}
        assert critical_path(tree) == ["a", "c", "d"]
        text = render_trace(tree)
        assert "4 spans" in text and "* run" in text and "saved" in text
        assert "0.150s saved" in text

    def test_orphans_become_roots(self):
        tree = build_trace(
            [_mk_span("t", "x", "lost-parent", "orphan", 0.0, 0.1)], "t"
        )
        assert [s["name"] for s in tree["roots"]] == ["orphan"]


# -- naming / alias contracts --------------------------------------------------

def _canonical_names(aliases=ALIASES):
    return {v.split("{", 1)[0] for v in aliases.values()}


class TestAliasContract:
    def test_stats_alias_mapping_pinned(self):
        # the mapping itself is API: a drift in either column is a break
        assert ALIASES["store_server:requests"] == "repro_store_server_requests_total"
        assert (
            ALIASES["store_server:streaming.chunks_in"]
            == "repro_store_server_stream_chunks_total{dir=in}"
        )
        assert ALIASES["store_server:uptime_s"] == "repro_store_server_uptime_seconds"
        assert ALIASES["cluster:failover_reads"] == "repro_cluster_failover_reads_total"
        assert (
            ALIASES["gateway:fabric.singleflight_waits"]
            == "repro_singleflight_waits_total"
        )
        assert ALIASES["gateway:gateway.*"] == "repro_gateway_requests_total{op=*}"
        assert (
            ALIASES["gateway:tenant.bytes_stored"]
            == "repro_tenant_stored_bytes{tenant=*}"
        )
        assert ALIASES["serve:runs"] == "repro_serve_requests_total"
        assert ALIASES["serve:units_skipped"] == "repro_serve_chunks_skipped_total"
        assert ALIASES["serve:snapshot_bytes"] == "repro_serve_snapshot_stored_bytes"

    def test_every_canonical_name_exists_on_live_surfaces(self):
        """Stand up the whole fabric (server + cluster client + gateway +
        serve surfaces) and prove each canonical metric in the alias map is
        actually registered somewhere — a silent rename breaks the map and
        fails here."""
        from repro.serve.engine import ServeMetrics
        from repro.serve.snapshots import MemorySnapshotStore

        servers = [StoreServer(MemoryBackend()).start() for _ in range(2)]
        urls = ",".join(f"127.0.0.1:{s.port}" for s in servers)
        client = Client(store_url=urls)
        register_demo_modules(client.registry)
        gw = GatewayServer(client, TokenAuthenticator({"t": "alice"}))
        # serve metrics live on whichever registry the engine mounts; bind
        # them to the client registry the way Client.serve_engine() does
        ServeMetrics(client.metrics)
        MemorySnapshotStore(registry=client.metrics)
        try:
            registered = set(client.metrics.to_doc())
            for s in servers:
                registered |= set(s.metrics.to_doc())
            missing = _canonical_names() - registered
            assert not missing, f"alias map points at unregistered metrics: {missing}"
        finally:
            client.close()
            for s in servers:
                s.stop()

    def test_store_server_stats_dict_keys_survive(self):
        server = StoreServer(MemoryBackend()).start()
        try:
            rb = RemoteBackend(f"127.0.0.1:{server.port}")
            rb.write_blob("k", "data", b"x" * 10)
            assert rb.read_blob("k", "data") == b"x" * 10
            rb.close()
            stats = server.stats()
            assert stats["requests"] >= 2
            assert "ops" in stats and stats["ops"].get("read_blob", 0) >= 1
            for key in (
                "streaming", "active_leases", "connections",
                "subscribers", "catalog_records", "uptime_s",
            ):
                assert key in stats, key
        finally:
            server.stop()

    def test_gateway_counts_dict_reconstructs_from_registry(self):
        client = Client()
        gw = GatewayServer(client, TokenAuthenticator({"t": "alice"}))
        try:
            gw._count("accepted")
            gw._count("accepted")
            gw._count("http_202")
            counts = gw.counts()
            assert counts["accepted"] == 2 and counts["http_202"] == 1
            reqs = {
                s["labels"]["op"]: s["value"]
                for s in gw._m_requests.series()
            }
            assert reqs["accepted"] == 2
        finally:
            client.close()

    def test_metric_naming_lint_on_live_registries(self):
        """Every registry the fabric creates must satisfy the naming scheme
        (repro_ prefix, _total counters, unit-suffixed histograms, label
        vocabulary) — the lint that keeps 'one naming scheme' true."""
        server = StoreServer(MemoryBackend()).start()
        client = Client(store_url=f"127.0.0.1:{server.port}")
        register_demo_modules(client.registry)
        try:
            spec = WorkflowSpec.from_steps("nums", ["normalize", "stats"])
            client.run(spec, [1.0, 2.0, 3.0])
            for reg in (client.metrics, server.metrics):
                assert lint_registry(reg) == []
            # the merged fabric doc lints clean too (merge adds only
            # vocabulary labels such as shard)
            assert lint_doc(client.metrics_doc()) == []
        finally:
            client.close()
            server.stop()

    def test_allowed_labels_vocabulary_pinned(self):
        assert ALLOWED_LABELS == {
            "op", "shard", "tenant", "namespace", "dir",
            "status", "source", "event", "policy",
        }
        assert len(DEFAULT_BUCKETS) == 14
