"""Dry-run machinery tests: sharding resolution + subprocess smoke compile."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.launch.analysis import parse_collectives, scan_correct


def test_resolve_spec_divisibility_fallbacks():
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import DEFAULT_RULES, resolve_spec, rules_for

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    rules = dict(DEFAULT_RULES)
    # heads divisible -> model
    assert resolve_spec(("embed", "heads", "head_dim"), (4096, 32, 128), FakeMesh(), rules) == P(None, "model")
    # heads not divisible -> replicated (no within-head fallback)
    assert resolve_spec(("embed", "heads", "head_dim"), (2560, 8, 256), FakeMesh(), rules) == P()
    # qwen2: experts 60 fail, expert_ff takes model
    assert resolve_spec(("experts", "embed", "expert_ff"), (60, 2048, 1408), FakeMesh(), rules) == P(None, None, "model")
    # deepseek-v2 override: experts -> data, expert_ff -> model
    ds = get_config("deepseek-v2-236b")
    r2 = rules_for(ds)
    assert resolve_spec(("experts", "embed", "expert_ff"), (160, 5120, 1536), FakeMesh(), r2) == P("data", None, "model")
    # batch over (pod, data) jointly
    assert resolve_spec(("batch", "seq"), (256, 4096), FakeMesh(), rules) == P(("pod", "data"))
    # batch=1 cannot shard; kv_seq picks data
    assert resolve_spec(("batch", "kv_seq"), (1, 524288), FakeMesh(), rules) == P(None, "data")


def test_parse_collectives_counts_and_bytes():
    hlo = """
  %p0 = bf16[64,128]{1,0} parameter(0)
  %ag = bf16[64,2048]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[32,32]{1,0} all-reduce(%x), to_apply=%sum
  %rs = f32[4,32]{1,0} reduce-scatter(%ar), dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(%p1)
"""
    stats = parse_collectives(hlo)
    assert stats.count_by_kind == {
        "all-gather": 1, "all-reduce": 1, "reduce-scatter": 1, "collective-permute": 1
    }
    assert stats.bytes_by_kind["all-reduce"] == 2 * 32 * 32 * 4
    # all-gather: result - operand
    assert stats.bytes_by_kind["all-gather"] == 64 * 2048 * 2 - 64 * 128 * 2
    assert stats.total_bytes > 0


def test_scan_correct_linearity():
    # fixed=10, body=5: q1=15, q2=20 -> L=30 gives 10+150
    assert scan_correct(15, 20, 30) == 10 + 30 * 5


@pytest.mark.slow
def test_dryrun_subprocess_smoke(tmp_path):
    """End-to-end dry-run CLI on the 8-device smoke mesh (subprocess: the
    forced device count must be set before jax initializes)."""
    repo = Path(__file__).resolve().parents[1]
    out = tmp_path / "dryrun"
    res = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "tinyllama-1.1b", "--shape", "train_4k",
            "--mesh", "pod", "--smoke-mesh", "--remat", "full",
            "--out", str(out),
        ],
        cwd=repo,
        env={"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert res.returncode == 0, res.stdout + res.stderr
    recs = [json.loads(p.read_text()) for p in out.glob("*.json")]
    assert len(recs) == 1 and recs[0]["status"] == "ok"
    r = recs[0]["roofline"]
    assert r["flops_per_chip"] > 0 and r["hbm_bytes_per_chip"] > 0
    assert recs[0]["memory"]["peak_hbm_bytes"] > 0
