"""repro.net: remote store protocol, caching, leases, and failure modes.

The failure-mode tests are the satellite contract of ISSUE 4: server restart
mid-run (client reconnects, digests re-verify), truncated frames (clean
retry/error, no wedged connections), and evicted-while-planned recompute
fallback through the remote path.
"""
import socket
import struct
import threading
import time

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import Client
from repro.core import IntermediateStore, LocalFSBackend, MemoryBackend, TSAR
from repro.net import (
    CachingBackend,
    DistributedSingleFlight,
    IntegrityError,
    RemoteBackend,
    RemoteStoreError,
    StoreServer,
)
from repro.net.protocol import parse_url, recv_frame, send_frame


@pytest.fixture()
def server(tmp_path):
    srv = StoreServer(LocalFSBackend(tmp_path / "pool")).start()
    yield srv
    srv.stop()


def _fast_backend(url, **kw):
    kw.setdefault("retries", 2)
    kw.setdefault("retry_backoff_s", 0.01)
    return RemoteBackend(url, **kw)


# -- protocol ----------------------------------------------------------------
def test_parse_url():
    assert parse_url("tcp://h:123") == ("h", 123)
    assert parse_url("h:123") == ("h", 123)
    assert parse_url("tcp://10.0.0.1:7077") == ("10.0.0.1", 7077)
    assert parse_url("myhost")[0] == "myhost"
    with pytest.raises(ValueError):
        parse_url("tcp://h:notaport")
    with pytest.raises(ValueError):
        parse_url("tcp://h:1/path")


def test_frame_roundtrip():
    a, b = socket.socketpair()
    try:
        send_frame(a, {"op": "x", "n": 3}, b"payload")
        header, payload = recv_frame(b)
        assert header == {"op": "x", "n": 3}
        assert payload == b"payload"
    finally:
        a.close()
        b.close()


# -- backend contract over the wire ------------------------------------------
def test_remote_backend_contract(server):
    rb = _fast_backend(server.url)
    try:
        assert rb.ping()
        assert not rb.exists("k")
        rb.write_blob("k", "manifest.json", b"{}")
        rb.write_blob("k", "leaf0.bin", b"\x01" * 100)
        assert rb.exists("k")
        assert rb.read_blob("k", "leaf0.bin") == b"\x01" * 100
        assert rb.nbytes("k") == 102
        with pytest.raises(KeyError):
            rb.read_blob("k", "missing.bin")
        rb.write_meta("index.json", '{"a": 1}')
        assert rb.read_meta("index.json") == '{"a": 1}'
        assert rb.read_meta("nope.json") is None
        rb.delete("k")
        assert not rb.exists("k")
        rb.delete("k")  # idempotent
    finally:
        rb.close()


def test_store_roundtrip_and_cross_client_adoption(server):
    rb1, rb2 = _fast_backend(server.url), _fast_backend(server.url)
    try:
        s1 = IntermediateStore(backend=CachingBackend(rb1))
        s2 = IntermediateStore(backend=CachingBackend(rb2))
        value = {"a": jnp.arange(12.0).reshape(3, 4), "b": np.ones((5,))}
        s1.put("key1", value, compute_seconds=0.2)
        # s2 never saw the put; it adopts the record from the shared pool
        assert s2.has("key1")
        out = s2.get("key1")
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(value["a"]))
        np.testing.assert_array_equal(np.asarray(out["b"]), value["b"])
    finally:
        rb1.close()
        rb2.close()


def test_caching_backend_serves_repeats_locally(server):
    rb = _fast_backend(server.url)
    try:
        cache = CachingBackend(rb)
        store = IntermediateStore(backend=cache)
        store.put("k", jnp.arange(64.0))
        store.get("k")  # populates any blobs not cached by the put
        before = rb.server_stats()["ops"].get("read_blob", 0)
        for _ in range(3):
            np.testing.assert_array_equal(np.asarray(store.get("k")), np.arange(64.0))
        after = rb.server_stats()["ops"].get("read_blob", 0)
        assert after == before, "cached re-reads must not hit the server"
        assert cache.hits > 0
    finally:
        rb.close()


def test_cache_bounded_lru():
    inner = MemoryBackend()
    cache = CachingBackend(inner, capacity_bytes=1000)
    for i in range(10):
        cache.write_blob(f"k{i}", "b", bytes([i]) * 300)
    assert cache.cached_bytes <= 1000
    # oldest entries were dropped, but reads still succeed via the backend
    assert cache.read_blob("k0", "b") == b"\x00" * 300


class _GatedBackend(MemoryBackend):
    """Inner backend whose fetch blocks until the test releases it — lets a
    test interleave an eviction event with an in-flight miss."""

    def __init__(self):
        super().__init__()
        self.entered = threading.Event()
        self.gate = threading.Event()

    def read_blob(self, key, name):
        self.entered.set()
        assert self.gate.wait(5), "test never released the gated fetch"
        return super().read_blob(key, name)


def test_cache_miss_insert_fenced_by_invalidation():
    """Regression (ISSUE 5 satellite): an eviction event landing between the
    inner fetch and the re-insert must not resurrect the dead blob."""
    inner = _GatedBackend()
    inner._objects["k"] = {"b": b"stale-bytes"}
    cache = CachingBackend(inner)
    out = {}

    def miss():
        out["data"] = cache.read_blob("k", "b")

    t = threading.Thread(target=miss)
    t.start()
    assert inner.entered.wait(5)
    # the event thread delivers the eviction while the fetch is in flight
    cache.invalidate("k")
    inner.gate.set()
    t.join(timeout=5)
    assert out["data"] == b"stale-bytes"  # the caller still gets its bytes…
    assert cache.stale_inserts_dropped == 1  # …but the corpse stays buried
    assert cache.cached_bytes == 0
    # the fence retires with the fetch: bookkeeping stays bounded by
    # in-flight concurrency, not by eviction-event volume
    assert not cache._gen and not cache._inflight
    # and a later miss (no interleaving) caches normally again
    inner.entered.clear()
    inner.gate.set()
    cache.read_blob("k", "b")
    assert cache.cached_bytes == len(b"stale-bytes")


def test_cache_invalidation_of_uncached_keys_leaves_no_state():
    """A busy fleet-wide eviction stream of keys this client never cached
    must not grow any cache bookkeeping."""
    cache = CachingBackend(MemoryBackend())
    for i in range(500):
        cache.invalidate(f"never-seen-{i}")
    assert not cache._gen and not cache._inflight and not cache._names
    assert cache.cached_bytes == 0


class _GatedWriteBackend(MemoryBackend):
    """Inner backend whose write blocks until released (write-path twin of
    :class:`_GatedBackend`)."""

    def __init__(self):
        super().__init__()
        self.entered = threading.Event()
        self.gate = threading.Event()

    def write_blob(self, key, name, data):
        self.entered.set()
        assert self.gate.wait(5), "test never released the gated write"
        return super().write_blob(key, name, data)


def test_cache_write_insert_fenced_by_invalidation():
    """Same fence on the write-through path: an eviction event landing
    during the inner write must beat the subsequent cache insert."""
    inner = _GatedWriteBackend()
    cache = CachingBackend(inner)
    t = threading.Thread(target=cache.write_blob, args=("k", "b", b"v1"))
    t.start()
    assert inner.entered.wait(5)
    cache.invalidate("k")  # event for the key's previous incarnation
    inner.gate.set()
    t.join(timeout=5)
    assert cache.stale_inserts_dropped == 1
    assert cache.cached_bytes == 0  # conservative: a missed fill, never stale
    assert inner.read_blob("k", "b") == b"v1"  # the write itself landed
    # a write after the dust settles caches normally
    cache.write_blob("k", "b", b"v2")
    assert cache.cached_bytes == 2


def test_cache_purge_uses_index_not_full_scan():
    """Regression (ISSUE 5 satellite): invalidation cost is O(blobs-of-key),
    not O(whole cache) — asserted via the examined-entries counter."""
    inner = MemoryBackend()
    cache = CachingBackend(inner)
    n_keys, blobs_per_key = 200, 2
    for i in range(n_keys):
        for j in range(blobs_per_key):
            cache.write_blob(f"k{i}", f"b{j}", b"x" * 8)
    assert cache.purge_examined == 0  # inserts never scan
    cache.invalidate("k7")
    assert cache.purge_examined == blobs_per_key, (
        f"invalidate examined {cache.purge_examined} entries; a full scan "
        f"would touch {n_keys * blobs_per_key}"
    )
    assert cache.read_blob("k7", "b0") == b"x" * 8  # refetch works
    # invalidating an uncached key examines nothing
    before = cache.purge_examined
    cache.invalidate("never-cached")
    assert cache.purge_examined == before


def test_eviction_event_stream(server):
    rb1, rb2 = _fast_backend(server.url), _fast_backend(server.url)
    try:
        s2_cache = CachingBackend(rb2)
        s2 = IntermediateStore(backend=s2_cache)
        seen = []

        def on_event(event, key):
            if event == "evicted":
                s2_cache.invalidate(key)
                s2.on_external_evict(key)
                seen.append(key)

        rb2.add_event_listener(on_event)
        deadline = time.time() + 2
        while rb2.server_stats()["subscribers"] == 0 and time.time() < deadline:
            time.sleep(0.01)

        s1 = IntermediateStore(backend=CachingBackend(rb1))
        s1.put("shared", jnp.ones((8,)))
        assert s2.has("shared")
        s1.evict("shared")  # broadcasts to rb2 (not back to rb1)
        deadline = time.time() + 2
        while not seen and time.time() < deadline:
            time.sleep(0.01)
        assert seen == ["shared"]
        assert "shared" not in s2.records
        assert not s2.has("shared")
    finally:
        rb1.close()
        rb2.close()


# -- failure modes (satellite) ------------------------------------------------
def test_server_restart_mid_run_reconnects(tmp_path):
    srv = StoreServer(LocalFSBackend(tmp_path / "pool")).start()
    port = srv.port
    rb = RemoteBackend(srv.url, retries=6, retry_backoff_s=0.05)
    try:
        store = IntermediateStore(backend=CachingBackend(rb, capacity_bytes=0))
        store.put("k", jnp.arange(32.0))
        srv.stop()
        # a dead server mid-run: requests fail over to redial with backoff
        srv = StoreServer(
            LocalFSBackend(tmp_path / "pool"), port=port
        ).start()
        np.testing.assert_array_equal(np.asarray(store.get("k")), np.arange(32.0))
        assert rb.reconnects > 0
    finally:
        rb.close()
        srv.stop()


def test_truncated_request_does_not_wedge_server(server):
    # a client that dies mid-frame must only kill its own connection
    raw = socket.create_connection((server.host, server.port))
    raw.sendall(struct.pack(">IQ", 500, 0) + b'{"op": "ping"')  # header cut short
    raw.close()
    rb = _fast_backend(server.url)
    try:
        assert rb.ping()  # the server still serves everyone else
    finally:
        rb.close()


def _one_shot_bad_server(responses):
    """Accepts connections; for each, reads one request and sends the next
    scripted raw response (or closes early on b"")."""
    ls = socket.socket()
    ls.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    ls.bind(("127.0.0.1", 0))
    ls.listen(8)

    def serve():
        for resp in responses:
            try:
                conn, _ = ls.accept()
                recv_frame(conn)
                if resp:
                    conn.sendall(resp)
                conn.close()
            except OSError:
                return

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    return ls, ls.getsockname()[1]


def test_truncated_response_retries_then_errors():
    # frame promises 100 payload bytes, delivers 10, closes: truncated
    head = b'{"ok":true}'
    bad = struct.pack(">IQ", len(head), 100) + head + b"x" * 10
    ls, port = _one_shot_bad_server([bad, bad, bad])
    rb = RemoteBackend(f"tcp://127.0.0.1:{port}", retries=2, retry_backoff_s=0.01)
    try:
        with pytest.raises(RemoteStoreError, match="unreachable after"):
            rb.ping()
    finally:
        rb.close()
        ls.close()


def test_digest_mismatch_raises_integrity_error():
    import json

    def resp_with_bad_digest():
        head = json.dumps({"ok": True, "digest": "0" * 64}).encode()
        return struct.pack(">IQ", len(head), 4) + head + b"evil"

    # retries=1: the fake server closes each conn after responding, so the
    # verification re-fetch needs one redial before it can see bad bytes twice
    ls, port = _one_shot_bad_server([resp_with_bad_digest()] * 4)
    rb = RemoteBackend(f"tcp://127.0.0.1:{port}", retries=1, retry_backoff_s=0.01)
    try:
        with pytest.raises(IntegrityError):
            rb.read_blob("k", "b")
    finally:
        rb.close()
        ls.close()


def test_evicted_while_planned_recomputes_through_remote(server):
    calls = {"n": 0}
    with Client(store_url=server.url, policy="TSAR") as client:
        @client.module("count")
        def count(x):
            calls["n"] += 1
            return x + 1

        r1 = client.run_steps("ds", jnp.arange(4.0), ["count"], "w1")
        assert calls["n"] == 1
        # wipe the artifact behind the client's back — directly on the
        # server's backend, so no eviction event reaches the client and its
        # policy still *plans* a load that will vanish
        key = r1.stored_keys[0]
        server.backend.delete(key)
        assert key in client.policy.stored
        r2 = client.run_steps("ds", jnp.arange(4.0), ["count"], "w2")
        assert calls["n"] == 2  # recompute fallback, not a crash
        np.testing.assert_array_equal(np.asarray(r2.output), np.arange(4.0) + 1)


# -- distributed single-flight -------------------------------------------------
def test_lease_auto_release_on_disconnect(server):
    rb1, rb2 = _fast_backend(server.url), _fast_backend(server.url)
    g1 = rb1.lease_acquire("k", wait=False)
    assert g1.granted
    rb1.close()  # leader dies: server auto-releases with stored=False
    g2 = rb2.lease_acquire("k", wait=True, timeout_s=5)
    try:
        # either we became the leader outright, or we observed the
        # auto-release (stored=False) and may re-contend
        assert g2.granted or not g2.stored
    finally:
        rb2.close()


def test_distributed_singleflight_exactly_once_across_clients(server):
    """The acceptance shape: concurrent cold-prefix requests from distinct
    clients (each its own lease connection) compute exactly once; followers
    load the leader's stored artifact."""
    computes = []
    lock = threading.Lock()

    def make_client():
        rb = _fast_backend(server.url)
        store = IntermediateStore(backend=CachingBackend(rb))
        sf = DistributedSingleFlight(rb, stored_fn=store.has, lease_timeout_s=10)
        return rb, store, sf

    clients = [make_client() for _ in range(4)]
    barrier = threading.Barrier(4)
    results = []

    def run(i):
        rb, store, sf = clients[i]

        def produce():
            if store.has("cold-key"):
                return "loaded", np.asarray(store.get("cold-key"))
            with lock:
                computes.append(i)
            time.sleep(0.1)  # a real compute: others must pile onto the lease
            value = np.arange(16.0)
            store.put("cold-key", value)
            return "computed", value

        barrier.wait()
        (source, value), leader = sf.run("cold-key", produce)
        results.append((i, source, leader, value))

    threads = [threading.Thread(target=run, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        assert len(computes) == 1, f"expected exactly one compute, got {computes}"
        assert len(results) == 4
        for _, source, leader, value in results:
            np.testing.assert_array_equal(value, np.arange(16.0))
        assert sum(1 for r in results if r[2]) == 1  # one fleet-wide leader
    finally:
        for rb, _, _ in clients:
            rb.close()


def test_distributed_singleflight_not_stored_falls_back_to_compute(server):
    """When the leader's artifact is rejected by admission (stored=False),
    followers re-contend and compute instead of loading thin air."""
    n_calls = []
    lock = threading.Lock()

    def make(i):
        rb = _fast_backend(server.url)
        sf = DistributedSingleFlight(rb, stored_fn=None, lease_timeout_s=5)

        def fn():
            with lock:
                n_calls.append(i)
            time.sleep(0.05)
            return i

        return rb, sf, fn

    pairs = [make(i) for i in range(3)]
    barrier = threading.Barrier(3)
    out = []

    def run(i):
        rb, sf, fn = pairs[i]
        barrier.wait()
        value, leader = sf.run("never-stored", fn)
        out.append((i, value, leader))

    threads = [threading.Thread(target=run, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        # nothing was stored, so every caller eventually computed its own
        assert len(n_calls) == 3
        for i, value, _ in out:
            assert value == i
    finally:
        for rb, _, _ in pairs:
            rb.close()


def test_client_store_url_end_to_end(server):
    """Two api.Clients on one server: artifacts stored by one are reused by
    the other (the cross-process reuse the tentpole exists for)."""
    def mk(cid):
        c = Client(store_url=server.url, policy="TSAR", client_id=cid)
        c.register_fn("double", lambda x: x * 2)
        c.register_fn("inc", lambda x, by=1: x + by, by=1)
        return c

    a, b = mk("a"), mk("b")
    try:
        data = jnp.arange(32.0)
        ra = a.run_steps("ds", data, ["double", "inc"], "wa")
        assert ra.n_skipped == 0
        rb_ = b.run_steps("ds", data, ["double", "inc"], "wb")
        assert rb_.n_skipped >= 1, "second client must reuse the first's prefix"
        np.testing.assert_array_equal(np.asarray(ra.output), np.asarray(rb_.output))
    finally:
        a.close()
        b.close()


def test_path_traversal_names_rejected(server):
    rb = _fast_backend(server.url)
    try:
        for name in ("../../evil", "..", "a/b", "c\\d", ""):
            with pytest.raises(RemoteStoreError, match="illegal blob name"):
                rb.write_blob("k", name, b"x")
            with pytest.raises(RemoteStoreError, match="illegal blob name"):
                rb.read_blob("k", name)
            with pytest.raises(RemoteStoreError, match="illegal blob name"):
                rb.write_meta(name, "x")
        # nothing escaped the pool root
        import pathlib

        root = pathlib.Path(server.backend.root)
        assert not (root.parent / "evil").exists()
    finally:
        rb.close()


def test_held_lease_survives_pool_churn(server):
    """The socket carrying a granted lease is pinned: churning the pool with
    other requests (checkouts, overflow closes) must not auto-release it."""
    rb = _fast_backend(server.url, max_pool=1)
    rb2 = _fast_backend(server.url)
    try:
        g = rb.lease_acquire("pinned", wait=False)
        assert g.granted
        # hammer the pool: every request cycles sockets through checkin,
        # overflowing max_pool=1 so extras get closed
        import threading as _t

        def churn():
            for _ in range(10):
                rb.exists("nope")

        ts = [_t.Thread(target=churn) for _ in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        # the lease must still be held: a non-waiting acquire is denied
        assert not rb2.lease_acquire("pinned", wait=False).granted
        rb.lease_release("pinned", g.token, stored=False)
        assert rb2.lease_acquire("pinned", wait=False).granted
    finally:
        rb.close()
        rb2.close()
