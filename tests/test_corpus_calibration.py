"""Corpus calibration: our replay on the Galaxy-calibrated corpus must stay
in the thesis' reported regime (guards the EXPERIMENTS §1/§2 tables)."""
from repro.core import evaluate_all, galaxy_ch4_corpus, galaxy_ch5_corpus


def test_ch4_calibration_regime():
    reports = evaluate_all(galaxy_ch4_corpus())
    pt, tsar, tspar, tsfr = (
        reports["PT"], reports["TSAR"], reports["TSPAR"], reports["TSFR"]
    )
    # headline: PT reuse likeliness ~52% (paper 51.97) with tiny storage
    assert 45 <= pt.lr <= 60
    assert pt.n_stored < 150  # paper: 49
    assert pt.pisrs < 2.5  # paper: 0.68%
    # orderings the thesis reports
    assert tsar.lr > pt.lr >= tspar.lr > tsfr.lr
    assert pt.psrr > tspar.psrr > tsfr.psrr > tsar.psrr
    assert pt.frsr > tspar.frsr > tsfr.frsr > tsar.frsr
    assert tsfr.n_stored > 400  # paper: 457 (~10% duplicate reruns)


def test_ch5_adaptive_regime():
    reports = evaluate_all(galaxy_ch5_corpus(), with_state=True)
    pt = reports["PT"]
    assert 35 <= pt.lr <= 60  # paper ~40
    assert pt.n_stored < 200  # paper: 61
    # tool states reduce reuse relative to the state-blind ch4 setting
    pt4 = evaluate_all(galaxy_ch4_corpus())["PT"]
    assert pt.lr <= pt4.lr + 1.0
