"""IntermediateStore round-trips + executor prefix skipping + error recovery."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import HealthCheck, given, settings, st

from repro.core import (
    IntermediateStore,
    ModuleSpec,
    RISP,
    TSAR,
    WorkflowError,
    WorkflowExecutor,
)


@pytest.fixture()
def store(tmp_path):
    return IntermediateStore(tmp_path / "store")


def test_store_roundtrip_pytree(store):
    value = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "b": [np.int32(7), jnp.ones((2, 2), jnp.bfloat16)],
    }
    store.put("k1", value)
    assert store.has("k1")
    out = store.get("k1")
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(value["a"]))
    assert out["b"][1].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["b"][1]), np.ones((2, 2)))


def test_store_dedup_and_delete(store):
    v = jnp.ones((8,))
    r1 = store.put("k", v)
    r2 = store.put("k", v)
    assert not r1.deduped and r2.deduped
    store.delete("k")
    assert not store.has("k")
    with pytest.raises(KeyError):
        store.get("k")


def test_store_index_survives_reopen(tmp_path):
    s1 = IntermediateStore(tmp_path / "s")
    s1.put("k", jnp.arange(4))
    s2 = IntermediateStore(tmp_path / "s")
    assert s2.has("k")
    np.testing.assert_array_equal(np.asarray(s2.get("k")), np.arange(4))


@settings(max_examples=10, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    shape=st.lists(st.integers(1, 5), min_size=0, max_size=3),
    dtype=st.sampled_from(["float32", "int32", "float16", "bfloat16"]),
    seed=st.integers(0, 100),
)
def test_store_roundtrip_property(tmp_path_factory, shape, dtype, seed):
    store = IntermediateStore(tmp_path_factory.mktemp("s"))
    rng = np.random.default_rng(seed)
    arr = jnp.asarray(rng.normal(size=shape)).astype(dtype)
    store.put("k", arr)
    out = store.get("k")
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(
        np.asarray(out, dtype=np.float64), np.asarray(arr, dtype=np.float64)
    )


def make_executor(store, policy=None, **kw):
    ex = WorkflowExecutor(store=store, policy=policy or RISP(), **kw)
    calls = {"double": 0, "inc": 0, "square": 0, "fail": 0}

    def count(name, fn):
        def wrapped(x, **params):
            calls[name] += 1
            return fn(x, **params)

        return wrapped

    ex.register(ModuleSpec("double", count("double", lambda x: x * 2)))
    ex.register(ModuleSpec("inc", count("inc", lambda x, by=1: x + by), {"by": 1}))
    ex.register(ModuleSpec("square", count("square", lambda x: x * x)))

    def failing(x, n_ok=0):
        calls["fail"] += 1
        raise RuntimeError("boom")

    ex.register(ModuleSpec("fail", failing))
    return ex, calls


def test_executor_prefix_skip(store):
    ex, calls = make_executor(store, policy=TSAR())
    data = jnp.arange(6.0)
    r1 = ex.run("ds", data, ["double", "inc", "square"], "w1")
    assert r1.n_skipped == 0 and calls["double"] == 1
    # same prefix, different tail: double+inc must be skipped
    r2 = ex.run("ds", data, ["double", "inc", "inc"], "w2")
    assert r2.n_skipped == 2
    assert calls["double"] == 1 and calls["inc"] == 2  # only the tail ran
    np.testing.assert_allclose(
        np.asarray(r2.output), np.asarray((data * 2 + 1) + 1)
    )


def test_executor_cache_equivalence(store, tmp_path):
    """Cached execution must produce bit-identical results to cold execution."""
    ex, _ = make_executor(store, policy=TSAR())
    data = jnp.linspace(-2, 2, 16)
    steps = ["double", ("inc", {"by": 3}), "square"]
    cold = ex.run("ds", data, steps, "w1").output
    warm = ex.run("ds", data, steps, "w2").output  # full-prefix cache hit
    np.testing.assert_array_equal(np.asarray(cold), np.asarray(warm))

    # and equals a store-free executor
    ex2, _ = make_executor(IntermediateStore(tmp_path / "s2"))
    ref = ex2.run("ds", data, steps, "w3").output
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(cold))


def test_executor_tool_state_distinguishes(store):
    ex, calls = make_executor(store, policy=TSAR(with_state=True))
    data = jnp.ones((4,))
    ex.run("ds", data, [("inc", {"by": 1})], "w1")
    r = ex.run("ds", data, [("inc", {"by": 2})], "w2")
    assert r.n_skipped == 0  # different tool state: no reuse
    np.testing.assert_allclose(np.asarray(r.output), 3.0)
    r3 = ex.run("ds", data, [("inc", {"by": 2})], "w3")
    assert r3.n_skipped == 1  # same state now cached


def test_executor_error_recovery(store):
    ex, calls = make_executor(store, policy=RISP())
    data = jnp.arange(4.0)
    with pytest.raises(WorkflowError) as ei:
        ex.run("ds", data, ["double", "inc", "fail"], "w1")
    assert ei.value.failed_at == 2
    # recovery point [double, inc] was persisted: a fixed rerun skips to it
    r = ex.run("ds", data, ["double", "inc", "square"], "w2")
    assert r.n_skipped == 2
    assert calls["double"] == 1 and calls["inc"] == 1
    np.testing.assert_allclose(np.asarray(r.output), np.asarray((data * 2 + 1) ** 2))


def test_executor_eviction_falls_back(store):
    ex, calls = make_executor(store, policy=TSAR())
    data = jnp.arange(4.0)
    ex.run("ds", data, ["double", "inc"], "w1")
    # evict the deepest artifact; executor must fall back to the shorter prefix
    deep_key = ex.make_workflow("ds", ["double", "inc"]).prefix(2).key(False)
    store.delete(deep_key)
    r = ex.run("ds", data, ["double", "inc"], "w2")
    assert r.n_skipped == 1
    np.testing.assert_allclose(np.asarray(r.output), np.asarray(data * 2 + 1))


def test_cost_admission_skips_cheap_modules(store):
    # with t1_gt_t2 admission, a microsecond module whose output is large
    # should not be stored (load would cost more than recompute)
    pol = TSAR()
    ex, _ = make_executor(store, policy=pol, admission="t1_gt_t2")
    big = jnp.ones((2048, 2048))  # 16 MB, instant to "compute"
    r = ex.run("ds", big, ["double"], "w1")
    # either stored or not depending on measured throughput; must not crash
    assert isinstance(r.stored_keys, list)
