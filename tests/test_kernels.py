"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import HealthCheck, given, settings, st

from repro.kernels.decode_attention import decode_attention, decode_attention_ref
from repro.kernels.embedding_bag import embedding_bag, embedding_bag_ref
from repro.kernels.flash_attention import attention_ref, flash_attention

TOLS = {"float32": 2e-5, "bfloat16": 2e-2}


def _tol(dtype):
    return TOLS[str(dtype)]


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
FLASH_CASES = [
    # (B, Sq, Sk, Hq, Hkv, d, causal, window, blk)
    (1, 64, 64, 1, 1, 32, True, None, 32),
    (2, 128, 128, 4, 2, 64, True, None, 64),
    (2, 96, 96, 8, 8, 32, False, None, 32),  # non-multiple of block -> padding
    (1, 256, 256, 4, 1, 64, True, 64, 64),  # MQA + sliding window
    (2, 128, 128, 4, 4, 128, True, None, 128),
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_flash_attention_matches_ref(case, dtype):
    B, Sq, Sk, Hq, Hkv, d, causal, window, blk = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Sk, Hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Sk, Hkv, d)), dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=blk, block_k=blk,
        interpret=True,
    )
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@settings(max_examples=8, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    s=st.integers(2, 5).map(lambda e: 2**e * 8),  # 32..256
    hq_groups=st.sampled_from([(2, 1), (4, 4), (8, 2)]),
    d=st.sampled_from([32, 64]),
    causal=st.booleans(),
)
def test_flash_attention_property(s, hq_groups, d, causal):
    hq, hkv = hq_groups
    rng = np.random.default_rng(s * d + hq)
    q = jnp.asarray(rng.normal(size=(1, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, s, hkv, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------
DECODE_CASES = [
    # (B, T, Hq, Hkv, d, blk)
    (2, 256, 4, 2, 64, 128),
    (4, 512, 8, 8, 32, 256),
    (1, 384, 4, 1, 128, 128),  # MQA, T non-multiple handled by padding
    (3, 200, 2, 2, 64, 128),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_decode_attention_matches_ref(case, dtype):
    B, T, Hq, Hkv, d, blk = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, Hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, d)), dtype)
    lens = jnp.asarray(rng.integers(1, T + 1, size=(B,)), jnp.int32)
    out = decode_attention(q, k, v, lens, block_k=blk, interpret=True)
    ref = decode_attention_ref(
        q.reshape(B, Hkv, Hq // Hkv, d), k, v, lens
    ).reshape(B, Hq, d)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_decode_attention_matches_model_path():
    """Kernel agrees with the model's XLA decode_attention (S=1)."""
    from repro.models.attention import decode_attention as xla_decode

    B, T, Hq, Hkv, d = 2, 128, 4, 2, 32
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, d)), jnp.float32)
    q_start = jnp.asarray([40, 100], jnp.int32)
    ref = xla_decode(q, k, v, q_start)  # attends kpos <= q_start
    out = decode_attention(q[:, 0], k, v, q_start + 1, block_k=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref[:, 0]), atol=2e-5, rtol=2e-5
    )


# --------------------------------------------------------------------------
# embedding bag
# --------------------------------------------------------------------------
BAG_CASES = [
    # (V, dim, n_bags, bag_size, combiner)
    (1000, 16, 8, 4, "sum"),
    (5000, 128, 16, 26, "sum"),  # dcn-v2-like field lookup
    (300, 10, 32, 39, "sum"),  # fm-like
    (256, 50, 4, 50, "mean"),  # sasrec-like history pooling
]


@pytest.mark.parametrize("case", BAG_CASES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_embedding_bag_matches_ref(case, dtype):
    V, dim, n_bags, bag, combiner = case
    rng = np.random.default_rng(hash(case) % 2**31)
    table = jnp.asarray(rng.normal(size=(V, dim)), dtype)
    ids = jnp.asarray(rng.integers(0, V, size=(n_bags, bag)), jnp.int32)
    w = jnp.asarray(rng.random((n_bags, bag)), jnp.float32)
    out = embedding_bag(table, ids, w, combiner=combiner, interpret=True)
    ref = embedding_bag_ref(table, ids, w, combiner=combiner)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


@settings(max_examples=8, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    v=st.integers(10, 2000),
    dim=st.sampled_from([8, 16, 64, 130]),
    n_bags=st.integers(1, 16),
    bag=st.integers(1, 12),
)
def test_embedding_bag_property(v, dim, n_bags, bag):
    rng = np.random.default_rng(v + dim + n_bags)
    table = jnp.asarray(rng.normal(size=(v, dim)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, v, size=(n_bags, bag)), jnp.int32)
    out = embedding_bag(table, ids, interpret=True)
    ref = embedding_bag_ref(table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# gnn aggregate
# --------------------------------------------------------------------------
from repro.kernels.gnn_aggregate import edge_to_padded, gnn_aggregate, gnn_aggregate_ref


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("case", [(50, 16, 8), (128, 70, 12), (16, 128, 4)])
def test_gnn_aggregate_matches_ref(case, dtype):
    N, dim, deg = case
    rng = np.random.default_rng(hash(case) % 2**31)
    h = jnp.asarray(rng.normal(size=(N, dim)), dtype)
    nbr = jnp.asarray(rng.integers(0, N, size=(N, deg)), jnp.int32)
    gates = jnp.asarray(rng.random((N, deg, dim)), dtype)
    out = gnn_aggregate(h, nbr, gates, interpret=True)
    ref = gnn_aggregate_ref(h, nbr, gates)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype),
    )


def test_gnn_aggregate_matches_segment_sum():
    """Padded-ELL kernel equals the model's COO segment_sum formulation."""
    N, E, dim, deg = 40, 150, 16, 24
    rng = np.random.default_rng(3)
    edge_index = np.stack([rng.integers(0, N, E), rng.integers(0, N, E)])
    h = jnp.asarray(rng.normal(size=(N, dim)), jnp.float32)
    eta = rng.random((E, dim)).astype(np.float32)
    nbr, gates = edge_to_padded(edge_index, eta, N, deg)
    out = gnn_aggregate(h, jnp.asarray(nbr), jnp.asarray(gates), interpret=True)
    ref = jax.ops.segment_sum(
        jnp.asarray(eta) * jnp.take(h, jnp.asarray(edge_index[0]), axis=0),
        jnp.asarray(edge_index[1]),
        num_segments=N,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)
