"""Per-architecture smoke tests: reduced config, one train/serve step on CPU,
asserting output shapes and finiteness (no NaNs)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_shapes, input_specs, list_archs
from repro.configs.base import GNNConfig, LMConfig, RecsysConfig, ShapeCell
from repro.data import make_batch
from repro.models.layers import init_params
from repro.optim import AdamWConfig
from repro.train import build_param_specs, build_serve_step, build_train_step, make_train_state

ALL_ARCHS = list_archs()


def _smoke_cell(cfg, cell: ShapeCell) -> ShapeCell:
    """Shrink a shape cell to CPU scale, keeping its kind."""
    p = dict(cell.params)
    if isinstance(cfg, LMConfig):
        p["seq_len"] = 32
        p["global_batch"] = 2
    elif isinstance(cfg, GNNConfig):
        if cell.kind == "full_graph":
            p.update(n_nodes=40, n_edges=160, d_feat=12)
        elif cell.kind == "minibatch":
            p.update(batch_nodes=4, fanout1=3, fanout2=2)
        elif cell.kind == "batched_graphs":
            p.update(batch=3, n_nodes=10, n_edges=24)
    else:
        p["batch"] = 8
        if "n_candidates" in p:
            p["n_candidates"] = 64
    return dataclasses.replace(cell, params=p)


def _assert_finite(tree, where=""):
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), f"NaN/Inf in {where}"


def _init(cfg, cell):
    specs = build_param_specs(cfg, cell)
    dtype = cfg.dtype if isinstance(cfg, LMConfig) else jnp.float32
    return init_params(jax.random.PRNGKey(0), specs, dtype)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    cells = [c for c in get_shapes(arch) if c.kind in ("train", "full_graph", "minibatch", "batched_graphs")]
    cell = _smoke_cell(cfg, cells[0])
    if isinstance(cfg, GNNConfig) and cell.kind == "minibatch":
        # minibatch spec hardcodes reddit d_feat; use the smoke-sized variant
        pass
    params = _init(cfg, cell)
    state = make_train_state(params)
    step = build_train_step(cfg, cell, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    batch = make_batch(cfg, cell, seed=1)
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    _assert_finite(new_state["params"], f"{arch} params after step")
    # one more step must also be finite (optimizer state exercised)
    new_state, metrics = jax.jit(step)(new_state, batch)
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS if a not in ("gatedgcn",)])
def test_serve_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    serve_cells = [c for c in get_shapes(arch) if c.kind in ("prefill", "decode", "serve", "retrieval")]
    for cell in serve_cells[:2]:  # limit CPU time: first two serve cells
        cell = _smoke_cell(cfg, cell)
        if isinstance(cfg, LMConfig) and cfg.name.startswith(("deepseek-7b", "tinyllama", "qwen2")) and cell.params["seq_len"] > 10**5:
            continue  # long_500k skipped for pure full-attention archs
        params = _init(cfg, cell)
        fn = build_serve_step(cfg, cell)
        batch = make_batch(cfg, cell, seed=2)
        out = jax.jit(fn)(params, **batch)
        _assert_finite(out, f"{arch}/{cell.name}")
        if isinstance(cfg, LMConfig) and cell.kind == "decode":
            logits, new_cache, new_len = out
            assert logits.shape == (cell.params["global_batch"], cfg.vocab)
            assert int(new_len[0]) == cell.params["seq_len"] // 2 + 1
        if isinstance(cfg, RecsysConfig) and cell.kind == "retrieval":
            scores = out
            assert scores.shape[-1] == cell.params["n_candidates"]


@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma3-4b", "deepseek-v2-236b"])
def test_lm_decode_matches_prefill(arch):
    """Decoding token t with a cache built by prefill must agree with a full
    forward pass over the first t+1 tokens (numerical consistency of the
    cached path — incl. MLA's absorbed decode and gemma3's local layers)."""
    cfg = get_config(arch, smoke=True)
    # fp32: this validates path equivalence (absorbed-MLA decode, gemma3 local
    # masks, cache insertion), not bf16 rounding between contraction orders
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    if cfg.moe is not None:
        # decode batches route without drops; match by lifting train capacity
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    from repro.models import transformer

    B, S = 2, 12
    params = _init(cfg, ShapeCell("x", "train", {"seq_len": S, "global_batch": B}))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S + 1)), jnp.int32)

    logits_full, _ = transformer.forward(params, cfg, tokens)
    # prefill first S tokens, then decode token S
    _, cache, cache_len = transformer.prefill(params, cfg, tokens[:, :S])
    # pad cache to S+1 so the decode insert has room
    cache = jax.tree_util.tree_map(
        lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, 4)] + [(0, 0)] * (c.ndim - 3)), cache
    )
    logits_dec, _, _ = transformer.decode_step(
        params, cfg, tokens[:, S : S + 1], cache, cache_len
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec),
        np.asarray(logits_full[:, S]),
        rtol=1e-4,
        atol=1e-4,
    )


def test_gnn_minibatch_sampler_end_to_end():
    from repro.data.graph import CSRGraph, NeighborSampler, random_graph

    cfg = get_config("gatedgcn", smoke=True)
    n, e = 500, 4000
    ei = random_graph(n, e, seed=0)
    g = CSRGraph.from_edge_index(ei, n)
    sampler = NeighborSampler(g, (3, 2), seed=0)
    seeds = np.arange(8, dtype=np.int32)
    block = sampler.sample(seeds)
    assert block["nodes"].shape == (8 * (1 + 3 + 6),)
    assert block["edge_index"].shape == (2, 8 * (3 + 6))
    assert block["edge_index"].max() < block["nodes"].shape[0]

    # run a train step on the sampled block
    from repro.models import gnn
    from repro.models.layers import init_params as ip

    feats = np.random.default_rng(0).normal(size=(n, 12)).astype(np.float32)
    node_feat = jnp.asarray(feats[block["nodes"]])
    specs = gnn.gnn_specs(cfg, 12)
    params = ip(jax.random.PRNGKey(0), specs, jnp.float32)
    logits = gnn.forward(params, cfg, node_feat, jnp.asarray(block["edge_index"]))
    assert logits.shape == (block["nodes"].shape[0], cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())
