"""Pure-jnp oracle for cached decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,  # [B, Hkv, G, d]
    k_cache: jax.Array,  # [B, T, Hkv, d]
    v_cache: jax.Array,
    lens: jax.Array,  # [B]
) -> jax.Array:
    B, Hkv, G, d = q.shape
    T = k_cache.shape[1]
    s = jnp.einsum("bkgd,btkd->bkgt", q, k_cache).astype(jnp.float32) / jnp.sqrt(d)
    mask = jnp.arange(T)[None, :] < lens[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgt,btkd->bkgd", p, v_cache)
