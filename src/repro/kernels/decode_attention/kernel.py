"""Cached decode attention (flash-decoding style) — one query token against a
long KV cache, online softmax over KV blocks, variable per-sequence lengths
delivered via scalar prefetch (SMEM on TPU).

Grid (B, Hkv, n_kv_blocks): KV innermost/sequential; the per-(b,h) state is
the grouped-query accumulator (G, d) in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

MASK_VALUE = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_kernel(
    lens_ref,  # scalar-prefetch: [B] int32 (valid length incl. current token)
    q_ref,  # [1, 1, G, d]
    k_ref,  # [1, blk_k, 1, d]
    v_ref,
    o_ref,  # [1, 1, G, d]
    m_scr,  # [G]
    l_scr,  # [G]
    acc_scr,  # [G, d]
    *,
    scale: float,
    block_k: int,
    n_k: int,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, :, :]  # [G, d]
    k = k_ref[0, :, 0, :]  # [blk_k, d]
    v = v_ref[0, :, 0, :]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = s * scale  # [G, blk_k]
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < lens_ref[b], s, MASK_VALUE)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    m_scr[...] = m_cur
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0, 0, :, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def decode_attention_kernel(
    q: jax.Array,  # [B, Hkv, G, d]
    k_cache: jax.Array,  # [B, T, Hkv, d]  (T padded to block multiple)
    v_cache: jax.Array,
    lens: jax.Array,  # [B] int32
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hkv, G, d = q.shape
    T = k_cache.shape[1]
    n_k = T // block_k
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_k=block_k, n_k=n_k
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b, h, ki, lens: (b, ki, h, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b, h, ki, lens: (b, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, q, k_cache, v_cache)
