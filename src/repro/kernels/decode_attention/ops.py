"""jit'd wrapper for the decode attention kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import decode_attention_kernel


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jax.Array,  # [B, Hq, d] — single query token per sequence
    k_cache: jax.Array,  # [B, T, Hkv, d]
    v_cache: jax.Array,
    lens: jax.Array,  # [B] valid cache length incl. current token
    *,
    block_k: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, d = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    block_k = min(block_k, T)
    pad = (-T) % block_k
    if pad:
        widths = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)
    qg = q.reshape(B, Hkv, G, d)
    out = decode_attention_kernel(
        qg, k_cache, v_cache, lens.astype(jnp.int32),
        block_k=block_k, interpret=interpret,
    )
    return out.reshape(B, Hq, d)
