"""Pallas-TPU API compatibility across jax releases.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; accept
either so the kernels run on both old (0.4.x) and current jax.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
