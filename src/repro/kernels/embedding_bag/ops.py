"""jit'd wrapper: dim padding + weight defaulting for the bag kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import embedding_bag_kernel


@partial(jax.jit, static_argnames=("combiner", "interpret"))
def embedding_bag(
    table: jax.Array,  # [V, dim]
    ids: jax.Array,  # [n_bags, bag_size]
    weights: jax.Array | None = None,
    *,
    combiner: str = "sum",
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    V, dim = table.shape
    pad = (-dim) % 128  # TPU lane alignment
    tp = jnp.pad(table, [(0, 0), (0, pad)]) if pad else table
    if weights is None:
        weights = jnp.ones(ids.shape, jnp.float32)
    out = embedding_bag_kernel(
        tp, ids.astype(jnp.int32), weights.astype(jnp.float32),
        combiner=combiner, interpret=interpret,
    )
    return out[:, :dim]
