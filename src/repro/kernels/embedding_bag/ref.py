"""Pure-jnp oracle: EmbeddingBag via take + weighted sum (segment form)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(
    table: jax.Array,  # [V, dim]
    ids: jax.Array,  # [n_bags, bag_size]
    weights: jax.Array | None = None,  # [n_bags, bag_size]
    combiner: str = "sum",
) -> jax.Array:
    rows = jnp.take(table, ids, axis=0).astype(jnp.float32)  # fp32 accumulate
    if weights is not None:
        rows = rows * weights[..., None].astype(jnp.float32)
    out = rows.sum(axis=1)
    if combiner == "mean":
        out = out / ids.shape[1]
    return out.astype(table.dtype)
