from .ops import embedding_bag
from .ref import embedding_bag_ref

__all__ = ["embedding_bag", "embedding_bag_ref"]
