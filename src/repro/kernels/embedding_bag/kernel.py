"""EmbeddingBag gather-reduce — the recsys hot path as a Pallas kernel.

TPU mapping: the bag indices are scalar-prefetched (SMEM) and drive the
*index_map* of the table's BlockSpec — each grid step DMAs exactly one
(1, dim) table row from HBM into VMEM (the canonical Pallas sparse-gather
pattern; FBGEMM TBE equivalent).  Grid (n_bags, bag_size) with the bag-item
dimension innermost/sequential accumulating into a VMEM scratch row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _bag_kernel(
    ids_ref,  # scalar-prefetch: [n_bags, bag_size] int32
    wgt_ref,  # scalar-prefetch: [n_bags, bag_size] f32 per-sample weights
    row_ref,  # [1, dim] — the gathered table row (DMA'd by index_map)
    o_ref,  # [1, dim]
    acc_scr,  # [dim] f32
    *,
    bag_size: int,
    combiner: str,
):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    w = wgt_ref[b, j]
    acc_scr[...] += row_ref[0, :].astype(jnp.float32) * w

    @pl.when(j == bag_size - 1)
    def _done():
        out = acc_scr[...]
        if combiner == "mean":
            out = out / bag_size
        o_ref[0, :] = out.astype(o_ref.dtype)


def embedding_bag_kernel(
    table: jax.Array,  # [V, dim]  (dim padded to 128)
    ids: jax.Array,  # [n_bags, bag_size] int32
    weights: jax.Array,  # [n_bags, bag_size] f32
    *,
    combiner: str = "sum",
    interpret: bool = False,
) -> jax.Array:
    n_bags, bag_size = ids.shape
    dim = table.shape[1]
    kernel = functools.partial(_bag_kernel, bag_size=bag_size, combiner=combiner)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_bags, bag_size),
        in_specs=[
            # the scalar-prefetched ids drive the gather: row = table[ids[b,j]]
            pl.BlockSpec((1, dim), lambda b, j, ids, wgt: (ids[b, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda b, j, ids, wgt: (b, 0)),
        scratch_shapes=[pltpu.VMEM((dim,), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, dim), table.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(ids, weights, table)
