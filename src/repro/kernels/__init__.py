"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle).  On CPU the wrappers run interpret=True;
on TPU they compile via Mosaic.
"""
from .decode_attention import decode_attention, decode_attention_ref
from .embedding_bag import embedding_bag, embedding_bag_ref
from .flash_attention import attention_ref, flash_attention
from .gnn_aggregate import edge_to_padded, gnn_aggregate, gnn_aggregate_ref

__all__ = [
    "attention_ref",
    "decode_attention",
    "decode_attention_ref",
    "edge_to_padded",
    "embedding_bag",
    "embedding_bag_ref",
    "flash_attention",
    "gnn_aggregate",
    "gnn_aggregate_ref",
]
