from .ops import gnn_aggregate
from .ref import edge_to_padded, gnn_aggregate_ref

__all__ = ["gnn_aggregate", "gnn_aggregate_ref", "edge_to_padded"]
