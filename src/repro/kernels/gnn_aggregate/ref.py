"""Pure-jnp oracle: padded-neighbour gated aggregation."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gnn_aggregate_ref(h: jax.Array, nbr: jax.Array, gates: jax.Array) -> jax.Array:
    rows = jnp.take(h, nbr, axis=0).astype(jnp.float32)  # [N, deg, dim]
    return (rows * gates.astype(jnp.float32)).sum(axis=1).astype(h.dtype)


def edge_to_padded(
    edge_index, eta, n_nodes: int, max_deg: int
):
    """Convert COO (src,dst) edges + per-edge gates to the padded-ELL layout
    the kernel consumes.  numpy host-side prep (data-pipeline stage)."""
    import numpy as np

    src, dst = np.asarray(edge_index)
    eta = np.asarray(eta)
    nbr = np.zeros((n_nodes, max_deg), np.int32)
    gates = np.zeros((n_nodes, max_deg, eta.shape[-1]), eta.dtype)
    fill = np.zeros(n_nodes, np.int32)
    for e in range(src.shape[0]):
        d = dst[e]
        if fill[d] < max_deg:
            nbr[d, fill[d]] = src[e]
            gates[d, fill[d]] = eta[e]
            fill[d] += 1
    return nbr, gates
