"""Gated neighbour aggregation (GatedGCN inner loop) as a Pallas kernel.

out[n] = sum_j gate[n,j,:] * h[nbr[n,j],:]   (padded-neighbour / ELL layout)

TPU mapping: like the embedding-bag gather, the neighbour table is scalar-
prefetched and drives the feature-row DMA via the BlockSpec index_map; the
per-edge vector gates stream through a regular (1,1,dim) block.  Grid
(n_nodes, max_degree), degree innermost accumulating in VMEM scratch.
Padding slots point at row 0 with zero gates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams


def _agg_kernel(nbr_ref, h_ref, gate_ref, o_ref, acc_scr, *, max_deg: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += h_ref[0, :].astype(jnp.float32) * gate_ref[0, 0, :].astype(
        jnp.float32
    )

    @pl.when(j == max_deg - 1)
    def _done():
        o_ref[0, :] = acc_scr[...].astype(o_ref.dtype)


def gnn_aggregate_kernel(
    h: jax.Array,  # [N, dim] node features (dim padded to 128)
    nbr: jax.Array,  # [N, max_deg] neighbour ids (pad -> 0)
    gates: jax.Array,  # [N, max_deg, dim] per-edge gates (pad -> 0)
    *,
    interpret: bool = False,
) -> jax.Array:
    N, dim = h.shape
    max_deg = nbr.shape[1]
    kernel = functools.partial(_agg_kernel, max_deg=max_deg)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N, max_deg),
        in_specs=[
            pl.BlockSpec((1, dim), lambda n, j, nbr: (nbr[n, j], 0)),
            pl.BlockSpec((1, 1, dim), lambda n, j, nbr: (n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda n, j, nbr: (n, 0)),
        scratch_shapes=[pltpu.VMEM((dim,), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, dim), h.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(nbr.astype(jnp.int32), h, gates)
