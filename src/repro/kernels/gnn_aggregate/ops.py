"""jit'd wrapper for the gated neighbour aggregation kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import gnn_aggregate_kernel


@partial(jax.jit, static_argnames=("interpret",))
def gnn_aggregate(
    h: jax.Array,  # [N, dim]
    nbr: jax.Array,  # [N, max_deg]
    gates: jax.Array,  # [N, max_deg, dim]
    *,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N, dim = h.shape
    pad = (-dim) % 128
    if pad:
        h = jnp.pad(h, [(0, 0), (0, pad)])
        gates = jnp.pad(gates, [(0, 0), (0, 0), (0, pad)])
    out = gnn_aggregate_kernel(h, nbr, gates, interpret=interpret)
    return out[:, :dim]
