"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # [B,Sq,Hq,d]
    k: jax.Array,  # [B,Sk,Hkv,d]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jax.Array:
    B, Sq, Hq, d = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, d)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) / jnp.sqrt(d)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(B, Sq, Hq, d)
