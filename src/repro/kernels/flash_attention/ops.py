"""jit'd wrapper: padding, GQA plumbing, interpret-mode fallback on CPU."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B,Sq,Hq,d]
    k: jax.Array,  # [B,Sk,Hkv,d]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, Hq, d = q.shape
    Sk = k.shape[1]
    block_q = min(block_q, max(Sq, 16))
    block_k = min(block_k, max(Sk, 16))
    qp = _pad_to(q, 1, block_q)
    kp = _pad_to(k, 1, block_k)
    vp = _pad_to(v, 1, block_k)
    out = flash_attention_kernel(
        qp,
        kp,
        vp,
        causal=causal,
        window=window,
        s_real=Sk,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
    return out[:, :Sq]
