"""Blocked flash attention (causal/local GQA) — pl.pallas_call + BlockSpec.

TPU mapping: grid (B, Hq, n_q_blocks, n_kv_blocks) with the KV dimension
innermost ("arbitrary" semantics — sequential on TPU), online-softmax
accumulators (m, l, acc) in VMEM scratch.  Block shapes are (block_q, d) /
(block_k, d) tiles — MXU-aligned multiples of 128 by default — so the
working set per step is q + k + v + acc ~ 4 * 128 * d * 4B << VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams

MASK_VALUE = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    n_k: int,
    s_real: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]  # [blk_q, d]
    k = k_ref[0, :, 0, :]  # [blk_k, d]
    v = v_ref[0, :, 0, :]  # [blk_k, d]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = s * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < s_real  # padded keys never contribute
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, MASK_VALUE)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
    m_scr[...] = m_cur
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == n_k - 1)
    def _done():
        o_ref[0, :, 0, :] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # [B, Sq, Hq, d]  (padded to block multiples)
    k: jax.Array,  # [B, Sk, Hkv, d]
    v: jax.Array,
    *,
    causal: bool,
    window: int | None,
    s_real: int,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, d = q.shape
    _, Sk, Hkv, _ = k.shape
    group = Hq // Hkv
    n_q = Sq // block_q
    n_k = Sk // block_k
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_k=n_k,
        s_real=s_real,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b, h, qi, ki: (b, ki, h // group, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b, h, qi, ki: (b, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
