from .checkpoint import CheckpointInfo, CheckpointManager

__all__ = ["CheckpointInfo", "CheckpointManager"]
