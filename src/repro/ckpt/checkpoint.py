"""Checkpointing: per-shard manifest save/restore, async save, elastic reshard.

Built on the same shard-aware IntermediateStore as RISP artifacts — a
checkpoint IS an intermediate state of the training workflow (the thesis'
error-recovery story, Ch. 3.5.2, applied to training):

  * every host writes only its addressable shards (HDFS-write analogue)
  * restore accepts a DIFFERENT mesh: shards are reassembled to the global
    array and re-sharded under the new mesh — elastic scaling
  * async mode snapshots to host memory and writes on a worker thread,
    overlapping serialization with the next step's compute
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any

import numpy as np

import jax

from ..core.store import IntermediateStore


@dataclass
class CheckpointInfo:
    step: int
    key: str
    nbytes: int
    seconds: float
    async_pending: bool = False


class CheckpointManager:
    def __init__(
        self,
        directory: str | Path,
        *,
        keep: int = 3,
        async_save: bool = False,
    ) -> None:
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.store = IntermediateStore(self.dir / "objects")
        self.keep = keep
        self.async_save = async_save
        self._meta_path = self.dir / "checkpoints.json"
        self.checkpoints: list[dict] = []
        if self._meta_path.exists():
            self.checkpoints = json.loads(self._meta_path.read_text())
        self._pending: threading.Thread | None = None

    # -- save ------------------------------------------------------------
    def _key(self, step: int) -> str:
        return f"ckpt::step{step:012d}"

    def save(self, step: int, state: Any) -> CheckpointInfo:
        if self.async_save:
            return self._save_async(step, state)
        t0 = time.perf_counter()
        res = self.store.put(self._key(step), state)
        self._commit(step, res.nbytes_raw)
        return CheckpointInfo(step, res.key, res.nbytes_raw, time.perf_counter() - t0)

    def _save_async(self, step: int, state: Any) -> CheckpointInfo:
        self.wait()  # one in flight at a time
        # snapshot to host memory synchronously (cheap), write on a thread
        host_state = jax.tree_util.tree_map(lambda a: np.asarray(a), state)

        def work():
            res = self.store.put(self._key(step), host_state)
            self._commit(step, res.nbytes_raw)

        self._pending = threading.Thread(target=work, daemon=True)
        self._pending.start()
        return CheckpointInfo(step, self._key(step), 0, 0.0, async_pending=True)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _commit(self, step: int, nbytes: int) -> None:
        self.checkpoints = [c for c in self.checkpoints if c["step"] != step]
        self.checkpoints.append({"step": step, "nbytes": nbytes, "ts": time.time()})
        self.checkpoints.sort(key=lambda c: c["step"])
        while len(self.checkpoints) > self.keep:
            old = self.checkpoints.pop(0)
            self.store.delete(self._key(old["step"]))
        self._meta_path.write_text(json.dumps(self.checkpoints))

    # -- restore -------------------------------------------------------------
    def latest_step(self) -> int | None:
        self.wait()
        return self.checkpoints[-1]["step"] if self.checkpoints else None

    def restore(
        self,
        step: int | None = None,
        *,
        shardings: Any = None,
    ) -> tuple[int, Any]:
        """Restore a checkpoint; ``shardings`` (a pytree of NamedShardings
        over ANY mesh) reshards on load — elastic scaling across mesh sizes."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        state = self.store.get(self._key(step))
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), state, shardings
            )
        return step, state
