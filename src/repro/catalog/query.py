"""``python -m repro.catalog.query`` — find-by-statepoint from the shell.

Works against every deployment:

* ``--root DIR`` — a local store directory: loads ``catalog.json`` through
  a :class:`~repro.core.backends.LocalFSBackend`.
* ``--store-url tcp://h:p[,h:p...]`` — a store server or cluster: runs the
  query server-side (``catalog_query``), fanning out and merging when the
  url names more than one shard.

Examples::

    python -m repro.catalog.query --root /tmp/store --module align --param k=31
    python -m repro.catalog.query --store-url tcp://localhost:7077 \
        --module train --param lr=0.1 --dataset d1 --json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Sequence

from .catalog import Catalog
from .records import CatalogQuery, CatalogRecord


def _parse_param(spec: str) -> tuple[str, Any]:
    """``name=value`` with the value parsed as JSON first (so ``k=31``
    matches the *int* 31), falling back to the raw string."""
    if "=" not in spec:
        raise argparse.ArgumentTypeError(
            f"--param needs name=value, got {spec!r}"
        )
    name, raw = spec.split("=", 1)
    try:
        return name, json.loads(raw)
    except json.JSONDecodeError:
        return name, raw


def _open_catalog(args: argparse.Namespace) -> Catalog:
    if args.store_url:
        from ..net.client import RemoteBackend
        from ..net.sharded import ShardedBackend

        url = args.store_url
        if "," in url:
            backend = ShardedBackend(url, replication=args.replication)
        else:
            backend = RemoteBackend(url)
        return Catalog(backend, persist=False)
    from ..core.backends import LocalFSBackend

    return Catalog(LocalFSBackend(args.root), persist=True)


def _fmt_row(rec: CatalogRecord) -> str:
    params = ", ".join(f"{k}={v!r}" for k, v in sorted(rec.params().items()))
    ns = rec.namespace or "-"
    return (
        f"{ns:12s} {rec.dataset:16s} {'>'.join(rec.modules):40s} "
        f"[{params}] loads={rec.n_loads} bytes={rec.nbytes}"
    )


def main(argv: "Sequence[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.catalog.query",
        description="Query the artifact catalog (find-by-statepoint).",
    )
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--root", help="local store directory (reads catalog.json)")
    src.add_argument(
        "--store-url",
        help="store server url; comma-separated list queries a cluster",
    )
    ap.add_argument("--module", help="terminal module id to match")
    ap.add_argument(
        "--param",
        action="append",
        default=[],
        type=_parse_param,
        metavar="NAME=VALUE",
        help="parameter filter (repeatable); VALUE parsed as JSON, then raw",
    )
    ap.add_argument("--dataset", help="bare dataset id to match")
    ap.add_argument("--namespace", help="namespace to match (e.g. shared)")
    ap.add_argument(
        "--any-position",
        action="store_true",
        help="match artifacts whose chain *contains* the module anywhere",
    )
    ap.add_argument("--limit", type=int, default=20)
    ap.add_argument(
        "--replication", type=int, default=2, help="cluster replica-set size"
    )
    ap.add_argument("--json", action="store_true", help="emit records as JSON")
    args = ap.parse_args(argv)
    if args.param and not args.module:
        ap.error("--param needs --module to anchor it")

    catalog = _open_catalog(args)
    try:
        q = CatalogQuery.build(
            module=args.module,
            params=dict(args.param),
            dataset=args.dataset,
            namespace=args.namespace,
            any_position=args.any_position,
            limit=args.limit,
        )
        hits = catalog.query(q)
    finally:
        close = getattr(catalog.backend, "close", None)
        if callable(close):
            close()
    if args.json:
        print(json.dumps([r.to_doc() for r in hits], indent=2))
    else:
        for rec in hits:
            print(_fmt_row(rec))
        print(f"{len(hits)} artifact(s)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
