"""``python -m repro.catalog.query`` — find-by-statepoint from the shell.

Works against every deployment:

* ``--root DIR`` — a local store directory: loads ``catalog.json`` through
  a :class:`~repro.core.backends.LocalFSBackend`.
* ``--store-url tcp://h:p[,h:p...]`` — a store server or cluster: runs the
  query server-side (``catalog_query``), fanning out and merging when the
  url names more than one shard.

``--dedup`` switches the tool from find-by-statepoint to a cross-namespace
duplication report: identical module chains (same dataset, same modules,
same encoded tool states) stored under several ``tenant:*`` namespaces are
promotion candidates — keep one copy under ``shared`` and the rest of the
bytes come back.

Examples::

    python -m repro.catalog.query --root /tmp/store --module align --param k=31
    python -m repro.catalog.query --store-url tcp://localhost:7077 \
        --module train --param lr=0.1 --dataset d1 --json
    python -m repro.catalog.query --root /tmp/store --dedup
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Sequence

from .catalog import Catalog
from .records import CatalogQuery, CatalogRecord


def _parse_param(spec: str) -> tuple[str, Any]:
    """``name=value`` with the value parsed as JSON first (so ``k=31``
    matches the *int* 31), falling back to the raw string."""
    if "=" not in spec:
        raise argparse.ArgumentTypeError(
            f"--param needs name=value, got {spec!r}"
        )
    name, raw = spec.split("=", 1)
    try:
        return name, json.loads(raw)
    except json.JSONDecodeError:
        return name, raw


def _open_catalog(args: argparse.Namespace) -> Catalog:
    if args.store_url:
        from ..net.client import RemoteBackend
        from ..net.sharded import ShardedBackend

        url = args.store_url
        if "," in url:
            backend = ShardedBackend(url, replication=args.replication)
        else:
            backend = RemoteBackend(url)
        return Catalog(backend, persist=False)
    from ..core.backends import LocalFSBackend

    return Catalog(LocalFSBackend(args.root), persist=True)


def _chain_identity(rec: CatalogRecord) -> tuple:
    """Hashable content identity of an artifact *ignoring namespace*: same
    bare dataset, same module chain, same encoded tool states at every
    position.  Two records with equal identities hold the same bytes — the
    store key differs only in the namespace segment."""
    return (
        rec.dataset,
        rec.modules,
        tuple(tuple(sorted(s.items())) for s in rec.states),
    )


def dedup_report(
    records: "Sequence[CatalogRecord]", *, tenant_only: bool = True
) -> list[dict[str, Any]]:
    """Group records by content identity and report every group stored under
    more than one namespace.  Each entry names the namespaces holding a copy,
    the canonical copy to keep (most-reused, ties to oldest), and the bytes
    reclaimed by promoting it to ``shared`` and dropping the rest.

    ``tenant_only`` restricts the scan to ``tenant:*`` namespaces — the
    multi-tenant case the gateway creates; pass ``False`` to consider every
    namespace (including ``""`` and ``shared`` itself).
    """
    groups: dict[tuple, list[CatalogRecord]] = {}
    for rec in records:
        if tenant_only and not rec.namespace.startswith("tenant:"):
            continue
        groups.setdefault(_chain_identity(rec), []).append(rec)

    report: list[dict[str, Any]] = []
    for members in groups.values():
        namespaces = {r.namespace for r in members}
        if len(namespaces) < 2:
            continue
        # keep the copy with the best reuse record; oldest breaks ties so
        # the choice is stable across runs
        keep = min(members, key=lambda r: (-r.n_loads, r.created_at, r.key))
        reclaimable = sum(r.nbytes for r in members) - keep.nbytes
        report.append(
            {
                "dataset": keep.dataset,
                "modules": list(keep.modules),
                "depth": keep.depth,
                "params": keep.params(),
                "namespaces": sorted(namespaces),
                "n_copies": len(members),
                "keep": keep.key,
                "promote_to": "shared",
                "reclaimable_bytes": reclaimable,
                "total_loads": sum(r.n_loads for r in members),
            }
        )
    report.sort(key=lambda e: (-e["reclaimable_bytes"], e["keep"]))
    return report


def _fmt_dedup_entry(entry: dict[str, Any]) -> str:
    chain = ">".join(entry["modules"])
    nss = ",".join(entry["namespaces"])
    return (
        f"{entry['dataset']:16s} {chain:40s} x{entry['n_copies']} "
        f"[{nss}] reclaim={entry['reclaimable_bytes']}B "
        f"loads={entry['total_loads']} keep={entry['keep']}"
    )


def _fmt_row(rec: CatalogRecord) -> str:
    params = ", ".join(f"{k}={v!r}" for k, v in sorted(rec.params().items()))
    ns = rec.namespace or "-"
    return (
        f"{ns:12s} {rec.dataset:16s} {'>'.join(rec.modules):40s} "
        f"[{params}] loads={rec.n_loads} bytes={rec.nbytes}"
    )


def main(argv: "Sequence[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.catalog.query",
        description="Query the artifact catalog (find-by-statepoint).",
    )
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--root", help="local store directory (reads catalog.json)")
    src.add_argument(
        "--store-url",
        help="store server url; comma-separated list queries a cluster",
    )
    ap.add_argument("--module", help="terminal module id to match")
    ap.add_argument(
        "--param",
        action="append",
        default=[],
        type=_parse_param,
        metavar="NAME=VALUE",
        help="parameter filter (repeatable); VALUE parsed as JSON, then raw",
    )
    ap.add_argument("--dataset", help="bare dataset id to match")
    ap.add_argument("--namespace", help="namespace to match (e.g. shared)")
    ap.add_argument(
        "--any-position",
        action="store_true",
        help="match artifacts whose chain *contains* the module anywhere",
    )
    ap.add_argument("--limit", type=int, default=20)
    ap.add_argument(
        "--replication", type=int, default=2, help="cluster replica-set size"
    )
    ap.add_argument("--json", action="store_true", help="emit records as JSON")
    ap.add_argument(
        "--dedup",
        action="store_true",
        help="report identical chains duplicated across tenant namespaces "
        "(promotion-to-shared candidates with reclaimable bytes)",
    )
    ap.add_argument(
        "--all-namespaces",
        action="store_true",
        help="with --dedup: consider every namespace, not just tenant:*",
    )
    args = ap.parse_args(argv)
    if args.param and not args.module:
        ap.error("--param needs --module to anchor it")
    if args.dedup and (args.module or args.param or args.namespace):
        ap.error("--dedup scans whole catalogs; it only composes with "
                 "--dataset and --json")

    catalog = _open_catalog(args)
    try:
        if args.dedup:
            # full scan: an unfiltered query returns every record the
            # catalog (or cluster, merged) knows about
            scan = CatalogQuery.build(dataset=args.dataset, limit=1_000_000)
            report = dedup_report(
                catalog.query(scan), tenant_only=not args.all_namespaces
            )
            if args.json:
                print(json.dumps(report, indent=2))
            else:
                for entry in report:
                    print(_fmt_dedup_entry(entry))
                total = sum(e["reclaimable_bytes"] for e in report)
                print(
                    f"{len(report)} duplicated chain(s), "
                    f"{total} byte(s) reclaimable by promotion to shared",
                    file=sys.stderr,
                )
            return 0
        q = CatalogQuery.build(
            module=args.module,
            params=dict(args.param),
            dataset=args.dataset,
            namespace=args.namespace,
            any_position=args.any_position,
            limit=args.limit,
        )
        hits = catalog.query(q)
    finally:
        close = getattr(catalog.backend, "close", None)
        if callable(close):
            close()
    if args.json:
        print(json.dumps([r.to_doc() for r in hits], indent=2))
    else:
        for rec in hits:
            print(_fmt_row(rec))
        print(f"{len(hits)} artifact(s)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
