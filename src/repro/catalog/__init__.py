"""``repro.catalog`` — queryable provenance index over the artifact space.

The store answers exact :class:`~repro.core.workflow.PrefixKey` lookups;
the catalog answers *find-by-statepoint* questions ("what artifacts exist
for module ``align`` with ``k=31`` on this dataset?") — the discoverability
surface the thesis' reuse results depend on, modeled on signac's
content-hashed statepoint index with ``find(filter)``.
"""
from .catalog import CATALOG_META, Catalog
from .index import CatalogIndex
from .records import (
    CatalogQuery,
    CatalogRecord,
    rank_key,
    record_for_prefix,
    split_namespaced_dataset,
)

__all__ = [
    "CATALOG_META",
    "Catalog",
    "CatalogIndex",
    "CatalogQuery",
    "CatalogRecord",
    "rank_key",
    "record_for_prefix",
    "split_namespaced_dataset",
]
