"""Catalog record + query documents.

A :class:`CatalogRecord` is the *queryable* description of one stored
artifact: where the store itself only answers exact ``PrefixKey`` lookups,
the catalog knows the artifact's module chain, each module's decoded
tool-state parameters, its dataset and namespace, and the cost/size/reuse
statistics that rank it.  Records are plain JSON documents so they travel
over the ``repro.net`` wire (the ``catalog_*`` op family) and persist as
``catalog.json`` beside ``index.json``.

Parameter values are kept in their **canonical encoded** form (the same
invertible :func:`repro.core.workflow.encode_param` rendering the
``ToolState`` identity uses).  Matching a user query therefore reduces to
string equality after encoding the query value — exactly the equality that
defines tool-state identity, so ``find(params={"k": 31})`` matches precisely
the artifacts whose store keys embed ``k=31``, typed (``31 != "31"``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

from ..core.workflow import ModuleRef, PrefixKey, ToolState, encode_param


def split_namespaced_dataset(dataset_id: str) -> tuple[str, str]:
    """Split a composed ``<namespace>/<dataset>`` identity (the inverse of
    :func:`repro.api.spec.namespaced_dataset`).  Legacy un-namespaced ids
    come back as ``("", dataset_id)``.  ``"/"`` is reserved as the separator,
    so only the first one splits."""
    if "/" in dataset_id:
        ns, ds = dataset_id.split("/", 1)
        return ns, ds
    return "", dataset_id


@dataclass
class CatalogRecord:
    """One stored artifact, as the catalog sees it.

    ``modules`` is the module-id chain root→terminal; ``states`` carries the
    *encoded* parameter mapping of each module at the same position.  The
    terminal module (``modules[-1]``) is the one that produced the artifact.
    """

    key: str  # the store key (PrefixKey rendering) — the catalog's identity
    namespace: str
    dataset: str  # bare dataset id (namespace stripped)
    modules: tuple[str, ...]
    states: tuple[Mapping[str, str], ...]  # encoded params per chain position
    nbytes: int = 0
    compute_s: float | None = None
    created_at: float = field(default_factory=time.time)
    last_used_at: float = 0.0
    n_loads: int = 0

    def __post_init__(self) -> None:
        self.modules = tuple(self.modules)
        self.states = tuple(dict(s) for s in self.states)
        if len(self.modules) != len(self.states):
            raise ValueError(
                f"chain of {len(self.modules)} modules with "
                f"{len(self.states)} states"
            )
        if not self.last_used_at:
            self.last_used_at = self.created_at

    # -- derived --------------------------------------------------------------
    @property
    def depth(self) -> int:
        return len(self.modules)

    @property
    def module(self) -> str:
        """The terminal module — the one whose output this artifact is."""
        return self.modules[-1]

    @property
    def dataset_id(self) -> str:
        """The composed dataset identity every ``PrefixKey`` uses."""
        return f"{self.namespace}/{self.dataset}" if self.namespace else self.dataset

    def params(self, position: int = -1) -> dict[str, Any]:
        """Decoded parameter mapping of one chain position (default:
        terminal module)."""
        state = ToolState(tuple(sorted(self.states[position].items())))
        return state.to_config()

    def prefix_key(self) -> PrefixKey:
        """Reconstruct the artifact's :class:`PrefixKey` (tool states
        included) — what a reuse probe or recommender suggestion needs."""
        refs = tuple(
            ModuleRef(m, ToolState(tuple(sorted(s.items()))))
            for m, s in zip(self.modules, self.states)
        )
        return PrefixKey(self.dataset_id, refs)

    # -- documents -------------------------------------------------------------
    def to_doc(self) -> dict[str, Any]:
        return {
            "key": self.key,
            "namespace": self.namespace,
            "dataset": self.dataset,
            "modules": list(self.modules),
            "states": [dict(s) for s in self.states],
            "nbytes": int(self.nbytes),
            "compute_s": self.compute_s,
            "created_at": self.created_at,
            "last_used_at": self.last_used_at,
            "n_loads": int(self.n_loads),
        }

    @classmethod
    def from_doc(cls, doc: Mapping[str, Any]) -> "CatalogRecord":
        return cls(
            key=str(doc["key"]),
            namespace=str(doc.get("namespace", "")),
            dataset=str(doc.get("dataset", "")),
            modules=tuple(str(m) for m in doc.get("modules", ())),
            states=tuple(
                {str(k): str(v) for k, v in s.items()} for s in doc.get("states", ())
            ),
            nbytes=int(doc.get("nbytes", 0) or 0),
            compute_s=doc.get("compute_s"),
            created_at=float(doc.get("created_at", 0.0) or 0.0),
            last_used_at=float(doc.get("last_used_at", 0.0) or 0.0),
            n_loads=int(doc.get("n_loads", 0) or 0),
        )


def record_for_prefix(
    prefix: PrefixKey,
    key: str,
    *,
    nbytes: int = 0,
    compute_s: float | None = None,
    created_at: float | None = None,
    last_used_at: float = 0.0,
    n_loads: int = 0,
) -> CatalogRecord:
    """Build the catalog record for one admitted artifact.  Called at the
    admission seam (``admit_and_store``), the only place that still holds the
    structured :class:`PrefixKey` the flat store key was rendered from."""
    namespace, dataset = split_namespaced_dataset(prefix.dataset_id)
    return CatalogRecord(
        key=key,
        namespace=namespace,
        dataset=dataset,
        modules=tuple(m.module_id for m in prefix.modules),
        states=tuple(dict(m.state.params) for m in prefix.modules),
        nbytes=nbytes,
        compute_s=compute_s,
        created_at=created_at if created_at is not None else time.time(),
        last_used_at=last_used_at,
        n_loads=n_loads,
    )


@dataclass
class CatalogQuery:
    """One find-by-statepoint query (signac's ``find(filter)``, specialized
    to the workflow data model).

    ``params`` values are **encoded** (see module docstring); build queries
    from user values with :meth:`build`.  ``module=None`` matches any module;
    ``any_position=True`` matches artifacts whose chain *contains* the module
    (with its params at that position) instead of only artifacts the module
    itself produced.  ``namespace=None`` means "any namespace" — the gateway
    never passes None (tenant scoping resolves a concrete namespace first).
    """

    module: str | None = None
    params: dict[str, str] = field(default_factory=dict)
    dataset: str | None = None
    namespace: str | None = None
    any_position: bool = False
    limit: int = 50

    @classmethod
    def build(
        cls,
        module: str | None = None,
        params: Mapping[str, Any] | None = None,
        dataset: str | None = None,
        namespace: str | None = None,
        any_position: bool = False,
        limit: int = 50,
    ) -> "CatalogQuery":
        if params and module is None:
            raise ValueError("a params filter needs a module to anchor it")
        return cls(
            module=module,
            params={str(k): encode_param(v) for k, v in (params or {}).items()},
            dataset=dataset,
            namespace=namespace,
            any_position=any_position,
            limit=max(1, int(limit)),
        )

    def to_doc(self) -> dict[str, Any]:
        return {
            "module": self.module,
            "params": dict(self.params),
            "dataset": self.dataset,
            "namespace": self.namespace,
            "any_position": self.any_position,
            "limit": self.limit,
        }

    @classmethod
    def from_doc(cls, doc: Mapping[str, Any]) -> "CatalogQuery":
        return cls(
            module=doc.get("module"),
            params={str(k): str(v) for k, v in (doc.get("params") or {}).items()},
            dataset=doc.get("dataset"),
            namespace=doc.get("namespace"),
            any_position=bool(doc.get("any_position", False)),
            limit=max(1, int(doc.get("limit", 50) or 50)),
        )

    # -- matching ---------------------------------------------------------------
    def _position_matches(self, rec: CatalogRecord, i: int) -> bool:
        if rec.modules[i] != self.module:
            return False
        state = rec.states[i]
        return all(state.get(k) == v for k, v in self.params.items())

    def matches(self, rec: CatalogRecord) -> bool:
        """Exact predicate — postings in :class:`CatalogIndex` are only a
        pre-filter (loose for repeated module ids); this decides."""
        if self.namespace is not None and rec.namespace != self.namespace:
            return False
        if self.dataset is not None and rec.dataset != self.dataset:
            return False
        if self.module is None:
            return True
        if self.any_position:
            return any(self._position_matches(rec, i) for i in range(rec.depth))
        return self._position_matches(rec, rec.depth - 1)


def rank_key(rec: CatalogRecord) -> tuple:
    """Ranking: most-reused first, then deepest (a deeper reusable prefix
    skips more work), then most recently touched; key breaks ties so the
    order is deterministic across processes."""
    return (-rec.n_loads, -rec.depth, -rec.last_used_at, rec.key)
