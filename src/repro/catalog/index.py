"""In-memory inverted index over :class:`CatalogRecord`s.

The index is the query engine shared by every deployment: in-process it is
the client's write-through view, cross-process it lives inside
``StoreServer`` (op family ``catalog_*``), and in a cluster each shard
holds the slice for the blobs it replicates.

Postings are *loose* pre-filters — e.g. ``by_param`` keys on
``(module, name, encoded_value)`` regardless of chain position, so a chain
that repeats a module id can over-match — and :meth:`CatalogQuery.matches`
is always applied as the final exact predicate.  That keeps the postings
simple and the results correct.

Thread safety: all public methods take the internal lock.  ``upsert`` /
``discard`` are cheap dict/set updates, safe to call from eviction
listeners that run under the store lock (no IO, no re-entry into the
store).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Iterable, Mapping

from .records import CatalogQuery, CatalogRecord, rank_key


class CatalogIndex:
    """Postings + exact-match query over catalog records."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self._records: dict[str, CatalogRecord] = {}
        # posting lists: loose pre-filters, each a set of record keys
        self._by_terminal: dict[str, set[str]] = {}
        self._by_member: dict[str, set[str]] = {}
        self._by_param: dict[tuple[str, str, str], set[str]] = {}
        self._by_dataset: dict[str, set[str]] = {}
        self._by_namespace: dict[str, set[str]] = {}
        self._mutations = 0  # monotonic; lets owners batch persistence

    # -- write path --------------------------------------------------------
    def _index_one(self, rec: CatalogRecord) -> None:
        key = rec.key
        self._by_terminal.setdefault(rec.module, set()).add(key)
        self._by_dataset.setdefault(rec.dataset, set()).add(key)
        self._by_namespace.setdefault(rec.namespace, set()).add(key)
        for module_id, state in zip(rec.modules, rec.states):
            self._by_member.setdefault(module_id, set()).add(key)
            for name, enc in state.items():
                self._by_param.setdefault((module_id, name, enc), set()).add(key)

    def _unindex_one(self, rec: CatalogRecord) -> None:
        key = rec.key

        def drop(table: dict, k: Any) -> None:
            bucket = table.get(k)
            if bucket is not None:
                bucket.discard(key)
                if not bucket:
                    del table[k]

        drop(self._by_terminal, rec.module)
        drop(self._by_dataset, rec.dataset)
        drop(self._by_namespace, rec.namespace)
        for module_id, state in zip(rec.modules, rec.states):
            drop(self._by_member, module_id)
            for name, enc in state.items():
                drop(self._by_param, (module_id, name, enc))

    def upsert(self, rec: CatalogRecord) -> None:
        with self._lock:
            old = self._records.get(rec.key)
            if old is not None:
                # keep the best-known stats: an upsert from a re-admission
                # must not erase reuse counters accumulated earlier
                if old.n_loads > rec.n_loads:
                    rec.n_loads = old.n_loads
                if old.last_used_at > rec.last_used_at:
                    rec.last_used_at = old.last_used_at
                if old.created_at and (
                    not rec.created_at or old.created_at < rec.created_at
                ):
                    rec.created_at = old.created_at
                self._unindex_one(old)
            self._records[rec.key] = rec
            self._index_one(rec)
            self._mutations += 1

    def touch(
        self, key: str, *, last_used_at: float | None = None, n_loads: int | None = None
    ) -> bool:
        """Update reuse stats for one record (no reindex needed — stats are
        not posting terms).  Returns False when the key is unknown."""
        with self._lock:
            rec = self._records.get(key)
            if rec is None:
                return False
            if last_used_at is not None and last_used_at > rec.last_used_at:
                rec.last_used_at = last_used_at
            if n_loads is not None and n_loads > rec.n_loads:
                rec.n_loads = n_loads
            self._mutations += 1
            return True

    def discard(self, key: str) -> bool:
        """Remove one record.  Idempotent; safe inside eviction listeners."""
        with self._lock:
            rec = self._records.pop(key, None)
            if rec is None:
                return False
            self._unindex_one(rec)
            self._mutations += 1
            return True

    def clear(self) -> None:
        with self._lock:
            self._records.clear()
            self._by_terminal.clear()
            self._by_member.clear()
            self._by_param.clear()
            self._by_dataset.clear()
            self._by_namespace.clear()
            self._mutations += 1

    # -- read path ---------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._records

    def get(self, key: str) -> CatalogRecord | None:
        with self._lock:
            return self._records.get(key)

    @property
    def mutations(self) -> int:
        with self._lock:
            return self._mutations

    def _candidates(self, q: CatalogQuery) -> set[str] | None:
        """Intersect the applicable posting lists; ``None`` means "all"."""
        pools: list[set[str]] = []
        if q.module is not None:
            table = self._by_member if q.any_position else self._by_terminal
            pools.append(table.get(q.module, set()))
            for name, enc in q.params.items():
                pools.append(self._by_param.get((q.module, name, enc), set()))
        if q.dataset is not None:
            pools.append(self._by_dataset.get(q.dataset, set()))
        if q.namespace is not None:
            pools.append(self._by_namespace.get(q.namespace, set()))
        if not pools:
            return None
        pools.sort(key=len)  # start from the rarest term
        out = set(pools[0])
        for p in pools[1:]:
            out &= p
            if not out:
                break
        return out

    def query(self, q: CatalogQuery) -> list[CatalogRecord]:
        """Ranked exact matches, at most ``q.limit`` of them."""
        with self._lock:
            keys = self._candidates(q)
            pool: Iterable[CatalogRecord]
            if keys is None:
                pool = list(self._records.values())
            else:
                pool = [self._records[k] for k in keys]
            hits = [r for r in pool if q.matches(r)]
        hits.sort(key=rank_key)
        return hits[: q.limit]

    def snapshot(self) -> list[dict]:
        """All records as JSON documents (persistence / wire transfer)."""
        with self._lock:
            return [r.to_doc() for r in self._records.values()]

    def load(self, docs: Iterable[Mapping[str, Any]]) -> int:
        """Bulk-load documents (replaces nothing — upserts).  Malformed
        documents are skipped: a damaged catalog file must not take the
        store down with it."""
        n = 0
        for doc in docs:
            try:
                rec = CatalogRecord.from_doc(doc)
            except (KeyError, ValueError, TypeError, AttributeError):
                continue
            self.upsert(rec)
            n += 1
        return n

    def prune(self, is_present: Callable[[str], bool]) -> int:
        """Drop records whose artifact no longer exists (used after
        loading a persisted snapshot that may have raced evictions)."""
        with self._lock:
            stale = [k for k in self._records if not is_present(k)]
        for k in stale:
            self.discard(k)
        return len(stale)
