"""The :class:`Catalog` facade — one publish/discard/find surface for all
three deployments.

* **in-process** — records live in the local :class:`CatalogIndex` and
  persist as ``catalog.json`` through the store's backend (batched, like
  ``index.json``).
* **cross-process** — the backend is a ``RemoteBackend``: every publish is
  mirrored to the server's index (``catalog_put``), queries prefer the
  server's view (it survives client churn and sees every writer), and
  persistence is the *server's* job.
* **cluster** — the backend is a ``ShardedBackend``: publishes land on the
  same replica set as the blobs they describe, queries fan out per shard
  and merge here.

Consistency is event-driven, never scan-driven: admission publishes
(``admit_and_store``), the store's evict listeners call :meth:`discard`
(in-memory only — listeners run under the store lock), and server-side
deletes prune the server's index directly, so budget evictions converge on
every deployment without anyone re-reading ``index.json``.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Mapping, Sequence

from ..core.backends import BackendUnavailable
from ..core.store import ArtifactRecord
from ..core.workflow import PrefixKey
from .index import CatalogIndex
from .records import CatalogQuery, CatalogRecord, rank_key, record_for_prefix

CATALOG_META = "catalog.json"


def _supports_remote_catalog(backend: Any) -> bool:
    return callable(getattr(backend, "catalog_put", None)) and callable(
        getattr(backend, "catalog_query", None)
    )


class Catalog:
    """Provenance index over the artifact space.

    Parameters
    ----------
    backend: the store's storage backend.  When it speaks the catalog op
        family (``RemoteBackend``/``ShardedBackend``), publishes are
        mirrored there and queries prefer its merged view; otherwise the
        catalog is purely local.
    persist: persist the local index as ``catalog.json`` through the
        backend's meta channel.  Defaults to on for local backends and off
        for remote ones (each server persists its own slice).
    flush_every: batch local persistence — write ``catalog.json`` after at
        most this many mutations (and on :meth:`flush`/:meth:`close`).
    """

    def __init__(
        self,
        backend: Any = None,
        *,
        persist: bool | None = None,
        flush_every: int = 64,
    ) -> None:
        self.index = CatalogIndex()
        self.backend = backend
        self._remote = backend if _supports_remote_catalog(backend) else None
        can_persist = backend is not None and callable(
            getattr(backend, "write_meta", None)
        )
        self.persist = (
            persist if persist is not None else (can_persist and self._remote is None)
        )
        self.flush_every = max(1, flush_every)
        self._flush_lock = threading.Lock()
        self._flushed_at_mutation = 0
        self._dirty = False
        # observability (tests + benchmarks assert on these)
        self.publish_failures = 0  # best-effort remote mirrors that failed
        self.remote_queries = 0
        self.local_queries = 0
        if self.persist:
            self._load()

    # -- persistence (local mode) -------------------------------------------
    def _load(self) -> None:
        try:
            raw = self.backend.read_meta(CATALOG_META)
        except BackendUnavailable:
            return
        if not raw:
            return
        try:
            docs = json.loads(raw)
        except json.JSONDecodeError:
            return  # damaged snapshot: rebuilt by future publishes
        if isinstance(docs, list):
            self.index.load(docs)
        self._flushed_at_mutation = self.index.mutations
        self._dirty = False

    def _flush_now(self) -> None:
        with self._flush_lock:
            snapshot = self.index.snapshot()
            mutations = self.index.mutations
            try:
                self.backend.write_meta(CATALOG_META, json.dumps(snapshot))
            except BackendUnavailable:
                return  # stays dirty; retried on the next mutation/flush
            self._flushed_at_mutation = mutations
            self._dirty = self.index.mutations != mutations

    def _mark_dirty(self) -> None:
        if not self.persist:
            return
        self._dirty = True
        if self.index.mutations - self._flushed_at_mutation >= self.flush_every:
            self._flush_now()

    def flush(self) -> None:
        """Persist the local index now if it has unflushed mutations."""
        if self.persist and self._dirty:
            self._flush_now()

    def close(self) -> None:
        self.flush()

    # -- write path ----------------------------------------------------------
    def publish(
        self,
        prefix: PrefixKey,
        key: str,
        record: "ArtifactRecord | None" = None,
        *,
        compute_s: float | None = None,
    ) -> CatalogRecord:
        """Index one admitted artifact.  Called from the admission seam
        (AFTER the store's ``put`` returns — never under the store lock).
        The remote mirror is best-effort: an unreachable server only costs a
        counter bump, the local view stays correct, and the server's index
        self-heals on the next publish of the same key."""
        rec = record_for_prefix(
            prefix,
            key,
            nbytes=int(getattr(record, "nbytes_disk", 0) or 0),
            compute_s=(
                compute_s
                if compute_s is not None
                else getattr(record, "compute_s", None)
            ),
            created_at=getattr(record, "created_at", None),
            last_used_at=float(getattr(record, "last_used_at", 0.0) or 0.0),
            n_loads=int(getattr(record, "n_loads", 0) or 0),
        )
        self.index.upsert(rec)
        self._mark_dirty()
        if self._remote is not None:
            # the net layer swallows transport errors (returns False) so a
            # flapping shard can't fail an admission that already landed
            if not self._remote.catalog_put(rec.to_doc()):
                self.publish_failures += 1
        return rec

    def discard(self, key: str) -> None:
        """Drop one key from the local view.  Purely in-memory + dirty mark:
        wired as a store evict listener, which runs under the store lock —
        no network, no meta IO, no re-entry into the store."""
        if self.index.discard(key):
            self._dirty = self.persist

    def touch(self, key: str, record: "ArtifactRecord | None" = None) -> None:
        """Refresh reuse stats after a hit (load) of ``key``."""
        if record is None:
            return
        if self.index.touch(
            key,
            last_used_at=float(getattr(record, "last_used_at", 0.0) or 0.0),
            n_loads=int(getattr(record, "n_loads", 0) or 0),
        ):
            self._mark_dirty()

    # -- read path -----------------------------------------------------------
    def find(
        self,
        module: str | None = None,
        params: Mapping[str, Any] | None = None,
        dataset: str | None = None,
        namespace: str | None = None,
        *,
        any_position: bool = False,
        limit: int = 50,
    ) -> list[CatalogRecord]:
        return self.query(
            CatalogQuery.build(
                module=module,
                params=params,
                dataset=dataset,
                namespace=namespace,
                any_position=any_position,
                limit=limit,
            )
        )

    def query(self, q: CatalogQuery) -> list[CatalogRecord]:
        """Ranked matches.  Remote-backed catalogs merge the server-side
        answer (authoritative across clients) with the local index (covers
        records whose best-effort mirror failed); dedup is by key, keeping
        the freshest stats."""
        local = self.index.query(q)
        remote_docs = self._query_remote(q)
        if remote_docs is None:
            self.local_queries += 1
            return local
        self.remote_queries += 1
        merged: dict[str, CatalogRecord] = {}
        for doc in remote_docs:
            try:
                rec = CatalogRecord.from_doc(doc)
            except (KeyError, ValueError, TypeError):
                continue
            if q.matches(rec):  # never trust a remote to have filtered right
                merged[rec.key] = rec
        for rec in local:
            old = merged.get(rec.key)
            if old is None or rec.last_used_at > old.last_used_at:
                merged[rec.key] = rec
        hits = sorted(merged.values(), key=rank_key)
        return hits[: q.limit]

    def _query_remote(self, q: CatalogQuery) -> "list[dict] | None":
        if self._remote is None:
            return None
        # None = unsupported server or pool unreachable: serve the local view
        return self._remote.catalog_query(q.to_doc())

    # -- consistency helpers ---------------------------------------------------
    def verify_present(
        self, records: Sequence[CatalogRecord], presence: Mapping[str, str]
    ) -> list[CatalogRecord]:
        """Filter records by a ``has_state_many`` answer, pruning the local
        index for authoritative absences (zero-phantom guarantee: a caller
        that verified gets only records whose artifact is readable *now*)."""
        out: list[CatalogRecord] = []
        for rec in records:
            state = presence.get(rec.key, "absent")
            if state == "present":
                out.append(rec)
            elif state == "absent":
                self.discard(rec.key)
        return out
