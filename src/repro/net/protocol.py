"""Wire protocol for the cross-process intermediate-data store.

One *frame* carries one message in either direction::

    +----------------+----------------+----------------+---------------+
    | header_len: 4B | payload_len: 8B| header (JSON)  | payload bytes |
    +----------------+----------------+----------------+---------------+

Lengths are big-endian.  The header is a small JSON object (``op`` and its
arguments on requests; ``ok`` plus result fields on responses); the payload
is the raw blob bytes (requests: ``write_blob``/``write_meta``; responses:
``read_blob``/``read_meta``).  Blob frames carry a ``digest`` field — the
SHA-256 hex of the payload — verified on both ends, so a flipped bit on the
wire (or a blob corrupted at rest) surfaces as :class:`IntegrityError`
instead of silently poisoning a downstream module.

A clean EOF *between* frames is a normal connection close
(:class:`ConnectionClosed`); an EOF *inside* a frame is a truncated frame
(:class:`ProtocolError`) — the distinction is what lets the client safely
retry idempotent requests after a server restart.

Wire format v2 (negotiated per feature, v1 peers keep working — see
``docs/remote.md``) adds a *chunked transfer mode* on top of the same frame
grammar: a large blob travels as a sequence of fixed-size chunk frames
(header ``{"c": 1}``) bounded by :data:`MAX_CHUNK_BYTES`, terminated by an
end frame (``{"end": true, "digest": <sha256-hex>}``) carrying the digest
folded incrementally as the chunks were produced.  Both endpoints process
the stream through a bounded buffer, so memory stays constant regardless of
blob size; the receiver verifies the folded digest at stream end.  A torn
stream (EOF inside a chunk frame) is a :class:`ProtocolError` exactly like
any other truncation.  v2 also adds a ``batch`` op coalescing small
presence/metadata requests into one round trip.
"""
from __future__ import annotations

import hashlib
import json
import os
import queue
import socket
import struct
import threading
from typing import Any

from ..core.backends import BackendUnavailable

_FRAME = struct.Struct(">IQ")  # header_len, payload_len

MAX_HEADER_BYTES = 1 << 20  # 1 MiB of JSON is already absurd
MAX_PAYLOAD_BYTES = 1 << 40  # sanity bound, not a quota

PROTO_VERSION = 2  # chunked transfer + batch; one-shot ops unchanged from v1
DEFAULT_CHUNK_BYTES = 4 << 20  # stream chunk size (bounded-buffer unit)
MAX_CHUNK_BYTES = 64 << 20  # a "chunk" frame above this is a protocol error
MAX_BATCH_OPS = 4096  # sub-ops per batch request

DEFAULT_PORT = 7077

_CHUNK_HDR = b'{"c":1}'  # pre-encoded per-chunk frame header


class ProtocolError(RuntimeError):
    """Malformed or truncated frame."""


class ConnectionClosed(ProtocolError):
    """Peer closed the connection at a frame boundary (normal teardown)."""


class IntegrityError(ProtocolError):
    """Payload bytes do not match their declared content digest."""


class RemoteStoreError(RuntimeError):
    """The store service failed a request (server-reported or transport)."""


class StoreUnreachable(RemoteStoreError, BackendUnavailable):
    """No server — or, in cluster mode, no replica of the key — could be
    reached at all.  Distinct from a server-*reported* failure (a reachable
    shard rejecting a bad request or hitting a disk error must not be
    treated as dead).  Subclasses
    :class:`~repro.core.backends.BackendUnavailable` so layers above the
    backend seam (store, scheduler) can degrade to recompute without
    importing ``repro.net``."""


def digest(data: bytes | bytearray | memoryview) -> str:
    return hashlib.sha256(data).hexdigest()


# payloads above this are sent as a second sendall on the raw buffer instead
# of being copied into one concatenated frame — a one-shot multi-GB blob must
# not cost an extra full-size allocation+memcpy just to prepend 12+N bytes
_INLINE_SEND_BYTES = 1 << 16


def send_frame(
    sock: socket.socket,
    header: dict[str, Any] | bytes,
    payload: bytes | bytearray | memoryview = b"",
) -> None:
    head = (
        header
        if isinstance(header, bytes)
        else json.dumps(header, separators=(",", ":")).encode()
    )
    if len(head) > MAX_HEADER_BYTES:
        raise ProtocolError(f"header too large: {len(head)} bytes")
    prefix = _FRAME.pack(len(head), len(payload)) + head
    if len(payload) <= _INLINE_SEND_BYTES:
        # one sendall: small frames leave in a single segment
        sock.sendall(prefix + payload)
    else:
        sock.sendall(prefix)
        sock.sendall(payload)  # memoryview-aware: no concatenation copy


def recv_exact_into(
    sock: socket.socket, view: memoryview, *, at_boundary: bool = False
) -> None:
    """Fill ``view`` exactly, reading into it without intermediate copies.
    ``at_boundary`` marks the read that starts a frame: EOF there is a clean
    close, EOF elsewhere a truncation."""
    n = len(view)
    got = 0
    while got < n:
        r = sock.recv_into(view[got:], min(n - got, 1 << 20))
        if not r:
            if at_boundary and got == 0:
                raise ConnectionClosed("peer closed the connection")
            raise ProtocolError(f"truncated frame: expected {n} bytes, got {got}")
        got += r


def recv_exact(sock: socket.socket, n: int, *, at_boundary: bool = False) -> bytes:
    """Read exactly ``n`` bytes.  ``at_boundary`` marks the read that starts
    a frame: EOF there is a clean close, EOF elsewhere a truncation."""
    if n == 0:
        return b""
    buf = bytearray(n)
    recv_exact_into(sock, memoryview(buf), at_boundary=at_boundary)
    return bytes(buf)


def _recv_prefix(sock: socket.socket) -> tuple[int, int]:
    raw = recv_exact(sock, _FRAME.size, at_boundary=True)
    header_len, payload_len = _FRAME.unpack(raw)
    if header_len > MAX_HEADER_BYTES or payload_len > MAX_PAYLOAD_BYTES:
        raise ProtocolError(
            f"frame lengths out of range: header={header_len} payload={payload_len}"
        )
    return header_len, payload_len


def _parse_header(raw: bytes) -> dict[str, Any]:
    try:
        header = json.loads(raw)
    except json.JSONDecodeError as e:
        raise ProtocolError(f"unparseable frame header: {e}") from e
    if not isinstance(header, dict):
        raise ProtocolError(f"frame header must be an object, got {type(header).__name__}")
    return header


def recv_frame(sock: socket.socket) -> tuple[dict[str, Any], bytes]:
    header_len, payload_len = _recv_prefix(sock)
    header = _parse_header(recv_exact(sock, header_len))
    payload = recv_exact(sock, payload_len) if payload_len else b""
    return header, payload


def recv_frame_into(
    sock: socket.socket, view: memoryview
) -> tuple[dict[str, Any], int]:
    """Like :func:`recv_frame` but receives the payload *into* ``view`` (no
    allocation — the stream loops reuse one bounded buffer, which is what
    keeps memory constant for arbitrarily large blobs).  Returns ``(header,
    payload_len)``; a payload larger than ``view`` is a protocol error."""
    header_len, payload_len = _recv_prefix(sock)
    header = _parse_header(recv_exact(sock, header_len))
    if payload_len > len(view):
        raise ProtocolError(
            f"stream chunk of {payload_len} bytes exceeds the "
            f"{len(view)}-byte receive window"
        )
    if payload_len:
        recv_exact_into(sock, view[:payload_len])
    return header, payload_len


# -- chunked transfer mode (wire format v2) -----------------------------------
def send_chunk(sock: socket.socket, payload: bytes | bytearray | memoryview) -> None:
    """One fixed-size chunk frame of a v2 stream."""
    if len(payload) > MAX_CHUNK_BYTES:
        raise ProtocolError(f"chunk of {len(payload)} bytes exceeds MAX_CHUNK_BYTES")
    send_frame(sock, _CHUNK_HDR, payload)


def send_chunk_prefix(sock: socket.socket, payload_len: int) -> None:
    """Frame prefix + header of a chunk whose payload the caller will push
    itself (``os.sendfile`` from a backend file straight into the socket —
    the payload bytes never enter userspace)."""
    if payload_len > MAX_CHUNK_BYTES:
        raise ProtocolError(f"chunk of {payload_len} bytes exceeds MAX_CHUNK_BYTES")
    sock.sendall(_FRAME.pack(len(_CHUNK_HDR), payload_len) + _CHUNK_HDR)


def send_stream_end(
    sock: socket.socket,
    *,
    digest_hex: str | None = None,
    abort: bool = False,
    error: str = "",
    kind: str = "server",
) -> None:
    """Terminal frame of a v2 stream: the folded digest on success, or an
    abort marker (with an error for the peer to surface) on failure."""
    end: dict[str, Any] = {"end": True}
    if abort:
        end.update(abort=True, error=error, kind=kind)
    else:
        end["digest"] = digest_hex
    send_frame(sock, end)


def send_blob_stream(
    sock: socket.socket,
    data: bytes | bytearray | memoryview,
    chunk_bytes: int = DEFAULT_CHUNK_BYTES,
) -> str:
    """Stream an in-memory buffer as chunk frames + end frame, folding the
    SHA-256 incrementally; chunks are memoryview slices (zero copies).
    Returns the hex digest that was declared in the end frame."""
    chunk_bytes = max(1, min(chunk_bytes, MAX_CHUNK_BYTES))
    mv = memoryview(data)
    sha = hashlib.sha256()
    for off in range(0, len(mv), chunk_bytes):
        piece = mv[off : off + chunk_bytes]
        sha.update(piece)
        send_chunk(sock, piece)
    hexd = sha.hexdigest()
    send_stream_end(sock, digest_hex=hexd)
    return hexd


def recv_blob_stream(
    sock: socket.socket, size: int, *, overlap_fold: bool | None = None
) -> tuple[bytearray, str, dict]:
    """Receive a v2 stream of exactly ``size`` payload bytes into one
    preallocated buffer, folding SHA-256 as chunks arrive.

    Returns ``(buffer, folded_digest_hex, end_header)``; the caller compares
    the folded digest to the end frame's declared one.  On an abort end frame
    the buffer is partial and ``end_header["abort"]`` is set.  Overrun (more
    payload than announced) and truncation are :class:`ProtocolError`\\ s.

    ``overlap_fold`` moves the digest fold to a worker thread so hashing
    chunk N overlaps receiving chunk N+1 (sha256 releases the GIL).  The
    default (``None``) enables it for multi-chunk streams on multi-core
    hosts only — on a single CPU the fold cannot run concurrently and the
    thread is pure overhead.
    """
    if size < 0 or size > MAX_PAYLOAD_BYTES:
        raise ProtocolError(f"stream size out of range: {size}")
    buf = bytearray(size)
    view = memoryview(buf)
    sha = hashlib.sha256()
    got = 0
    if overlap_fold is None:
        overlap_fold = size > DEFAULT_CHUNK_BYTES and (os.cpu_count() or 1) > 1
    # Each chunk lands in its own disjoint slice of ``buf`` and is never
    # rewritten, so the fold can safely run one chunk behind the socket.
    folder = _StreamFolder(sha) if overlap_fold else None
    try:
        while True:
            header, n = recv_frame_into(sock, view[got:])
            if header.get("end"):
                if not header.get("abort") and got != size:
                    raise ProtocolError(
                        f"stream ended early: expected {size} bytes, got {got}"
                    )
                if folder is not None:
                    folder.finish()
                    folder = None
                return buf, sha.hexdigest(), header
            if n > 0:
                if folder is not None:
                    folder.feed(view[got : got + n])
                else:
                    sha.update(view[got : got + n])
                got += n
            if got > size:  # unreachable (recv_frame_into bounds it) — belt
                raise ProtocolError("stream overran its announced size")
    finally:
        if folder is not None:
            folder.finish()


class _StreamFolder:
    """Folds SHA-256 over buffer slices on a worker thread, in feed order."""

    def __init__(self, sha) -> None:
        self._sha = sha
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            self._sha.update(item)

    def feed(self, view: memoryview) -> None:
        self._q.put(view)

    def finish(self) -> None:
        """Drain the queue and join; after this the sha holds every fed byte."""
        self._q.put(None)
        self._thread.join()


def parse_url(url: str) -> tuple[str, int]:
    """``tcp://host:port`` / ``host:port`` / ``host`` -> ``(host, port)``."""
    rest = url[len("tcp://"):] if url.startswith("tcp://") else url
    if "/" in rest:
        raise ValueError(f"store url must not carry a path: {url!r}")
    host, sep, port = rest.rpartition(":")
    if not sep:
        return rest or "127.0.0.1", DEFAULT_PORT
    try:
        return host or "127.0.0.1", int(port)
    except ValueError:
        raise ValueError(f"bad port in store url {url!r}") from None


def parse_urls(url: str) -> list[tuple[str, int]]:
    """Comma-separated cluster membership -> ordered ``(host, port)`` list.

    ``"tcp://h:7077,h:7078,other:7077"`` — the scheme prefix may appear on
    any (or no) member.  Order is irrelevant to routing (the hash ring sorts
    members canonically) but duplicates are rejected: a member listed twice
    would silently halve its effective replication.
    """
    endpoints = [parse_url(part.strip()) for part in url.split(",") if part.strip()]
    if not endpoints:
        raise ValueError(f"no endpoints in store url {url!r}")
    if len(set(endpoints)) != len(endpoints):
        raise ValueError(f"duplicate endpoints in store url {url!r}")
    return endpoints
