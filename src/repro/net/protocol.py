"""Wire protocol for the cross-process intermediate-data store.

One *frame* carries one message in either direction::

    +----------------+----------------+----------------+---------------+
    | header_len: 4B | payload_len: 8B| header (JSON)  | payload bytes |
    +----------------+----------------+----------------+---------------+

Lengths are big-endian.  The header is a small JSON object (``op`` and its
arguments on requests; ``ok`` plus result fields on responses); the payload
is the raw blob bytes (requests: ``write_blob``/``write_meta``; responses:
``read_blob``/``read_meta``).  Blob frames carry a ``digest`` field — the
SHA-256 hex of the payload — verified on both ends, so a flipped bit on the
wire (or a blob corrupted at rest) surfaces as :class:`IntegrityError`
instead of silently poisoning a downstream module.

A clean EOF *between* frames is a normal connection close
(:class:`ConnectionClosed`); an EOF *inside* a frame is a truncated frame
(:class:`ProtocolError`) — the distinction is what lets the client safely
retry idempotent requests after a server restart.
"""
from __future__ import annotations

import hashlib
import json
import socket
import struct
from typing import Any

from ..core.backends import BackendUnavailable

_FRAME = struct.Struct(">IQ")  # header_len, payload_len

MAX_HEADER_BYTES = 1 << 20  # 1 MiB of JSON is already absurd
MAX_PAYLOAD_BYTES = 1 << 40  # sanity bound, not a quota

DEFAULT_PORT = 7077


class ProtocolError(RuntimeError):
    """Malformed or truncated frame."""


class ConnectionClosed(ProtocolError):
    """Peer closed the connection at a frame boundary (normal teardown)."""


class IntegrityError(ProtocolError):
    """Payload bytes do not match their declared content digest."""


class RemoteStoreError(RuntimeError):
    """The store service failed a request (server-reported or transport)."""


class StoreUnreachable(RemoteStoreError, BackendUnavailable):
    """No server — or, in cluster mode, no replica of the key — could be
    reached at all.  Distinct from a server-*reported* failure (a reachable
    shard rejecting a bad request or hitting a disk error must not be
    treated as dead).  Subclasses
    :class:`~repro.core.backends.BackendUnavailable` so layers above the
    backend seam (store, scheduler) can degrade to recompute without
    importing ``repro.net``."""


def digest(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def send_frame(sock: socket.socket, header: dict[str, Any], payload: bytes = b"") -> None:
    head = json.dumps(header, separators=(",", ":")).encode()
    if len(head) > MAX_HEADER_BYTES:
        raise ProtocolError(f"header too large: {len(head)} bytes")
    # one sendall: small frames leave in a single segment
    sock.sendall(_FRAME.pack(len(head), len(payload)) + head + payload)


def recv_exact(sock: socket.socket, n: int, *, at_boundary: bool = False) -> bytes:
    """Read exactly ``n`` bytes.  ``at_boundary`` marks the read that starts
    a frame: EOF there is a clean close, EOF elsewhere a truncation."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        chunk = sock.recv(min(n - got, 1 << 20))
        if not chunk:
            if at_boundary and got == 0:
                raise ConnectionClosed("peer closed the connection")
            raise ProtocolError(f"truncated frame: expected {n} bytes, got {got}")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def recv_frame(sock: socket.socket) -> tuple[dict[str, Any], bytes]:
    raw = recv_exact(sock, _FRAME.size, at_boundary=True)
    header_len, payload_len = _FRAME.unpack(raw)
    if header_len > MAX_HEADER_BYTES or payload_len > MAX_PAYLOAD_BYTES:
        raise ProtocolError(
            f"frame lengths out of range: header={header_len} payload={payload_len}"
        )
    try:
        header = json.loads(recv_exact(sock, header_len))
    except json.JSONDecodeError as e:
        raise ProtocolError(f"unparseable frame header: {e}") from e
    if not isinstance(header, dict):
        raise ProtocolError(f"frame header must be an object, got {type(header).__name__}")
    payload = recv_exact(sock, payload_len) if payload_len else b""
    return header, payload


def parse_url(url: str) -> tuple[str, int]:
    """``tcp://host:port`` / ``host:port`` / ``host`` -> ``(host, port)``."""
    rest = url[len("tcp://"):] if url.startswith("tcp://") else url
    if "/" in rest:
        raise ValueError(f"store url must not carry a path: {url!r}")
    host, sep, port = rest.rpartition(":")
    if not sep:
        return rest or "127.0.0.1", DEFAULT_PORT
    try:
        return host or "127.0.0.1", int(port)
    except ValueError:
        raise ValueError(f"bad port in store url {url!r}") from None


def parse_urls(url: str) -> list[tuple[str, int]]:
    """Comma-separated cluster membership -> ordered ``(host, port)`` list.

    ``"tcp://h:7077,h:7078,other:7077"`` — the scheme prefix may appear on
    any (or no) member.  Order is irrelevant to routing (the hash ring sorts
    members canonically) but duplicates are rejected: a member listed twice
    would silently halve its effective replication.
    """
    endpoints = [parse_url(part.strip()) for part in url.split(",") if part.strip()]
    if not endpoints:
        raise ValueError(f"no endpoints in store url {url!r}")
    if len(set(endpoints)) != len(endpoints):
        raise ValueError(f"duplicate endpoints in store url {url!r}")
    return endpoints
