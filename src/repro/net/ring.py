"""Consistent-hash ring: static cluster membership -> per-key shard routing.

The sharded store routes every artifact key (and every meta name) onto an
ordered *preference list* of shards: the key's position on the ring picks
its **primary**, and walking the ring clockwise yields the failover /
replication order.  Consistent hashing — rather than ``hash(key) % N`` —
keeps two properties the cluster leans on:

  * **stability** — membership is part of the configuration every client
    shares (``Client(store_url="h:p1,h:p2,h:p3")``); any process that hashes
    the same member list routes every key identically, with no coordination.
    Removing one member remaps only the keys that lived on it.
  * **spread** — each member is hashed onto the ring at many *virtual
    points*, so the keyspace splits near-uniformly even with 3 shards
    (a single point per shard can skew arc lengths by several x).

Keys here are the store's ``PrefixKey`` digests — high-entropy strings — so
SHA-256 of ``key`` is an unbiased ring position.  The ring is immutable
after construction: membership changes are a *deployment* action (restart
clients with the new list), which is the static-membership contract
``docs/remote.md`` documents.
"""
from __future__ import annotations

import bisect
import hashlib
from typing import Iterable, Sequence


def _point(label: str) -> int:
    """Ring position of ``label``: first 8 bytes of SHA-256, big-endian."""
    return int.from_bytes(hashlib.sha256(label.encode()).digest()[:8], "big")


class HashRing:
    """Immutable consistent-hash ring over a static member list."""

    def __init__(self, nodes: Iterable[str], vnodes: int = 64) -> None:
        self.nodes: tuple[str, ...] = tuple(dict.fromkeys(nodes))
        if not self.nodes:
            raise ValueError("a hash ring needs at least one node")
        if vnodes < 1:
            raise ValueError("vnodes must be >= 1")
        self.vnodes = vnodes
        points: list[tuple[int, str]] = []
        for node in self.nodes:
            for v in range(vnodes):
                points.append((_point(f"{node}#{v}"), node))
        points.sort()
        self._points = points
        self._hashes = [h for h, _ in points]

    def order(self, key: str) -> list[str]:
        """Every node, in ring-walk (preference) order for ``key``.

        Index 0 is the key's primary; successive entries are the failover /
        replica targets.  Walking clockwise from the key's hash and keeping
        the first appearance of each node makes the order consistent across
        processes and stable under key-space shifts.
        """
        if len(self.nodes) == 1:
            return [self.nodes[0]]
        start = bisect.bisect_right(self._hashes, _point(key)) % len(self._points)
        seen: set[str] = set()
        out: list[str] = []
        n = len(self._points)
        for i in range(n):
            node = self._points[(start + i) % n][1]
            if node not in seen:
                seen.add(node)
                out.append(node)
                if len(out) == len(self.nodes):
                    break
        return out

    def primary(self, key: str) -> str:
        return self.order(key)[0]

    def replicas(self, key: str, r: int) -> list[str]:
        """The key's first ``min(r, len(nodes))`` preferred nodes (>= 1)."""
        return self.order(key)[: max(1, min(r, len(self.nodes)))]

    def spread(self, keys: Sequence[str]) -> dict[str, int]:
        """Primary-assignment histogram (diagnostics / balance tests)."""
        counts = {n: 0 for n in self.nodes}
        for k in keys:
            counts[self.primary(k)] += 1
        return counts

    def __len__(self) -> int:
        return len(self.nodes)

    def __repr__(self) -> str:
        return f"HashRing({list(self.nodes)!r}, vnodes={self.vnodes})"
