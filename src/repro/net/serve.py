"""``python -m repro.net.serve`` — run a store server over a directory.

Example::

    python -m repro.net.serve --root /var/lib/repro-store --port 7077

Clients then mount the pool with ``repro.api.Client(store_url="tcp://host:7077")``
or ``IntermediateStore(backend=RemoteBackend("tcp://host:7077"))``.

A *cluster* is simply N of these processes, each over its **own** root
directory (never a shared one — a shard owns its bytes), mounted together:
``Client(store_url="h:7077,h:7078,h:7079", replication=2)``.  Routing,
replication, and failover are entirely client-side (see ``docs/remote.md``,
"Cluster mode"); the servers need not know about each other.
"""
from __future__ import annotations

import argparse
import signal
import sys

from ..core.backends import LocalFSBackend, MemoryBackend, TieredBackend
from .protocol import DEFAULT_PORT
from .server import StoreServer


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.net.serve",
        description="Serve a directory as a shared intermediate-data store.",
    )
    parser.add_argument("--root", required=True, help="artifact directory")
    parser.add_argument(
        "--host",
        default="127.0.0.1",
        help="bind address; the protocol is unauthenticated, so expose it "
        "beyond loopback (--host 0.0.0.0) only on a trusted network",
    )
    parser.add_argument("--port", type=int, default=DEFAULT_PORT)
    parser.add_argument(
        "--hot-mb",
        type=int,
        default=0,
        help="optional in-memory hot tier (MiB); 0 disables tiering",
    )
    args = parser.parse_args(argv)

    backend = LocalFSBackend(args.root)
    if args.hot_mb > 0:
        backend = TieredBackend(
            backend, MemoryBackend(), hot_capacity_bytes=args.hot_mb << 20
        )
    server = StoreServer(backend, host=args.host, port=args.port)
    server.start()
    print(f"store server listening on {server.url} (root={args.root})", flush=True)

    signal.signal(signal.SIGTERM, lambda *_: server.stop())
    signal.signal(signal.SIGINT, lambda *_: server.stop())
    try:
        server.wait()
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
