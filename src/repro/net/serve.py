"""``python -m repro.net.serve`` — run a store server over a directory.

Example::

    python -m repro.net.serve --root /var/lib/repro-store --port 7077

Clients then mount the pool with ``repro.api.Client(store_url="tcp://host:7077")``
or ``IntermediateStore(backend=RemoteBackend("tcp://host:7077"))``.

A *cluster* is simply N of these processes, each over its **own** root
directory (never a shared one — a shard owns its bytes), mounted together:
``Client(store_url="h:7077,h:7078,h:7079", replication=2)``.  Routing,
replication, and failover are entirely client-side (see ``docs/remote.md``,
"Cluster mode"); the servers need not know about each other.
"""
from __future__ import annotations

import argparse
import os
import signal
import sys

from ..core.backends import LocalFSBackend, MemoryBackend, TieredBackend
from ..obs.logging import configure_logging, get_logger
from ..obs.tracing import configure_tracing
from .protocol import DEFAULT_PORT
from .server import StoreServer


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.net.serve",
        description="Serve a directory as a shared intermediate-data store.",
    )
    parser.add_argument("--root", required=True, help="artifact directory")
    parser.add_argument(
        "--host",
        default="127.0.0.1",
        help="bind address; the protocol is unauthenticated, so expose it "
        "beyond loopback (--host 0.0.0.0) only on a trusted network",
    )
    parser.add_argument("--port", type=int, default=DEFAULT_PORT)
    parser.add_argument(
        "--hot-mb",
        type=int,
        default=0,
        help="optional in-memory hot tier (MiB); 0 disables tiering",
    )
    parser.add_argument(
        "--log-level",
        default="info",
        choices=["debug", "info", "warning", "error", "critical"],
        help="logging verbosity for the repro logger tree",
    )
    parser.add_argument(
        "--log-json",
        action="store_true",
        help="emit JSON-lines logs instead of the human-readable format",
    )
    parser.add_argument(
        "--trace-dir",
        default=None,
        help="record spans as NDJSON under this directory (enables tracing; "
        "also reachable via REPRO_TRACE_DIR)",
    )
    parser.add_argument(
        "--service",
        default=os.environ.get("REPRO_SERVICE", "store"),
        help="service name stamped on this process's spans "
        "(default: $REPRO_SERVICE or 'store')",
    )
    args = parser.parse_args(argv)

    configure_logging(args.log_level, json_lines=args.log_json)
    log = get_logger("net.serve")
    if args.trace_dir:
        configure_tracing(args.trace_dir, args.service)

    backend = LocalFSBackend(args.root)
    if args.hot_mb > 0:
        backend = TieredBackend(
            backend, MemoryBackend(), hot_capacity_bytes=args.hot_mb << 20
        )
    server = StoreServer(
        backend, host=args.host, port=args.port, trace_service=args.service
    )
    server.start()
    log.info("store server listening on %s (root=%s)", server.url, args.root)

    signal.signal(signal.SIGTERM, lambda *_: server.stop())
    signal.signal(signal.SIGINT, lambda *_: server.stop())
    try:
        server.wait()
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
    return 0


if __name__ == "__main__":
    sys.exit(main())
