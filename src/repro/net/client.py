"""``RemoteBackend`` — the store-server client, a drop-in ``StorageBackend``.

``IntermediateStore(backend=RemoteBackend("tcp://host:7077"))`` gives any
process a view onto the shared artifact pool with *zero* changes above the
backend seam: serialization, manifests, codecs, eviction accounting, and
policy bookkeeping all keep running client-side; only bytes cross the wire.

Transport properties:

  * **connection pool** — concurrent scheduler threads each check out a
    socket (dialing on demand), so a long lease wait never blocks unrelated
    reads;
  * **reconnect-and-retry** — every request is idempotent at the server, so
    transport failures (server restart, dropped conn, truncated frame)
    are retried on a fresh connection with exponential backoff before an
    error ever reaches the store;
  * **digest verification** — blob reads carry the server's SHA-256 and are
    re-fetched once on mismatch, then fail loudly with ``IntegrityError``;
  * **event subscription** — an optional dedicated connection streams
    server-side eviction events to registered listeners (the store's
    ``on_external_evict``, the read-through cache's ``invalidate``), with
    automatic resubscription after a server restart.
"""
from __future__ import annotations

import socket
import threading
import time
import uuid
from typing import Any, Callable, Iterable, NoReturn

from ..core.backends import StorageBackend
from ..obs import tracing as _tracing
from ..obs.metrics import MetricsRegistry
from .protocol import (
    DEFAULT_CHUNK_BYTES,
    MAX_BATCH_OPS,
    ConnectionClosed,
    IntegrityError,
    ProtocolError,
    RemoteStoreError,
    StoreUnreachable,
    digest,
    parse_url,
    recv_blob_stream,
    recv_frame,
    send_blob_stream,
    send_frame,
)


class LeaseGrant:
    """Outcome of one ``lease_acquire`` round."""

    __slots__ = ("granted", "token", "stored", "timed_out")

    def __init__(self, granted: bool, token: str = "", stored: bool = False,
                 timed_out: bool = False) -> None:
        self.granted = granted
        self.token = token
        self.stored = stored
        self.timed_out = timed_out


class RemoteBackend(StorageBackend):
    """TCP client for a :class:`~repro.net.server.StoreServer`."""

    name = "remote"

    def __init__(
        self,
        url: str,
        *,
        client_id: str | None = None,
        connect_timeout_s: float = 5.0,
        op_timeout_s: float = 120.0,
        retries: int = 5,
        retry_backoff_s: float = 0.05,
        max_pool: int = 8,
        stream_threshold: int = 1 << 20,
        chunk_bytes: int = DEFAULT_CHUNK_BYTES,
        registry: MetricsRegistry | None = None,
    ) -> None:
        self.host, self.port = parse_url(url)
        self.client_id = client_id or f"c-{uuid.uuid4().hex[:12]}"
        self.connect_timeout_s = connect_timeout_s
        self.op_timeout_s = op_timeout_s
        self.retries = retries
        self.retry_backoff_s = retry_backoff_s
        self.max_pool = max_pool
        # blobs at/above stream_threshold travel chunked (wire v2) when the
        # server supports it; negotiation is lazy — the first bad_op reply
        # marks the server v1 and every later op goes one-shot/pipelined
        self.stream_threshold = stream_threshold
        self.chunk_bytes = chunk_bytes
        self._server_proto: int | None = None  # None = not yet probed
        self._server_catalog: bool | None = None  # None = not yet probed
        self._pool: list[socket.socket] = []
        self._pool_lock = threading.Lock()
        self._lease_lock = threading.Lock()
        self._lease_socks: dict[tuple[str, str], socket.socket] = {}
        self._closed = False
        self._listeners: list[Callable[[str, str], None]] = []
        self._listener_lock = threading.Lock()
        self._event_thread: threading.Thread | None = None
        self._event_sock: socket.socket | None = None
        # transport counters live on the unified registry, shard-labeled so a
        # multi-shard client's series stay distinguishable after a merge; the
        # legacy attribute names below are read-only aliases
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._shard = f"{self.host}:{self.port}"
        lbl = {"shard": self._shard}
        self._m_reconnects = self.metrics.counter(
            "repro_remote_reconnects_total", "transport-level redials", ("shard",)
        ).labels(**lbl)
        self._m_streamed_writes = self.metrics.counter(
            "repro_remote_streamed_writes_total",
            "blobs written via chunked streaming",
            ("shard",),
        ).labels(**lbl)
        self._m_streamed_reads = self.metrics.counter(
            "repro_remote_streamed_reads_total",
            "blobs read via chunked streaming",
            ("shard",),
        ).labels(**lbl)
        self._m_batched = self.metrics.counter(
            "repro_remote_batched_requests_total",
            "batch round trips issued",
            ("shard",),
        ).labels(**lbl)
        self._m_rpc_seconds = self.metrics.histogram(
            "repro_remote_rpc_seconds", "remote RPC round-trip time", ("op", "shard")
        )

    # -- deprecated counter aliases ---------------------------------------------
    @property
    def reconnects(self) -> int:
        """Deprecated alias of ``repro_remote_reconnects_total{shard}``."""
        return int(self._m_reconnects.value)

    @property
    def streamed_writes(self) -> int:
        """Deprecated alias of ``repro_remote_streamed_writes_total{shard}``."""
        return int(self._m_streamed_writes.value)

    @property
    def streamed_reads(self) -> int:
        """Deprecated alias of ``repro_remote_streamed_reads_total{shard}``."""
        return int(self._m_streamed_reads.value)

    @property
    def batched_requests(self) -> int:
        """Deprecated alias of ``repro_remote_batched_requests_total{shard}``."""
        return int(self._m_batched.value)

    # -- connection management -------------------------------------------------
    def _dial(self) -> socket.socket:
        sock = socket.create_connection(
            (self.host, self.port), timeout=self.connect_timeout_s
        )
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(self.op_timeout_s)
        return sock

    def _checkout(self) -> socket.socket:
        with self._pool_lock:
            if self._pool:
                return self._pool.pop()
        return self._dial()

    def _checkin(self, sock: socket.socket) -> None:
        with self._pool_lock:
            if not self._closed and len(self._pool) < self.max_pool:
                sock.settimeout(self.op_timeout_s)  # undo per-request overrides
                self._pool.append(sock)
                return
        try:
            sock.close()
        except OSError:
            pass

    def close(self) -> None:
        self._closed = True
        with self._pool_lock:
            pool, self._pool = self._pool, []
        with self._lease_lock:
            pool += list(self._lease_socks.values())  # server auto-releases
            self._lease_socks.clear()
        for sock in pool:
            try:
                sock.close()
            except OSError:
                pass
        if self._event_sock is not None:
            try:
                self._event_sock.close()
            except OSError:
                pass
        if self._event_thread is not None:
            self._event_thread.join(timeout=2)
            self._event_thread = None

    # -- request core ----------------------------------------------------------
    def _scrap(self, sock: socket.socket) -> None:
        """Discard a socket whose framing state is unknown — and its pooled
        siblings, which are almost certainly from the same dead server epoch,
        rather than letting stale sockets burn through the retry budget one
        by one."""
        with self._pool_lock:
            stale, self._pool = self._pool, []
        for s in [sock, *stale]:
            try:
                s.close()
            except OSError:
                pass

    def _with_retries(self, fn: Callable[[socket.socket], Any]) -> Any:
        """Run ``fn(sock)`` on a pooled socket, redialing on transport
        failure with exponential backoff.  ``fn`` owns the socket for its
        whole call — it may exchange *multiple* frames (a chunked stream, a
        pipelined batch) and every frame of a failed attempt is replayed on
        the fresh socket, which is safe because all ops are idempotent at
        the server."""
        if self._closed:
            raise RemoteStoreError("backend is closed")
        last: Exception | None = None
        for attempt in range(self.retries + 1):
            try:
                sock = self._checkout()
            except OSError as e:  # server down/restarting: back off and redial
                last = e
                self._m_reconnects.inc()
                if attempt < self.retries:  # no pointless sleep before raising
                    time.sleep(self.retry_backoff_s * (2**attempt))
                continue
            try:
                result = fn(sock)
            except (ProtocolError, OSError) as e:
                self._scrap(sock)
                last = e
                self._m_reconnects.inc()
                if attempt < self.retries:  # no pointless sleep before raising
                    time.sleep(self.retry_backoff_s * (2**attempt))
                continue
            self._checkin(sock)
            return result
        raise StoreUnreachable(
            f"store server {self.host}:{self.port} unreachable after "
            f"{self.retries + 1} attempts: {last}"
        ) from last

    def _exchange(
        self,
        header: dict[str, Any],
        payload: bytes = b"",
        *,
        timeout_s: float | None = None,
    ) -> tuple[dict[str, Any], bytes, socket.socket]:
        """One request/response, retrying transport failures on fresh
        sockets.  Returns the (healthy) socket WITHOUT checking it back in —
        the caller decides whether to pool it or pin it."""
        if self._closed:
            raise RemoteStoreError("backend is closed")
        last: Exception | None = None
        for attempt in range(self.retries + 1):
            try:
                sock = self._checkout()
            except OSError as e:  # server down/restarting: back off and redial
                last = e
                self._m_reconnects.inc()
                if attempt < self.retries:  # no pointless sleep before raising
                    time.sleep(self.retry_backoff_s * (2**attempt))
                continue
            try:
                if timeout_s is not None:
                    sock.settimeout(timeout_s)
                send_frame(sock, header, payload)
                resp, data = recv_frame(sock)
            except (ProtocolError, OSError) as e:
                self._scrap(sock)
                last = e
                self._m_reconnects.inc()
                if attempt < self.retries:  # no pointless sleep before raising
                    time.sleep(self.retry_backoff_s * (2**attempt))
                continue
            return resp, data, sock
        raise StoreUnreachable(
            f"store server {self.host}:{self.port} unreachable after "
            f"{self.retries + 1} attempts: {last}"
        ) from last

    @staticmethod
    def _raise_reply(resp: dict[str, Any]) -> NoReturn:
        """Map a server error reply to the typed exception the store layer
        expects.  The reply ``kind`` rides on ``RemoteStoreError`` so callers
        can distinguish a v1 server's ``bad_op`` (fall back) from a real
        failure (raise)."""
        kind = resp.get("kind", "server")
        msg = resp.get("error", "remote store error")
        if kind == "not_found":
            raise KeyError(msg)
        if kind == "integrity":
            raise IntegrityError(msg)
        err = RemoteStoreError(msg)
        err.kind = kind
        raise err

    @staticmethod
    def _stamp(header: dict[str, Any]) -> dict[str, Any]:
        """Attach the current traceparent (``tp``) to an outbound request
        header.  Servers that predate tracing ignore the unknown field; with
        tracing off this is a no-op, so the wire stays byte-identical."""
        tp = _tracing.current_traceparent()
        if tp is not None:
            header["tp"] = tp
        return header

    def _request(
        self,
        header: dict[str, Any],
        payload: bytes = b"",
        *,
        timeout_s: float | None = None,
    ) -> tuple[dict[str, Any], bytes]:
        op = header.get("op", "?")
        t0 = time.perf_counter()
        with _tracing.span("rpc", kind="rpc", op=op, shard=self._shard):
            resp, data, sock = self._exchange(
                self._stamp(header), payload, timeout_s=timeout_s
            )
        self._m_rpc_seconds.labels(op=op, shard=self._shard).observe(
            time.perf_counter() - t0
        )
        self._checkin(sock)
        if resp.get("ok"):
            return resp, data
        self._raise_reply(resp)

    # -- StorageBackend contract -----------------------------------------------
    def write_blob(self, key: str, name: str, data: bytes) -> int:
        if len(data) >= self.stream_threshold and self._server_proto != 1:
            try:
                return self._write_blob_chunked(key, name, data)
            except RemoteStoreError as e:
                if getattr(e, "kind", "") != "bad_op":
                    raise
                # v1 server: remember, fall through to the one-shot path
                self._server_proto = 1
        resp, _ = self._request(
            {"op": "write_blob", "key": key, "name": name, "digest": digest(data)},
            data,
        )
        return int(resp["nbytes"])

    def _write_blob_chunked(self, key: str, name: str, data: bytes) -> int:
        """Chunked PUT: request -> ready ack -> chunk stream -> commit reply.
        The ready ack lands *before* any chunk leaves, so a v1 server's
        ``bad_op`` costs one round trip, not one blob; a torn stream replays
        whole on a fresh socket (server-side commit is atomic + idempotent)."""
        header = self._stamp(
            {
                "op": "write_blob_chunked",
                "key": key,
                "name": name,
                "size": len(data),
                "chunk_bytes": self.chunk_bytes,
            }
        )

        def put(sock: socket.socket) -> dict[str, Any]:
            send_frame(sock, header)
            ack, _ = recv_frame(sock)
            if not ack.get("ok"):
                return ack  # server-reported: not a transport failure
            send_blob_stream(sock, data, self.chunk_bytes)
            final, _ = recv_frame(sock)
            return final

        t0 = time.perf_counter()
        with _tracing.span(
            "rpc", kind="rpc", op="write_blob_chunked", shard=self._shard
        ):
            resp = self._with_retries(put)
        self._m_rpc_seconds.labels(op="write_blob_chunked", shard=self._shard).observe(
            time.perf_counter() - t0
        )
        if not resp.get("ok"):
            self._raise_reply(resp)
        self._m_streamed_writes.inc()
        return int(resp["nbytes"])

    def read_blob(self, key: str, name: str) -> bytes:
        declared, folded, data = self._fetch_blob(key, name)
        if declared != folded:
            # one corrupt transfer is retryable; a corrupt blob at rest is not
            declared, folded, data = self._fetch_blob(key, name)
            if declared != folded:
                raise IntegrityError(f"blob {key}/{name} failed digest verification")
        return data

    def _fetch_blob(self, key: str, name: str) -> tuple[str, str, bytes]:
        """One GET; returns (declared digest, locally computed digest, data).
        The request advertises ``accept_chunked`` — a v2 server streams blobs
        ≥ ``stream_min_bytes`` and we fold SHA-256 as chunks arrive; a v1
        server ignores the unknown fields and answers one-shot.  No
        negotiation round trip either way."""
        req: dict[str, Any] = {"op": "read_blob", "key": key, "name": name}
        if self._server_proto != 1:
            req.update(
                accept_chunked=True,
                stream_min_bytes=self.stream_threshold,
                chunk_bytes=self.chunk_bytes,
            )
        self._stamp(req)

        def get(sock: socket.socket) -> tuple[dict[str, Any], str, bytes]:
            send_frame(sock, req)
            resp, data = recv_frame(sock)
            if not resp.get("ok") or not resp.get("chunked"):
                return resp, digest(data), data
            buf, folded, end = recv_blob_stream(sock, int(resp["size"]))
            if end.get("abort"):
                return end, "", b""  # server-reported mid-stream failure
            resp = dict(resp)
            resp["digest"] = end.get("digest")
            self._m_streamed_reads.inc()
            return resp, folded, bytes(buf)

        t0 = time.perf_counter()
        with _tracing.span("rpc", kind="rpc", op="read_blob", shard=self._shard):
            resp, folded, data = self._with_retries(get)
        self._m_rpc_seconds.labels(op="read_blob", shard=self._shard).observe(
            time.perf_counter() - t0
        )
        if not resp.get("ok"):
            self._raise_reply(resp)
        return resp.get("digest"), folded, data

    def delete(self, key: str) -> None:
        self._request({"op": "delete", "key": key, "client_id": self.client_id})

    def exists(self, key: str) -> bool:
        resp, _ = self._request({"op": "exists", "key": key})
        return bool(resp["exists"])

    def write_meta(self, name: str, text: str) -> None:
        self._request({"op": "write_meta", "name": name}, text.encode())

    def read_meta(self, name: str) -> str | None:
        resp, data = self._request({"op": "read_meta", "name": name})
        if resp.get("none"):
            return None
        return data.decode()

    def nbytes(self, key: str) -> int:
        resp, _ = self._request({"op": "nbytes", "key": key})
        return int(resp["nbytes"])

    # -- v2: batched / pipelined small ops --------------------------------------
    def hello(self) -> dict[str, Any]:
        """Probe the server's protocol version and feature list.  Never
        required — every v2 path negotiates lazily — but callers that want to
        know up front (diagnostics, tests) can ask."""
        try:
            resp, _ = self._request({"op": "hello"})
        except RemoteStoreError as e:
            if getattr(e, "kind", "") != "bad_op":
                raise
            self._server_proto = 1
            return {"proto": 1, "features": []}
        self._server_proto = int(resp.get("proto", 1))
        return {"proto": self._server_proto, "features": resp.get("features", [])}

    def batch(self, ops: list[dict[str, Any]]) -> list[dict[str, Any]]:
        """Coalesce small read-only sub-ops (``exists``/``read_meta``/
        ``nbytes``/``ping``) into one round trip.  Against a v1 server the
        sub-ops are *pipelined* instead — all requests go out back-to-back on
        one socket before the responses are read — so deep probe walks still
        avoid per-op latency stacking.  Returns one result dict per sub-op
        (server errors are captured per-result, not raised)."""
        if not ops:
            return []
        if len(ops) > MAX_BATCH_OPS:
            raise ValueError(f"batch of {len(ops)} exceeds {MAX_BATCH_OPS} sub-ops")
        if self._server_proto != 1:
            try:
                resp, _ = self._request({"op": "batch", "ops": ops})
                self._m_batched.inc()
                results = resp["results"]
                # an oversized read_meta bounces out of the batch: retry it
                # singularly (rare; keeps the response header bounded)
                for i, r in enumerate(results):
                    if not r.get("ok") and r.get("kind") == "too_large":
                        results[i] = self._singular(ops[i])
                return results
            except RemoteStoreError as e:
                if getattr(e, "kind", "") != "bad_op":
                    raise
                self._server_proto = 1
        return self._pipelined(ops)

    def _singular(self, sub: dict[str, Any]) -> dict[str, Any]:
        try:
            resp, data = self._request(dict(sub))
        except KeyError as e:
            return {"ok": False, "error": str(e), "kind": "not_found"}
        except RemoteStoreError as e:
            return {"ok": False, "error": str(e), "kind": getattr(e, "kind", "server")}
        if sub.get("op") == "read_meta" and not resp.get("none"):
            resp = dict(resp)
            resp["text"] = data.decode()
        return resp

    def _pipelined(self, ops: list[dict[str, Any]]) -> list[dict[str, Any]]:
        tp = _tracing.current_traceparent()

        def run(sock: socket.socket) -> list[dict[str, Any]]:
            for sub in ops:
                send_frame(sock, {**sub, "tp": tp} if tp is not None else sub)
            out: list[dict[str, Any]] = []
            for sub in ops:
                resp, data = recv_frame(sock)
                if resp.get("ok") and sub.get("op") == "read_meta" and not resp.get("none"):
                    resp = dict(resp)
                    resp["text"] = data.decode()
                out.append(resp)
            return out

        return self._with_retries(run)

    def exists_many(self, keys: "Iterable[str]") -> dict[str, "bool | None"]:
        """Batched presence probe: one round trip for any number of keys.
        ``None`` marks a key whose presence could not be decided (server
        unreachable or per-key server error) — the store treats those as
        unreachable, never as absent."""
        keys = list(keys)
        if not keys:
            return {}
        out: dict[str, bool | None] = {}
        for start in range(0, len(keys), MAX_BATCH_OPS):
            group = keys[start : start + MAX_BATCH_OPS]
            try:
                results = self.batch([{"op": "exists", "key": k} for k in group])
            except (RemoteStoreError, ProtocolError, OSError):
                for k in group:
                    out[k] = None
                continue
            for k, r in zip(group, results):
                out[k] = bool(r.get("exists")) if r.get("ok") else None
        return out

    # -- catalog ops -------------------------------------------------------------
    # Transport failures and pre-catalog servers degrade, never raise: the
    # catalog is a discovery surface riding on operations (admission, delete)
    # that already succeeded — mirroring it must not fail them.  A server
    # answering ``bad_op`` is remembered so later ops skip the round trip.
    def catalog_put(self, doc: dict[str, Any]) -> bool:
        """Upsert one record into the server-side catalog.  False when the
        server predates the op family or is unreachable."""
        if self._server_catalog is False:
            return False
        try:
            self._request({"op": "catalog_put", "doc": doc})
        except StoreUnreachable:
            return False
        except RemoteStoreError as e:
            if getattr(e, "kind", "") != "bad_op":
                raise
            self._server_catalog = False
            return False
        self._server_catalog = True
        return True

    def catalog_remove(self, key: str) -> bool:
        """Drop one record from the server-side catalog (idempotent)."""
        if self._server_catalog is False:
            return False
        try:
            self._request({"op": "catalog_remove", "key": key})
        except StoreUnreachable:
            return False
        except RemoteStoreError as e:
            if getattr(e, "kind", "") != "bad_op":
                raise
            self._server_catalog = False
            return False
        self._server_catalog = True
        return True

    def catalog_query(self, query_doc: dict[str, Any]) -> "list[dict[str, Any]] | None":
        """Run a catalog query server-side.  ``None`` (vs ``[]``) means the
        answer is unavailable — pre-catalog server or pool unreachable — so
        the caller can fall back to its local view."""
        if self._server_catalog is False:
            return None
        try:
            resp, _ = self._request({"op": "catalog_query", "query": query_doc})
        except StoreUnreachable:
            return None
        except RemoteStoreError as e:
            if getattr(e, "kind", "") != "bad_op":
                raise
            self._server_catalog = False
            return None
        self._server_catalog = True
        return list(resp.get("results", ()))

    # -- coordination ----------------------------------------------------------
    def lease_acquire(
        self, key: str, *, wait: bool = True, timeout_s: float = 300.0
    ) -> LeaseGrant:
        resp, _, sock = self._exchange(
            self._stamp(
                {
                    "op": "lease_acquire",
                    "key": key,
                    "client_id": self.client_id,
                    "wait": wait,
                    "timeout": timeout_s,
                }
            ),
            # the socket must outlive the server-side blocking wait
            timeout_s=timeout_s + 30.0,
        )
        if not resp.get("ok"):
            self._checkin(sock)
            raise RemoteStoreError(resp.get("error", "lease_acquire failed"))
        grant = LeaseGrant(
            granted=bool(resp.get("granted")),
            token=resp.get("token", ""),
            stored=bool(resp.get("stored", False)),
            timed_out=bool(resp.get("timeout", False)),
        )
        if grant.granted:
            # the server auto-releases a lease when the connection that
            # acquired it dies — so the carrying socket must stay pinned
            # (out of the shared pool, immune to pool-overflow closes)
            # until lease_release travels back over it
            with self._lease_lock:
                self._lease_socks[(key, grant.token)] = sock
        else:
            self._checkin(sock)
        return grant

    def lease_release(self, key: str, token: str, *, stored: bool) -> None:
        with self._lease_lock:
            sock = self._lease_socks.pop((key, token), None)
        header = {"op": "lease_release", "key": key, "token": token, "stored": stored}
        if sock is None:
            # unknown pin (reconnected meanwhile): plain request; the server
            # treats releasing an unknown lease as a no-op
            self._request(header)
            return
        try:
            sock.settimeout(self.op_timeout_s)
            send_frame(sock, header)
            recv_frame(sock)
        except (ProtocolError, OSError):
            # losing this socket releases the lease server-side anyway
            try:
                sock.close()
            except OSError:
                pass
        else:
            self._checkin(sock)

    def server_stats(self) -> dict[str, Any]:
        resp, _ = self._request({"op": "stats"})
        return dict(resp["stats"])

    def metrics_doc(self) -> "dict[str, Any] | None":
        """Fetch the server's metrics-registry document (see
        ``repro.obs.metrics.MetricsRegistry.to_doc``).  ``None`` against a
        server that predates the ``metrics`` op."""
        try:
            resp, _ = self._request({"op": "metrics"})
        except RemoteStoreError as e:
            if getattr(e, "kind", "") != "bad_op":
                raise
            return None
        return dict(resp.get("metrics", {}))

    def ping(self) -> bool:
        resp, _ = self._request({"op": "ping"})
        return bool(resp.get("pong"))

    # -- eviction-event stream --------------------------------------------------
    def add_event_listener(self, fn: Callable[[str, str], None]) -> None:
        """``fn(event, key)`` runs on the event thread for every server-side
        event (currently ``"evicted"``).  Listeners must be fast and must
        not call back into this backend."""
        with self._listener_lock:
            self._listeners.append(fn)
            if self._event_thread is None:
                self._event_thread = threading.Thread(
                    target=self._event_loop, name="store-events", daemon=True
                )
                self._event_thread.start()

    def _event_loop(self) -> None:
        backoff = self.retry_backoff_s
        while not self._closed:
            sock: socket.socket | None = None
            try:
                sock = self._dial()
                send_frame(sock, {"op": "subscribe", "client_id": self.client_id})
                resp, _ = recv_frame(sock)
                if not resp.get("ok"):
                    raise RemoteStoreError("subscribe rejected")
                self._event_sock = sock
                sock.settimeout(None)  # events arrive whenever they arrive
                backoff = self.retry_backoff_s
                while not self._closed:
                    event, _ = recv_frame(sock)
                    self._dispatch_event(event)
            except (ProtocolError, OSError, RemoteStoreError):
                if self._closed:
                    return
                # server restarting: resubscribe when it comes back
                time.sleep(backoff)
                backoff = min(backoff * 2, 2.0)
            finally:
                self._event_sock = None
                if sock is not None:
                    try:
                        sock.close()
                    except OSError:
                        pass

    def _dispatch_event(self, event: dict[str, Any]) -> None:
        name = event.get("event", "")
        key = event.get("key", "")
        with self._listener_lock:
            listeners = list(self._listeners)
        for fn in listeners:
            try:
                fn(name, key)
            except Exception:  # noqa: BLE001 - one listener must not kill the stream
                pass
