"""``RemoteBackend`` — the store-server client, a drop-in ``StorageBackend``.

``IntermediateStore(backend=RemoteBackend("tcp://host:7077"))`` gives any
process a view onto the shared artifact pool with *zero* changes above the
backend seam: serialization, manifests, codecs, eviction accounting, and
policy bookkeeping all keep running client-side; only bytes cross the wire.

Transport properties:

  * **connection pool** — concurrent scheduler threads each check out a
    socket (dialing on demand), so a long lease wait never blocks unrelated
    reads;
  * **reconnect-and-retry** — every request is idempotent at the server, so
    transport failures (server restart, dropped conn, truncated frame)
    are retried on a fresh connection with exponential backoff before an
    error ever reaches the store;
  * **digest verification** — blob reads carry the server's SHA-256 and are
    re-fetched once on mismatch, then fail loudly with ``IntegrityError``;
  * **event subscription** — an optional dedicated connection streams
    server-side eviction events to registered listeners (the store's
    ``on_external_evict``, the read-through cache's ``invalidate``), with
    automatic resubscription after a server restart.
"""
from __future__ import annotations

import socket
import threading
import time
import uuid
from typing import Any, Callable

from ..core.backends import StorageBackend
from .protocol import (
    ConnectionClosed,
    IntegrityError,
    ProtocolError,
    RemoteStoreError,
    StoreUnreachable,
    digest,
    parse_url,
    recv_frame,
    send_frame,
)


class LeaseGrant:
    """Outcome of one ``lease_acquire`` round."""

    __slots__ = ("granted", "token", "stored", "timed_out")

    def __init__(self, granted: bool, token: str = "", stored: bool = False,
                 timed_out: bool = False) -> None:
        self.granted = granted
        self.token = token
        self.stored = stored
        self.timed_out = timed_out


class RemoteBackend(StorageBackend):
    """TCP client for a :class:`~repro.net.server.StoreServer`."""

    name = "remote"

    def __init__(
        self,
        url: str,
        *,
        client_id: str | None = None,
        connect_timeout_s: float = 5.0,
        op_timeout_s: float = 120.0,
        retries: int = 5,
        retry_backoff_s: float = 0.05,
        max_pool: int = 8,
    ) -> None:
        self.host, self.port = parse_url(url)
        self.client_id = client_id or f"c-{uuid.uuid4().hex[:12]}"
        self.connect_timeout_s = connect_timeout_s
        self.op_timeout_s = op_timeout_s
        self.retries = retries
        self.retry_backoff_s = retry_backoff_s
        self.max_pool = max_pool
        self._pool: list[socket.socket] = []
        self._pool_lock = threading.Lock()
        self._lease_lock = threading.Lock()
        self._lease_socks: dict[tuple[str, str], socket.socket] = {}
        self._closed = False
        self._listeners: list[Callable[[str, str], None]] = []
        self._listener_lock = threading.Lock()
        self._event_thread: threading.Thread | None = None
        self._event_sock: socket.socket | None = None
        self.reconnects = 0  # transport-level redials (observability/tests)

    # -- connection management -------------------------------------------------
    def _dial(self) -> socket.socket:
        sock = socket.create_connection(
            (self.host, self.port), timeout=self.connect_timeout_s
        )
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        sock.settimeout(self.op_timeout_s)
        return sock

    def _checkout(self) -> socket.socket:
        with self._pool_lock:
            if self._pool:
                return self._pool.pop()
        return self._dial()

    def _checkin(self, sock: socket.socket) -> None:
        with self._pool_lock:
            if not self._closed and len(self._pool) < self.max_pool:
                sock.settimeout(self.op_timeout_s)  # undo per-request overrides
                self._pool.append(sock)
                return
        try:
            sock.close()
        except OSError:
            pass

    def close(self) -> None:
        self._closed = True
        with self._pool_lock:
            pool, self._pool = self._pool, []
        with self._lease_lock:
            pool += list(self._lease_socks.values())  # server auto-releases
            self._lease_socks.clear()
        for sock in pool:
            try:
                sock.close()
            except OSError:
                pass
        if self._event_sock is not None:
            try:
                self._event_sock.close()
            except OSError:
                pass
        if self._event_thread is not None:
            self._event_thread.join(timeout=2)
            self._event_thread = None

    # -- request core ----------------------------------------------------------
    def _exchange(
        self,
        header: dict[str, Any],
        payload: bytes = b"",
        *,
        timeout_s: float | None = None,
    ) -> tuple[dict[str, Any], bytes, socket.socket]:
        """One request/response, retrying transport failures on fresh
        sockets.  Returns the (healthy) socket WITHOUT checking it back in —
        the caller decides whether to pool it or pin it."""
        if self._closed:
            raise RemoteStoreError("backend is closed")
        last: Exception | None = None
        for attempt in range(self.retries + 1):
            try:
                sock = self._checkout()
            except OSError as e:  # server down/restarting: back off and redial
                last = e
                self.reconnects += 1
                if attempt < self.retries:  # no pointless sleep before raising
                    time.sleep(self.retry_backoff_s * (2**attempt))
                continue
            try:
                if timeout_s is not None:
                    sock.settimeout(timeout_s)
                send_frame(sock, header, payload)
                resp, data = recv_frame(sock)
            except (ProtocolError, OSError) as e:
                # the socket's framing state is unknown: never reuse it — and
                # its pooled siblings are almost certainly from the same dead
                # server epoch, so drop them all rather than letting stale
                # sockets burn through the whole retry budget one by one
                with self._pool_lock:
                    stale, self._pool = self._pool, []
                for s in [sock, *stale]:
                    try:
                        s.close()
                    except OSError:
                        pass
                last = e
                self.reconnects += 1
                if attempt < self.retries:  # no pointless sleep before raising
                    time.sleep(self.retry_backoff_s * (2**attempt))
                continue
            return resp, data, sock
        raise StoreUnreachable(
            f"store server {self.host}:{self.port} unreachable after "
            f"{self.retries + 1} attempts: {last}"
        ) from last

    def _request(
        self,
        header: dict[str, Any],
        payload: bytes = b"",
        *,
        timeout_s: float | None = None,
    ) -> tuple[dict[str, Any], bytes]:
        resp, data, sock = self._exchange(header, payload, timeout_s=timeout_s)
        self._checkin(sock)
        if resp.get("ok"):
            return resp, data
        kind = resp.get("kind", "server")
        msg = resp.get("error", "remote store error")
        if kind == "not_found":
            raise KeyError(msg)
        if kind == "integrity":
            raise IntegrityError(msg)
        raise RemoteStoreError(msg)

    # -- StorageBackend contract -----------------------------------------------
    def write_blob(self, key: str, name: str, data: bytes) -> int:
        resp, _ = self._request(
            {"op": "write_blob", "key": key, "name": name, "digest": digest(data)},
            data,
        )
        return int(resp["nbytes"])

    def read_blob(self, key: str, name: str) -> bytes:
        req = {"op": "read_blob", "key": key, "name": name}
        resp, data = self._request(req)
        if resp.get("digest") != digest(data):
            # one corrupt transfer is retryable; a corrupt blob at rest is not
            resp, data = self._request(req)
            if resp.get("digest") != digest(data):
                raise IntegrityError(f"blob {key}/{name} failed digest verification")
        return data

    def delete(self, key: str) -> None:
        self._request({"op": "delete", "key": key, "client_id": self.client_id})

    def exists(self, key: str) -> bool:
        resp, _ = self._request({"op": "exists", "key": key})
        return bool(resp["exists"])

    def write_meta(self, name: str, text: str) -> None:
        self._request({"op": "write_meta", "name": name}, text.encode())

    def read_meta(self, name: str) -> str | None:
        resp, data = self._request({"op": "read_meta", "name": name})
        if resp.get("none"):
            return None
        return data.decode()

    def nbytes(self, key: str) -> int:
        resp, _ = self._request({"op": "nbytes", "key": key})
        return int(resp["nbytes"])

    # -- coordination ----------------------------------------------------------
    def lease_acquire(
        self, key: str, *, wait: bool = True, timeout_s: float = 300.0
    ) -> LeaseGrant:
        resp, _, sock = self._exchange(
            {
                "op": "lease_acquire",
                "key": key,
                "client_id": self.client_id,
                "wait": wait,
                "timeout": timeout_s,
            },
            # the socket must outlive the server-side blocking wait
            timeout_s=timeout_s + 30.0,
        )
        if not resp.get("ok"):
            self._checkin(sock)
            raise RemoteStoreError(resp.get("error", "lease_acquire failed"))
        grant = LeaseGrant(
            granted=bool(resp.get("granted")),
            token=resp.get("token", ""),
            stored=bool(resp.get("stored", False)),
            timed_out=bool(resp.get("timeout", False)),
        )
        if grant.granted:
            # the server auto-releases a lease when the connection that
            # acquired it dies — so the carrying socket must stay pinned
            # (out of the shared pool, immune to pool-overflow closes)
            # until lease_release travels back over it
            with self._lease_lock:
                self._lease_socks[(key, grant.token)] = sock
        else:
            self._checkin(sock)
        return grant

    def lease_release(self, key: str, token: str, *, stored: bool) -> None:
        with self._lease_lock:
            sock = self._lease_socks.pop((key, token), None)
        header = {"op": "lease_release", "key": key, "token": token, "stored": stored}
        if sock is None:
            # unknown pin (reconnected meanwhile): plain request; the server
            # treats releasing an unknown lease as a no-op
            self._request(header)
            return
        try:
            sock.settimeout(self.op_timeout_s)
            send_frame(sock, header)
            recv_frame(sock)
        except (ProtocolError, OSError):
            # losing this socket releases the lease server-side anyway
            try:
                sock.close()
            except OSError:
                pass
        else:
            self._checkin(sock)

    def server_stats(self) -> dict[str, Any]:
        resp, _ = self._request({"op": "stats"})
        return dict(resp["stats"])

    def ping(self) -> bool:
        resp, _ = self._request({"op": "ping"})
        return bool(resp.get("pong"))

    # -- eviction-event stream --------------------------------------------------
    def add_event_listener(self, fn: Callable[[str, str], None]) -> None:
        """``fn(event, key)`` runs on the event thread for every server-side
        event (currently ``"evicted"``).  Listeners must be fast and must
        not call back into this backend."""
        with self._listener_lock:
            self._listeners.append(fn)
            if self._event_thread is None:
                self._event_thread = threading.Thread(
                    target=self._event_loop, name="store-events", daemon=True
                )
                self._event_thread.start()

    def _event_loop(self) -> None:
        backoff = self.retry_backoff_s
        while not self._closed:
            sock: socket.socket | None = None
            try:
                sock = self._dial()
                send_frame(sock, {"op": "subscribe", "client_id": self.client_id})
                resp, _ = recv_frame(sock)
                if not resp.get("ok"):
                    raise RemoteStoreError("subscribe rejected")
                self._event_sock = sock
                sock.settimeout(None)  # events arrive whenever they arrive
                backoff = self.retry_backoff_s
                while not self._closed:
                    event, _ = recv_frame(sock)
                    self._dispatch_event(event)
            except (ProtocolError, OSError, RemoteStoreError):
                if self._closed:
                    return
                # server restarting: resubscribe when it comes back
                time.sleep(backoff)
                backoff = min(backoff * 2, 2.0)
            finally:
                self._event_sock = None
                if sock is not None:
                    try:
                        sock.close()
                    except OSError:
                        pass

    def _dispatch_event(self, event: dict[str, Any]) -> None:
        name = event.get("event", "")
        key = event.get("key", "")
        with self._listener_lock:
            listeners = list(self._listeners)
        for fn in listeners:
            try:
                fn(name, key)
            except Exception:  # noqa: BLE001 - one listener must not kill the stream
                pass
