"""``StoreServer`` — the intermediate-data store as a shared daemon.

One server process owns a :class:`~repro.core.backends.StorageBackend` (a
``LocalFSBackend`` in the CLI) and exposes its full contract over the framed
TCP protocol of :mod:`repro.net.protocol`, so any number of workflow
processes share one artifact pool — the fleet-wide denominator the gain-loss
storing model needs (arXiv 2202.06473) and the reuse-across-workers setup
parallel SWfMSs assume (arXiv 1303.7195).

Beyond the byte ops the server provides the two pieces of *coordination*
that cannot live client-side:

  * a **lease table** — the cross-process generalization of the in-process
    :class:`~repro.sched.singleflight.SingleFlight`: the first client to
    ``lease_acquire`` an uncomputed store key becomes the fleet-wide leader;
    later acquirers block until the leader releases (carrying a ``stored``
    bit telling them whether loading or recomputing is next).  Leases held
    by a connection are auto-released when it dies, so a crashed leader
    never wedges the fleet.
  * an **eviction-event stream** — every ``delete`` is broadcast to
    subscribed clients (minus the originator), so each client's
    ``policy.stored`` bookkeeping and read-through cache converge on the
    same view of what still exists.

Every connection is handled by its own thread (handlers mostly block on
socket or disk I/O, where the GIL is released); per-op request counters are
exposed via the ``stats`` op — benchmarks use them to prove cache hits never
touch the network.
"""
from __future__ import annotations

import hashlib
import itertools
import json
import os
import socket
import threading
import time
from typing import Any

from ..catalog.index import CatalogIndex
from ..catalog.records import CatalogQuery, CatalogRecord
from ..core.backends import StorageBackend
from ..obs import tracing as _tracing
from ..obs.metrics import MetricsRegistry
from .protocol import (
    DEFAULT_CHUNK_BYTES,
    MAX_BATCH_OPS,
    MAX_CHUNK_BYTES,
    MAX_HEADER_BYTES,
    MAX_PAYLOAD_BYTES,
    PROTO_VERSION,
    ConnectionClosed,
    ProtocolError,
    digest,
    recv_frame,
    recv_frame_into,
    send_chunk_prefix,
    send_frame,
    send_stream_end,
)

_MAX_LEASE_WAIT_S = 3600.0


class _Lease:
    __slots__ = ("token", "client_id", "event", "stored")

    def __init__(self, token: str, client_id: str) -> None:
        self.token = token
        self.client_id = client_id
        self.event = threading.Event()
        self.stored = False


class _Conn:
    """Per-connection server state (socket + locks + held leases)."""

    def __init__(self, sock: socket.socket, peer: Any) -> None:
        self.sock = sock
        self.peer = peer
        self.send_lock = threading.Lock()  # event pushes race with responses
        self.client_id = ""
        self.leases: set[tuple[str, str]] = set()  # (key, token)
        self.subscriber = False

    def send(
        self, header: dict[str, Any], payload: bytes = b"", *,
        timeout_s: float | None = None,
    ) -> None:
        with self.send_lock:
            if timeout_s is not None:
                self.sock.settimeout(timeout_s)
            try:
                send_frame(self.sock, header, payload)
            finally:
                if timeout_s is not None:
                    self.sock.settimeout(None)


class StoreServer:
    """Threaded TCP daemon exposing a ``StorageBackend`` plus coordination."""

    def __init__(
        self,
        backend: StorageBackend,
        host: str = "127.0.0.1",
        port: int = 0,
        registry: MetricsRegistry | None = None,
        trace_service: str | None = None,
    ) -> None:
        self.backend = backend
        self.host = host
        self.port = port
        # service name stamped on this server's spans — in-process test
        # clusters give each shard its own so stitched traces can tell the
        # shards apart even under one pid
        self.trace_service = trace_service
        self._listener: socket.socket | None = None
        self._accept_thread: threading.Thread | None = None
        self._stopping = threading.Event()
        self._conns_lock = threading.Lock()
        self._conns: set[_Conn] = set()
        self._lease_lock = threading.Lock()
        self._leases: dict[str, _Lease] = {}
        self._token_counter = itertools.count(1)
        # per-op and streaming counters live on the unified metrics registry;
        # ``stats()`` reconstructs its legacy dict shape from the same series
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._m_requests = self.metrics.counter(
            "repro_store_server_requests_total", "requests dispatched, per op", ("op",)
        )
        self._m_stream_chunks = self.metrics.counter(
            "repro_store_server_stream_chunks_total",
            "chunk frames moved by streaming transfers",
            ("dir",),
        )
        self._m_stream_bytes = self.metrics.counter(
            "repro_store_server_stream_bytes_total",
            "payload bytes moved by streaming transfers",
            ("dir",),
        )
        self._m_stream_events = self.metrics.counter(
            "repro_store_server_stream_events_total",
            "streaming milestones (streamed_writes, sendfile_reads, spill_aborts, ...)",
            ("event",),
        )
        # pre-bound children: the per-chunk path must not pay a label lookup
        self._m_chunks_in = self._m_stream_chunks.labels(dir="in")
        self._m_chunks_out = self._m_stream_chunks.labels(dir="out")
        self._m_bytes_in = self._m_stream_bytes.labels(dir="in")
        self._m_bytes_out = self._m_stream_bytes.labels(dir="out")
        self.metrics.gauge(
            "repro_store_server_connections", "live client connections"
        ).unlabeled.set_function(lambda: len(self._conns))
        self.metrics.gauge(
            "repro_store_server_active_leases", "keys currently leased"
        ).unlabeled.set_function(lambda: len(self._leases))
        self.metrics.gauge(
            "repro_store_server_subscribers", "connections subscribed to events"
        ).unlabeled.set_function(
            lambda: sum(1 for c in list(self._conns) if c.subscriber)
        )
        self.metrics.gauge(
            "repro_store_server_catalog_records", "records in the catalog slice"
        ).unlabeled.set_function(lambda: len(self.catalog))
        self.metrics.gauge(
            "repro_store_server_uptime_seconds", "seconds since start()"
        ).unlabeled.set_function(lambda: time.monotonic() - self._started_at)
        # digest sidecar: content digests recorded at verified writes, so a
        # chunked read can skip the server-side SHA-256 pass (the client's
        # incremental fold is the end-to-end check) and go through
        # ``os.sendfile`` without the bytes ever entering userspace.  Purely
        # an optimization cache: lazily repopulated by folding reads after a
        # restart, dropped on delete.
        self._digest_lock = threading.Lock()
        self._digests: dict[tuple[str, str], str] = {}
        # server-side catalog slice: the provenance index for the artifacts
        # this shard holds.  Lives here (not client-side) so it survives
        # client churn; persisted as catalog.json through the backend with
        # the same batched-flush discipline as the store's index.json.
        self.catalog = CatalogIndex()
        self.catalog_flush_every = 64
        self._catalog_flushed = 0
        # monotonic, not wall: uptime and every lease-wait deadline in this
        # process must be immune to NTP steps — a wall-clock jump must never
        # expire (or extend) a lease or report negative uptime
        self._started_at = time.monotonic()

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "StoreServer":
        if self._listener is not None:
            raise RuntimeError("server already started")
        self._stopping.clear()
        self._load_catalog()
        ls = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        ls.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        ls.bind((self.host, self.port))
        ls.listen(128)
        # a thread blocked in accept() holds the socket open past close()
        # (Linux), pinning the port; a timeout lets it observe _stopping
        ls.settimeout(0.2)
        self.port = ls.getsockname()[1]
        self._listener = ls
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="store-accept", daemon=True
        )
        self._accept_thread.start()
        return self

    def stop(self) -> None:
        """Close the listener and every live connection; wake lease waiters."""
        self._stopping.set()
        if self._listener is not None:
            if self._accept_thread is not None:
                self._accept_thread.join(timeout=2)  # drain a blocked accept()
            try:
                self._listener.close()
            except OSError:
                pass
            self._listener = None
        with self._conns_lock:
            conns = list(self._conns)
        for conn in conns:
            self._drop_conn(conn)
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5)
            self._accept_thread = None
        self._flush_catalog()

    def wait(self) -> None:
        """Block until :meth:`stop` is called (signal handler, other thread)."""
        while not self._stopping.wait(0.5):
            pass

    def serve_forever(self) -> None:
        self.start()
        try:
            self.wait()
        finally:
            self.stop()

    def __enter__(self) -> "StoreServer":
        return self.start()

    def __exit__(self, *exc: Any) -> None:
        self.stop()

    @property
    def url(self) -> str:
        return f"tcp://{self.host}:{self.port}"

    # -- accept / per-connection loop ---------------------------------------
    def _accept_loop(self) -> None:
        assert self._listener is not None
        listener = self._listener
        while not self._stopping.is_set():
            try:
                sock, peer = listener.accept()
            except socket.timeout:
                continue
            except OSError:  # listener closed by stop()
                return
            sock.settimeout(None)  # accept()ed sockets inherit the timeout
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _Conn(sock, peer)
            with self._conns_lock:
                self._conns.add(conn)
            threading.Thread(
                target=self._serve_conn, args=(conn,), name="store-conn", daemon=True
            ).start()

    def _drop_conn(self, conn: _Conn) -> None:
        with self._conns_lock:
            self._conns.discard(conn)
        # a dead leader must not wedge its followers: auto-release with
        # stored=False so waiters recompute (or re-elect) instead of hanging.
        # Snapshot under the lease lock (the serve thread mutates the set
        # under it too), release outside (the lock is not reentrant).
        with self._lease_lock:
            held = list(conn.leases)
            conn.leases.clear()
        for key, token in held:
            self._release_lease(key, token, stored=False)
        try:
            conn.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            conn.sock.close()
        except OSError:
            pass

    def _serve_conn(self, conn: _Conn) -> None:
        try:
            while not self._stopping.is_set():
                try:
                    header, payload = recv_frame(conn.sock)
                except ConnectionClosed:
                    return
                except (ProtocolError, OSError):
                    # truncated/garbled frame: this connection's framing is
                    # unrecoverable — drop it; other connections are unharmed
                    return
                try:
                    self._dispatch(conn, header, payload)
                except (ProtocolError, OSError):
                    # a tear mid-op (chunk stream truncated, peer vanished):
                    # framing is unrecoverable — drop the connection quietly;
                    # the op's own cleanup (spill abort) already ran
                    return
        finally:
            self._drop_conn(conn)

    # -- request dispatch -----------------------------------------------------
    def _count_stream(self, what: str, n: int = 1) -> None:
        self._m_stream_events.labels(event=what).inc(n)

    def _dispatch(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        op = req.get("op", "")
        self._m_requests.labels(op=op).inc()
        # adopt the caller's trace context when the request carries one (the
        # optional ``tp`` field — absent from old clients, ignored by old
        # servers) so the server-side span stitches under the caller's trace
        ctx = _tracing.TraceContext.from_traceparent(req.get("tp"))
        sp = (
            _tracing.span(f"store.{op}", kind="server", parent=ctx,
                          svc=self.trace_service)
            if ctx is not None
            else _tracing.NOOP_SPAN
        )
        try:
            with sp:
                handler = getattr(self, f"_op_{op}", None)
                if handler is None:
                    conn.send(
                        {"ok": False, "error": f"unknown op {op!r}", "kind": "bad_op"}
                    )
                    return
                handler(conn, req, payload)
        except (KeyError, FileNotFoundError) as e:
            conn.send({"ok": False, "error": str(e), "kind": "not_found"})
        except (BrokenPipeError, ConnectionResetError):
            raise
        except ProtocolError:
            # a tear *inside* a chunk stream: the connection's framing state
            # is gone — it must be dropped, never answered (the per-op spill
            # cleanup already ran via the handler's try/finally)
            raise
        except Exception as e:  # noqa: BLE001 - fault isolation per request
            conn.send({"ok": False, "error": f"{type(e).__name__}: {e}", "kind": "server"})

    @staticmethod
    def _bad_name(name: Any) -> bool:
        """Blob/meta names are joined into filesystem paths by the backend;
        a network client must never be able to traverse outside the root."""
        return (
            not isinstance(name, str)
            or not name
            or "/" in name
            or "\\" in name
            or "\x00" in name
            or name in (".", "..")
        )

    def _check_name(self, conn: _Conn, req: dict[str, Any]) -> str | None:
        name = req.get("name")
        if self._bad_name(name):
            conn.send(
                {"ok": False, "error": f"illegal blob name {name!r}", "kind": "bad_name"}
            )
            return None
        return name

    # -- digest sidecar --------------------------------------------------------
    def _record_digest(self, key: str, name: str, hexd: str) -> None:
        with self._digest_lock:
            self._digests[(key, name)] = hexd

    def _known_digest(self, key: str, name: str) -> str | None:
        with self._digest_lock:
            return self._digests.get((key, name))

    def _forget_digests(self, key: str) -> None:
        with self._digest_lock:
            for k in [k for k in self._digests if k[0] == key]:
                del self._digests[k]

    # -- storage ops ----------------------------------------------------------
    def _op_write_blob(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        name = self._check_name(conn, req)
        if name is None:
            return
        want = req.get("digest")
        if want is not None and digest(payload) != want:
            conn.send(
                {"ok": False, "error": "payload digest mismatch", "kind": "integrity"}
            )
            return
        n = self.backend.write_blob(req["key"], name, payload)
        if want is not None:
            self._record_digest(req["key"], name, want)
        conn.send({"ok": True, "nbytes": n})

    def _op_read_blob(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        name = self._check_name(conn, req)
        if name is None:
            return
        key = req["key"]
        if req.get("accept_chunked"):
            # v2 client: stream large blobs; small ones still go one-shot
            # (same fields as a v1 response, so the client's fallback parse
            # is trivial).  A v1 server never sees accept_chunked — unknown
            # request fields are ignored — which is the whole read-side
            # negotiation: none needed.
            stream_min = int(req.get("stream_min_bytes", 0))
            reader = self.backend.open_blob_reader(key, name)
            with reader:
                if reader.size >= stream_min:
                    self._stream_blob(conn, req, key, name, reader)
                    return
                data = reader.raw.read()
            hexd = digest(data)
            self._record_digest(key, name, hexd)
            conn.send({"ok": True, "digest": hexd}, data)
            return
        data = self.backend.read_blob(key, name)
        hexd = digest(data)
        self._record_digest(key, name, hexd)
        conn.send({"ok": True, "digest": hexd}, data)

    def _stream_blob(self, conn, req, key: str, name: str, reader) -> None:
        """Chunked read response: ``{"ok","chunked","size"}`` then chunk
        frames and an end frame.  When the sidecar already knows the content
        digest the payload goes through ``os.sendfile`` (zero-copy, no SHA
        pass — the client's fold is the end-to-end check); otherwise we read
        through one bounded buffer, folding as we go, and the fold both
        terminates this stream and repopulates the sidecar."""
        size = reader.size
        chunk_bytes = max(
            1, min(int(req.get("chunk_bytes", DEFAULT_CHUNK_BYTES)), MAX_CHUNK_BYTES)
        )
        known = self._known_digest(key, name)
        fd = None
        if known is not None and hasattr(os, "sendfile"):
            try:
                fd = reader.fileno()
            except (OSError, ValueError, AttributeError):
                fd = None  # memory-backed reader: fall through to the copy loop
        with conn.send_lock:  # one frame sequence, never interleaved
            send_frame(conn.sock, {"ok": True, "chunked": True, "size": size})
            try:
                if fd is not None:
                    offset = 0
                    while offset < size:
                        n = min(chunk_bytes, size - offset)
                        send_chunk_prefix(conn.sock, n)
                        sent = 0
                        while sent < n:
                            sent += os.sendfile(
                                conn.sock.fileno(), fd, offset + sent, n - sent
                            )
                        offset += n
                        self._m_chunks_out.inc()
                        self._m_bytes_out.inc(n)
                    send_stream_end(conn.sock, digest_hex=known)
                    self._count_stream("sendfile_reads")
                else:
                    buf = bytearray(chunk_bytes)
                    view = memoryview(buf)
                    sha = hashlib.sha256()
                    sent = 0
                    while sent < size:
                        n = reader.readinto(view)
                        if n <= 0:
                            raise OSError(
                                f"blob {key}/{name} shrank mid-read "
                                f"({sent} of {size} bytes)"
                            )
                        n = min(n, size - sent)
                        sha.update(view[:n])
                        send_frame(conn.sock, b'{"c":1}', view[:n])
                        sent += n
                        self._m_chunks_out.inc()
                        self._m_bytes_out.inc(n)
                    hexd = sha.hexdigest()
                    self._record_digest(key, name, hexd)
                    send_stream_end(conn.sock, digest_hex=hexd)
                self._count_stream("streamed_reads")
            except (BrokenPipeError, ConnectionResetError, ProtocolError):
                raise
            except OSError as e:
                # backend failure after the ok header went out: the stream
                # grammar's abort frame is the only way to tell the client
                send_stream_end(conn.sock, abort=True, error=str(e), kind="server")

    def _op_write_blob_chunked(
        self, conn: _Conn, req: dict[str, Any], payload: bytes
    ) -> None:
        """v2 chunked PUT.  Handshake: this request -> ready ack -> chunk
        frames -> end frame (digest) -> commit response.  The ready ack is
        the negotiation: a v1 server answers ``bad_op`` *before* the client
        has streamed anything, so falling back costs one round trip, not one
        blob.  Bytes append to a :class:`BlobWriter` (spill file on the FS
        backend) — nothing is visible to ``exists``/``read_blob`` until the
        folded digest checks out and the writer commits."""
        name = self._check_name(conn, req)
        if name is None:
            return
        key = req["key"]
        try:
            size = int(req["size"])
        except (KeyError, TypeError, ValueError):
            conn.send({"ok": False, "error": "bad or missing size", "kind": "bad_op"})
            return
        if size < 0 or size > MAX_PAYLOAD_BYTES:
            conn.send({"ok": False, "error": f"size out of range: {size}", "kind": "bad_op"})
            return
        chunk_bytes = max(
            1, min(int(req.get("chunk_bytes", DEFAULT_CHUNK_BYTES)), MAX_CHUNK_BYTES)
        )
        conn.send({"ok": True, "ready": True})
        writer = self.backend.open_blob_writer(key, name)
        committed = False
        try:
            buf = bytearray(chunk_bytes)
            view = memoryview(buf)
            sha = hashlib.sha256()
            got = 0
            while True:
                header, n = recv_frame_into(conn.sock, view)
                if header.get("end"):
                    break
                if got + n > size:
                    # the peer lied about size; framing trust is gone
                    raise ProtocolError(
                        f"stream overran its announced {size} bytes"
                    )
                if n:
                    sha.update(view[:n])
                    writer.write(view[:n])
                    got += n
                    self._m_chunks_in.inc()
                    self._m_bytes_in.inc(n)
            if header.get("abort"):
                conn.send(
                    {
                        "ok": False,
                        "error": header.get("error") or "client aborted stream",
                        "kind": "aborted",
                    }
                )
                return
            if got != size:
                conn.send(
                    {
                        "ok": False,
                        "error": f"stream ended at {got} of {size} bytes",
                        "kind": "protocol",
                    }
                )
                return
            folded = sha.hexdigest()
            want = header.get("digest")
            if want is not None and want != folded:
                conn.send(
                    {"ok": False, "error": "stream digest mismatch", "kind": "integrity"}
                )
                return
            nbytes = writer.commit()
            committed = True
            self._record_digest(key, name, folded)
            self._count_stream("streamed_writes")
            conn.send({"ok": True, "nbytes": nbytes})
        finally:
            if not committed:
                # torn stream, overrun, digest mismatch, backend error: the
                # spill file is reclaimed and no partial blob ever landed
                writer.abort()
                self._count_stream("spill_aborts")

    def _op_delete(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        key = req["key"]
        self.backend.delete(key)
        self._forget_digests(key)
        # keep the provenance index consistent with the blobs it describes:
        # an evicted artifact must never be reported as present by a query
        if self.catalog.discard(key):
            self._catalog_dirty()
        conn.send({"ok": True})
        self._broadcast(
            {"event": "evicted", "key": key}, skip_client=req.get("client_id", "")
        )

    def _op_exists(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        conn.send({"ok": True, "exists": bool(self.backend.exists(req["key"]))})

    def _op_write_meta(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        name = self._check_name(conn, req)
        if name is None:
            return
        self.backend.write_meta(name, payload.decode())
        conn.send({"ok": True})

    def _op_read_meta(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        name = self._check_name(conn, req)
        if name is None:
            return
        text = self.backend.read_meta(name)
        if text is None:
            conn.send({"ok": True, "none": True})
        else:
            conn.send({"ok": True}, text.encode())

    def _op_nbytes(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        conn.send({"ok": True, "nbytes": int(self.backend.nbytes(req["key"]))})

    # -- v2: negotiation + batched small ops ----------------------------------
    def _op_hello(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        conn.send(
            {
                "ok": True,
                "proto": PROTO_VERSION,
                "features": ["chunked", "batch", "catalog", "metrics"],
            }
        )

    # only cheap presence/metadata probes may ride in a batch: a blob op in
    # the middle of a coalesced round trip would re-serialize the data plane
    # behind metadata traffic
    _BATCH_SUBOPS = frozenset({"exists", "read_meta", "nbytes", "ping"})
    # one read_meta result above this is returned as kind="too_large" instead
    # of blowing the 1 MiB response-header cap when many ride together
    _BATCH_META_BYTES = 256 << 10

    def _op_batch(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        ops = req.get("ops")
        if not isinstance(ops, list):
            conn.send({"ok": False, "error": "batch needs an ops list", "kind": "bad_op"})
            return
        if len(ops) > MAX_BATCH_OPS:
            conn.send(
                {
                    "ok": False,
                    "error": f"batch of {len(ops)} exceeds {MAX_BATCH_OPS} sub-ops",
                    "kind": "bad_op",
                }
            )
            return
        self._count_stream("batch_subops", len(ops))
        results = []
        budget = MAX_HEADER_BYTES - (64 << 10)  # response-header headroom
        for sub in ops:
            results.append(self._batch_one(sub, budget))
            if results[-1].get("ok") and "text" in results[-1]:
                budget -= len(results[-1]["text"])
        conn.send({"ok": True, "results": results})

    def _batch_one(self, sub: Any, budget: int) -> dict[str, Any]:
        if not isinstance(sub, dict):
            return {"ok": False, "error": "sub-op must be an object", "kind": "bad_op"}
        op = sub.get("op", "")
        if op not in self._BATCH_SUBOPS:
            return {
                "ok": False,
                "error": f"op {op!r} not allowed in a batch",
                "kind": "bad_op",
            }
        try:
            if op == "ping":
                return {"ok": True, "pong": True}
            if op == "exists":
                return {"ok": True, "exists": bool(self.backend.exists(sub["key"]))}
            if op == "nbytes":
                return {"ok": True, "nbytes": int(self.backend.nbytes(sub["key"]))}
            # read_meta: the result rides inline in the response header, so
            # oversized values must bounce (client retries them singularly)
            name = sub.get("name")
            if self._bad_name(name):
                return {
                    "ok": False,
                    "error": f"illegal blob name {name!r}",
                    "kind": "bad_name",
                }
            text = self.backend.read_meta(name)
            if text is None:
                return {"ok": True, "none": True}
            if len(text) > min(self._BATCH_META_BYTES, max(budget, 0)):
                return {
                    "ok": False,
                    "error": f"meta {name!r} too large for a batch response",
                    "kind": "too_large",
                }
            return {"ok": True, "text": text}
        except (KeyError, FileNotFoundError) as e:
            return {"ok": False, "error": str(e), "kind": "not_found"}
        except Exception as e:  # noqa: BLE001 - per-sub-op fault isolation
            return {"ok": False, "error": f"{type(e).__name__}: {e}", "kind": "server"}

    # -- catalog ops -----------------------------------------------------------
    # one query's results ride in the response header (1 MiB cap): bound them
    _CATALOG_MAX_LIMIT = 1000

    def _load_catalog(self) -> None:
        """Restore the persisted catalog slice, pruning records whose
        artifacts vanished while the server was down (crashed writer, disk
        wipe) — the index must never promise a blob the backend lost."""
        try:
            raw = self.backend.read_meta("catalog.json")
        except Exception:  # noqa: BLE001 - a damaged snapshot must not stop startup
            return
        if not raw:
            return
        try:
            docs = json.loads(raw)
        except json.JSONDecodeError:
            return
        if isinstance(docs, list):
            self.catalog.load(docs)
            try:
                self.catalog.prune(self.backend.exists)
            except Exception:  # noqa: BLE001
                pass
        self._catalog_flushed = self.catalog.mutations

    def _flush_catalog(self) -> None:
        if self.catalog.mutations == self._catalog_flushed:
            return
        try:
            self.backend.write_meta("catalog.json", json.dumps(self.catalog.snapshot()))
        except Exception:  # noqa: BLE001 - persistence is a cache, not truth
            return
        self._catalog_flushed = self.catalog.mutations

    def _catalog_dirty(self) -> None:
        if self.catalog.mutations - self._catalog_flushed >= self.catalog_flush_every:
            self._flush_catalog()

    def _op_catalog_put(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        doc = req.get("doc")
        if not isinstance(doc, dict):
            conn.send({"ok": False, "error": "catalog_put needs a doc", "kind": "bad_op"})
            return
        try:
            rec = CatalogRecord.from_doc(doc)
        except (KeyError, ValueError, TypeError) as e:
            conn.send({"ok": False, "error": f"bad catalog doc: {e}", "kind": "bad_op"})
            return
        self.catalog.upsert(rec)
        self._catalog_dirty()
        conn.send({"ok": True})

    def _op_catalog_remove(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        removed = self.catalog.discard(req["key"])
        if removed:
            self._catalog_dirty()
        conn.send({"ok": True, "removed": removed})

    def _op_catalog_query(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        try:
            q = CatalogQuery.from_doc(req.get("query") or {})
        except (ValueError, TypeError) as e:
            conn.send({"ok": False, "error": f"bad catalog query: {e}", "kind": "bad_op"})
            return
        q.limit = min(q.limit, self._CATALOG_MAX_LIMIT)
        results = [r.to_doc() for r in self.catalog.query(q)]
        conn.send({"ok": True, "results": results, "total": len(self.catalog)})

    # -- coordination ops ------------------------------------------------------
    def _op_lease_acquire(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        key = req["key"]
        client_id = req.get("client_id", "")
        wait = bool(req.get("wait", True))
        timeout = min(float(req.get("timeout", 300.0)), _MAX_LEASE_WAIT_S)
        with self._lease_lock:
            lease = self._leases.get(key)
            if lease is None:
                token = f"t{next(self._token_counter)}"
                self._leases[key] = _Lease(token, client_id)
                conn.leases.add((key, token))
        if lease is None:
            # send OUTSIDE the lease lock: a client with a full receive
            # window must never wedge fleet-wide lease traffic
            conn.send({"ok": True, "granted": True, "token": token})
            return
        if not wait:
            conn.send({"ok": True, "granted": False, "waited": False})
            return
        # block this handler thread (connection-per-thread makes that safe)
        # until the leader releases; the stored bit tells the waiter whether
        # the artifact landed (load it) or not (become the next leader).
        # Event.wait computes its deadline from the monotonic clock, so an
        # NTP step can neither cut a lease wait short nor stretch it.
        if lease.event.wait(timeout):
            conn.send({"ok": True, "granted": False, "stored": lease.stored})
        else:
            conn.send({"ok": True, "granted": False, "stored": False, "timeout": True})

    def _op_lease_release(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        key, token = req["key"], req.get("token", "")
        self._release_lease(key, token, stored=bool(req.get("stored", False)))
        with self._lease_lock:
            conn.leases.discard((key, token))
        # releasing an unknown/expired lease is a no-op: the client may be
        # replaying after a reconnect that already auto-released it
        conn.send({"ok": True})

    def _release_lease(self, key: str, token: str, stored: bool) -> None:
        with self._lease_lock:
            lease = self._leases.get(key)
            if lease is None or lease.token != token:
                return
            del self._leases[key]
        lease.stored = stored
        lease.event.set()

    def _op_subscribe(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        conn.client_id = req.get("client_id", "")
        conn.subscriber = True
        conn.send({"ok": True})

    def _broadcast(self, event: dict[str, Any], skip_client: str = "") -> None:
        with self._conns_lock:
            subs = [c for c in self._conns if c.subscriber]
        for sub in subs:
            if skip_client and sub.client_id == skip_client:
                continue  # originator already handled it locally
            try:
                # bounded send: a subscriber that stopped draining its socket
                # must not wedge the deleting connection (and, through its
                # send_lock, every later broadcast) — drop it instead
                sub.send(event, timeout_s=5.0)
            except OSError:  # includes socket.timeout
                self._drop_conn(sub)

    # -- introspection ---------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        """Legacy dict-shaped snapshot, now a **deprecated alias** view
        reconstructed from the unified metrics registry (the canonical
        surface is the ``metrics`` op / ``repro_store_server_*`` series —
        see ``repro/obs/naming.py`` for the pinned key mapping)."""
        counts = {
            s["labels"]["op"]: int(s["value"] or 0)
            for s in self._m_requests.series()
        }
        streaming: dict[str, int] = {}
        for s in self._m_stream_chunks.series():
            streaming[f"chunks_{s['labels']['dir']}"] = int(s["value"] or 0)
        for s in self._m_stream_bytes.series():
            streaming[f"bytes_{s['labels']['dir']}"] = int(s["value"] or 0)
        for s in self._m_stream_events.series():
            streaming[s["labels"]["event"]] = int(s["value"] or 0)
        with self._lease_lock:
            n_leases = len(self._leases)
        with self._conns_lock:
            n_conns = len(self._conns)
            n_subs = sum(1 for c in self._conns if c.subscriber)
        return {
            "proto": PROTO_VERSION,
            "requests": sum(counts.values()),
            "ops": counts,
            "streaming": streaming,
            "active_leases": n_leases,
            "connections": n_conns,
            "subscribers": n_subs,
            "catalog_records": len(self.catalog),
            "uptime_s": time.monotonic() - self._started_at,
        }

    def _op_stats(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        conn.send({"ok": True, "stats": self.stats()})

    def _op_metrics(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        """Canonical introspection surface: the full registry doc, mergeable
        across shards (``ShardedBackend.metrics_doc`` fans this out)."""
        conn.send({"ok": True, "metrics": self.metrics.to_doc()})

    def _op_ping(self, conn: _Conn, req: dict[str, Any], payload: bytes) -> None:
        conn.send({"ok": True, "pong": True})
