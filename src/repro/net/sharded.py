"""``ShardedBackend`` — N store servers as one replicated artifact pool.

PR 4's single ``StoreServer`` is the right *contract* but the wrong
*cardinality*: one daemon's disk, accept loop, and lease table saturate
exactly when workflow parallelism starts paying (the single-node data
bottleneck of parallel SWfMS surveys, arXiv 1303.7195).  This backend keeps
the ``StorageBackend`` seam byte-for-byte and spreads it over a static
cluster:

  * **routing** — every artifact key (and meta name) maps onto a
    :class:`~repro.net.ring.HashRing` preference list; the first ``R``
    nodes are its replica set (``replication=R``).
  * **replicated writes** — each blob write lands on every reachable
    replica; one success is enough to return (unreachable replicas are
    healed later by read-repair).  With ``R=2`` a shard can die mid-run
    without losing a single artifact.
  * **failover reads** — reads walk the replica set in ring order, skipping
    shards marked down; a read served by a non-primary counts as a
    ``failover_read``.
  * **read-repair** — when a later replica serves a blob that an *alive*
    earlier replica was missing (it restarted empty, or missed the write
    while down), the blob is written back best-effort, converging the
    replica set without any background process.
  * **ring-aware leases** — ``lease_acquire`` contends on the key's primary
    and falls over along the ring when it is unreachable, so the fleet-wide
    single-flight election (``DistributedSingleFlight``) survives a shard
    death mid-run: waiters re-elect on the next live node.
  * **merged event streams** — eviction events from every shard fan into
    the same listeners.  A replicated delete broadcasts from up to ``R``
    shards; listeners (cache invalidation, ``store.on_external_evict``)
    are idempotent by design.

Absence is only trusted when *every* replica of a key is reachable and
answers "no"; if any replica is down, presence questions raise
:class:`~repro.net.protocol.StoreUnreachable` (a ``BackendUnavailable``),
which the store and scheduler treat as "not reusable right now" — plan a
recompute, never prune a record for bytes that may still exist.  Only
transport-level unreachability gets that treatment: a reachable shard
*reporting* an error (bad request, disk fault) propagates as-is and never
marks the shard down.

Membership is static configuration (the same comma-separated list every
client passes); see ``docs/remote.md`` for the operational caveats, chiefly
that a shard restored from an old disk can resurrect artifacts deleted
while it was away.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Sequence

from ..core.backends import BackendUnavailable, StorageBackend
from ..obs.metrics import MetricsRegistry, merge_docs
from .client import LeaseGrant, RemoteBackend
from .protocol import MAX_BATCH_OPS, IntegrityError, StoreUnreachable, parse_urls
from .ring import HashRing


class ShardedBackend(StorageBackend):
    """Consistent-hash routed, replicated client over N ``StoreServer``s.

    Parameters
    ----------
    urls: comma-separated endpoint list (``"h:7077,h:7078"``) or a sequence
        of single-endpoint urls.  Order is irrelevant: the ring sorts
        members canonically, so every client sharing the list routes alike.
    replication: replica-set size ``R`` per key (clamped to the shard
        count).  ``R=1`` is pure sharding (a dead shard loses its keys until
        it returns); ``R>=2`` survives single-shard death with no loss.
    client_id: shared across the per-shard connections, so a replicated
        delete's eviction broadcast still skips its originator on every
        shard.
    down_cooldown_s: after a transport failure a shard is skipped for this
        long before being probed again — failover stays fast without
        hammering a dead endpoint, and recovery is noticed within one
        cooldown.
    backend_kw: forwarded to each per-shard :class:`RemoteBackend` (its own
        socket pool — pool-per-shard).  Retries default lower than a
        single-server backend's: the ring itself is the retry of record.
    """

    name = "sharded"

    def __init__(
        self,
        urls: str | Sequence[str],
        *,
        replication: int = 2,
        client_id: str | None = None,
        down_cooldown_s: float = 1.0,
        vnodes: int = 64,
        registry: MetricsRegistry | None = None,
        **backend_kw: Any,
    ) -> None:
        if isinstance(urls, str):
            endpoints = parse_urls(urls)
        else:
            endpoints = [ep for u in urls for ep in parse_urls(u)]
        if len(set(endpoints)) != len(endpoints):
            raise ValueError(f"duplicate endpoints in {urls!r}")
        if replication < 1:
            raise ValueError("replication must be >= 1")
        backend_kw.setdefault("retries", 2)
        backend_kw.setdefault("retry_backoff_s", 0.05)
        self.nodes: tuple[str, ...] = tuple(f"{h}:{p}" for h, p in endpoints)
        self.ring = HashRing(self.nodes, vnodes=vnodes)
        self.replication = min(replication, len(self.nodes))
        self.down_cooldown_s = down_cooldown_s
        # one registry across the per-shard clients: their series carry a
        # ``shard`` label, so sharing never collides
        self.metrics = registry if registry is not None else MetricsRegistry()
        self._shards: dict[str, RemoteBackend] = {
            node: RemoteBackend(
                f"tcp://{node}", client_id=client_id, registry=self.metrics,
                **backend_kw,
            )
            for node in self.nodes
        }
        self.client_id = next(iter(self._shards.values())).client_id
        for rb in self._shards.values():
            rb.client_id = self.client_id  # one identity across the cluster
        self._lock = threading.Lock()
        self._down_until: dict[str, float] = {}  # node -> monotonic retry time
        self._lease_routes: dict[tuple[str, str], str] = {}  # (key, token) -> node
        # cluster-health counters on the registry; the attribute names the
        # tests and benchmarks assert on survive below as read-only aliases
        self._m_failover_reads = self.metrics.counter(
            "repro_cluster_failover_reads_total",
            "reads served by a non-first live replica",
        )
        self._m_read_repairs = self.metrics.counter(
            "repro_cluster_read_repairs_total",
            "blobs healed back onto a lagging replica",
        )
        self._m_lease_failovers = self.metrics.counter(
            "repro_cluster_lease_failovers_total",
            "lease ops that left the key's primary",
        )

    # -- shard health ----------------------------------------------------------
    def _is_down(self, node: str) -> bool:
        with self._lock:
            until = self._down_until.get(node)
            return until is not None and time.monotonic() < until

    def _mark_down(self, node: str) -> None:
        with self._lock:
            self._down_until[node] = time.monotonic() + self.down_cooldown_s

    def _mark_up(self, node: str) -> None:
        with self._lock:
            self._down_until.pop(node, None)

    def _replicas(self, key: str) -> list[str]:
        return self.ring.replicas(key, self.replication)

    def _candidates(self, targets: Sequence[str]) -> tuple[list[str], int]:
        """The nodes an op should actually dial, in preference order, plus
        the count of within-cooldown shards it must treat as unreachable
        WITHOUT dialing (redialing a dead endpoint on every presence probe
        would pay full connect retries per op, serialized under the store
        lock).  A shard whose cooldown expired counts as live again — that
        is how recovery is noticed.  When every target is inside its
        cooldown, probe them all anyway: the fleet must never lock itself
        out by having marked everything down."""
        live = [n for n in targets if not self._is_down(n)]
        if not live:
            return list(targets), 0
        return live, len(targets) - len(live)

    # -- blob ops --------------------------------------------------------------
    def write_blob(self, key: str, name: str, data: bytes) -> int:
        """Write to every replica of ``key``; >= 1 must land.  Like
        ``delete`` — and unlike the read paths — this dials replicas inside
        their down-cooldown too: a transient blip must not silently
        under-replicate a fresh artifact (read-repair only heals a lagging
        replica when a *preferred* one fails, so a skipped write could stay
        single-copy until the exact moment redundancy is needed)."""
        targets = self._replicas(key)
        nbytes: int | None = None
        last: Exception | None = None
        for node in targets:
            try:
                n = self._shards[node].write_blob(key, name, data)
            except BackendUnavailable as e:
                self._mark_down(node)
                last = e
                continue
            self._mark_up(node)
            if nbytes is None:
                nbytes = n
        if nbytes is None:
            raise StoreUnreachable(
                f"no replica of {key!r} reachable for write "
                f"(tried {targets}): {last}"
            ) from last
        return nbytes

    def read_blob(self, key: str, name: str) -> bytes:
        targets = self._replicas(key)
        to_try, unreachable = self._candidates(targets)
        missing: list[str] = []  # alive replicas that answered "not found"
        corrupt: list[str] = []  # alive replicas whose copy failed its digest
        last: Exception | None = None
        for node in to_try:
            try:
                data = self._shards[node].read_blob(key, name)
            except (KeyError, FileNotFoundError) as e:
                missing.append(node)
                last = e
                continue
            except IntegrityError as e:
                # bit rot on this replica: another may hold a verified-good
                # copy — replication's whole point.  Repair it if one does.
                corrupt.append(node)
                last = e
                continue
            except BackendUnavailable as e:
                self._mark_down(node)
                unreachable += 1
                last = e
                continue
            self._mark_up(node)
            if node != targets[0]:
                # served by a non-primary replica — whether we fell through
                # this very op or the primary was already marked down
                self._m_failover_reads.inc()
            self._repair(key, name, data, missing + corrupt)
            return data
        if corrupt and unreachable == 0:
            raise IntegrityError(
                f"blob {key}/{name}: every reachable replica holding it is "
                f"corrupt ({corrupt})"
            ) from last
        if unreachable == 0:
            raise KeyError(f"{key}/{name}") from last
        raise StoreUnreachable(
            f"blob {key}/{name}: {unreachable} replica(s) unreachable and no "
            f"reachable replica holds it"
        ) from last

    def _repair(self, key: str, name: str, data: bytes, lagging: list[str]) -> None:
        """Best-effort write-back to alive replicas that missed the blob
        (restarted empty, down during the original write, or bit-rotten)."""
        for node in lagging:
            try:
                self._shards[node].write_blob(key, name, data)
            except BackendUnavailable:
                self._mark_down(node)
            else:
                self._m_read_repairs.inc()

    def delete(self, key: str) -> None:
        """Delete on every replica — deliberately including shards inside
        their down-cooldown (a skipped delete is a future resurrection, the
        static-membership caveat in the docs; a skipped write is only a
        pending repair)."""
        targets = self._replicas(key)
        reached = False
        last: Exception | None = None
        for node in targets:
            try:
                self._shards[node].delete(key)
            except BackendUnavailable as e:
                self._mark_down(node)
                last = e
                continue
            self._mark_up(node)
            reached = True
        if not reached:
            raise StoreUnreachable(
                f"no replica of {key!r} reachable for delete (tried {targets})"
            ) from last

    def exists(self, key: str) -> bool:
        """True on the first replica that has the key.  ``False`` is only
        trusted when every replica was reachable and answered no: an
        unreachable replica might be the sole holder, and a false negative
        would make the planner recompute-and-overwrite — raise instead so
        ``store.has`` degrades to "not reusable right now"."""
        to_try, unreachable = self._candidates(self._replicas(key))
        last: Exception | None = None
        for node in to_try:
            try:
                present = self._shards[node].exists(key)
            except BackendUnavailable as e:
                self._mark_down(node)
                unreachable += 1
                last = e
                continue
            self._mark_up(node)
            if present:
                return True
        if unreachable == 0:
            return False
        raise StoreUnreachable(
            f"presence of {key!r} undecidable: {unreachable} replica(s) "
            f"unreachable, none of the reachable ones hold it"
        ) from last

    def exists_many(self, keys: "Sequence[str]") -> dict[str, "bool | None"]:
        """Batched presence probe across the cluster: group every key's
        replica set by node, send **at most one ``batch`` request per
        involved shard**, and merge with ``exists``'s exact semantics —
        ``True`` on any replica's yes; ``False`` only when every replica of
        the key was reachable and said no; ``None`` (undecidable) otherwise.
        Unlike :meth:`exists` this never raises for an undecidable key — a
        deep probe walk must report what it *can* decide in one round."""
        keys = list(dict.fromkeys(keys))
        if not keys:
            return {}
        node_keys: dict[str, list[str]] = {}
        unreachable: dict[str, int] = {k: 0 for k in keys}
        votes: dict[str, list[bool]] = {k: [] for k in keys}
        for k in keys:
            to_try, skipped = self._candidates(self._replicas(k))
            unreachable[k] = skipped
            for node in to_try:
                node_keys.setdefault(node, []).append(k)
        for node, ks in node_keys.items():
            shard = self._shards[node]
            results: list[dict[str, Any]] = []
            try:
                for start in range(0, len(ks), MAX_BATCH_OPS):
                    group = ks[start : start + MAX_BATCH_OPS]
                    results.extend(shard.batch([{"op": "exists", "key": k} for k in group]))
            except BackendUnavailable:
                self._mark_down(node)
                for k in ks:
                    unreachable[k] += 1
                continue
            self._mark_up(node)
            for k, r in zip(ks, results):
                if r.get("ok"):
                    votes[k].append(bool(r.get("exists")))
                else:
                    unreachable[k] += 1
        out: dict[str, bool | None] = {}
        for k in keys:
            if any(votes[k]):
                out[k] = True
            elif unreachable[k] == 0:
                out[k] = False
            else:
                out[k] = None
        return out

    def nbytes(self, key: str) -> int:
        to_try, _ = self._candidates(self._replicas(key))
        best: int | None = None
        last: Exception | None = None
        for node in to_try:
            try:
                n = self._shards[node].nbytes(key)
            except BackendUnavailable as e:
                self._mark_down(node)
                last = e
                continue
            self._mark_up(node)
            # replicas can lag (repair pending): report the fullest copy
            best = n if best is None else max(best, n)
        if best is None:
            raise StoreUnreachable(
                f"no replica of {key!r} reachable for nbytes"
            ) from last
        return best

    # -- meta ops --------------------------------------------------------------
    # Store-level metadata (index.json — a crash-safe stats cache, never a
    # source of truth) is broadcast to every shard so any single survivor
    # can seed a fresh client's adoption stats.
    def write_meta(self, name: str, text: str) -> None:
        to_try, _ = self._candidates(self.nodes)
        reached = False
        last: Exception | None = None
        for node in to_try:
            try:
                self._shards[node].write_meta(name, text)
            except BackendUnavailable as e:
                self._mark_down(node)
                last = e
                continue
            self._mark_up(node)
            reached = True
        if not reached:
            raise StoreUnreachable(f"no shard reachable for write_meta {name!r}") from last

    def read_meta(self, name: str) -> str | None:
        to_try, _ = self._candidates(self.ring.order(name))
        last: Exception | None = None
        reached = False
        for node in to_try:
            try:
                text = self._shards[node].read_meta(name)
            except BackendUnavailable as e:
                self._mark_down(node)
                last = e
                continue
            self._mark_up(node)
            reached = True
            if text is not None:
                return text
        if reached:
            return None  # every reachable shard agrees it is absent
        raise StoreUnreachable(f"no shard reachable for read_meta {name!r}") from last

    # -- catalog ops -------------------------------------------------------------
    def catalog_put(self, doc: dict[str, Any]) -> bool:
        """Mirror a catalog record onto the SAME replica set as the blob it
        describes — when a shard dies, the survivors that still serve the
        artifact also still answer queries about it.  Like ``write_blob``
        this dials replicas inside their down-cooldown (a skipped mirror
        would leave a replica serving a blob its catalog has never heard
        of).  True when >= 1 replica accepted."""
        key = str(doc.get("key", ""))
        if not key:
            return False
        landed = False
        for node in self._replicas(key):
            try:
                ok = self._shards[node].catalog_put(doc)
            except BackendUnavailable:
                self._mark_down(node)
                continue
            self._mark_up(node)
            landed = landed or ok
        return landed

    def catalog_remove(self, key: str) -> bool:
        """Drop a record on every replica (mirrors ``delete``'s discipline:
        cooldown shards are dialed too — a skipped removal is a future
        phantom)."""
        reached = False
        for node in self._replicas(key):
            try:
                ok = self._shards[node].catalog_remove(key)
            except BackendUnavailable:
                self._mark_down(node)
                continue
            self._mark_up(node)
            reached = reached or ok
        return reached

    def catalog_query(self, query_doc: dict[str, Any]) -> "list[dict[str, Any]] | None":
        """Fan the query out to every live shard and merge, deduplicating by
        key (replication means up to R shards answer for one artifact —
        keep the copy with the freshest stats).  ``None`` only when no shard
        answered at all; a partial cluster still returns what the reachable
        shards know, which is exactly the replica-surviving answer the
        kill-one-shard guarantee needs."""
        to_try, _ = self._candidates(self.nodes)
        merged: dict[str, dict[str, Any]] = {}
        answered = False
        for node in to_try:
            try:
                results = self._shards[node].catalog_query(query_doc)
            except BackendUnavailable:
                self._mark_down(node)
                continue
            self._mark_up(node)
            if results is None:  # pre-catalog shard: no vote either way
                continue
            answered = True
            for doc in results:
                key = str(doc.get("key", ""))
                old = merged.get(key)
                if old is None or float(doc.get("last_used_at", 0) or 0) > float(
                    old.get("last_used_at", 0) or 0
                ):
                    merged[key] = doc
        if not answered:
            return None
        return list(merged.values())

    # -- coordination ----------------------------------------------------------
    def lease_acquire(
        self, key: str, *, wait: bool = True, timeout_s: float = 300.0
    ) -> LeaseGrant:
        """Contend on the key's ring primary, falling over clockwise.

        All contenders walk the same order and skip the same down shards, so
        after a primary death the fleet re-converges on one stand-in
        electorate: a waiter whose blocked acquire dies with the shard
        retries here and lands on the next live node, where election
        proceeds (exactly-once is then restored by the stored-artifact probe
        every producer runs before computing).
        """
        to_try, _ = self._candidates(self.ring.order(key))
        last: Exception | None = None
        for node in to_try:
            try:
                grant = self._shards[node].lease_acquire(
                    key, wait=wait, timeout_s=timeout_s
                )
            except BackendUnavailable as e:
                self._mark_down(node)
                last = e
                continue
            self._mark_up(node)
            if node != self.ring.primary(key):
                self._m_lease_failovers.inc()
            if grant.granted:
                with self._lock:
                    self._lease_routes[(key, grant.token)] = node
            return grant
        raise StoreUnreachable(f"no shard reachable to lease {key!r}") from last

    def lease_release(self, key: str, token: str, *, stored: bool) -> None:
        with self._lock:
            node = self._lease_routes.pop((key, token), None)
        if node is None:
            node = self.ring.primary(key)
        try:
            self._shards[node].lease_release(key, token, stored=stored)
        except BackendUnavailable:
            # the granting shard is gone — and with it the lease table entry
            # (its death already auto-released every lease it held)
            self._mark_down(node)

    # -- events / introspection ------------------------------------------------
    def add_event_listener(self, fn: Callable[[str, str], None]) -> None:
        """Subscribe ``fn(event, key)`` to EVERY shard's event stream.  A
        replicated delete broadcasts from up to R shards; listeners must be
        idempotent per key (cache invalidation and record-drop both are)."""
        for rb in self._shards.values():
            rb.add_event_listener(fn)

    def server_stats(self) -> dict[str, Any]:
        """Aggregate + per-shard server counters (``None`` for dead shards)."""
        shards: dict[str, Any] = {}
        ops: dict[str, int] = {}
        total = 0
        for node, rb in self._shards.items():
            try:
                st = rb.server_stats()
            except BackendUnavailable:
                self._mark_down(node)
                shards[node] = None
                continue
            self._mark_up(node)
            shards[node] = st
            total += st.get("requests", 0)
            for op, n in st.get("ops", {}).items():
                ops[op] = ops.get(op, 0) + n
        return {"requests": total, "ops": ops, "shards": shards}

    def metrics_doc(self) -> dict[str, Any]:
        """Cluster-wide metrics merge: fan the ``metrics`` op out to every
        shard and fold the docs element-wise (fixed histogram buckets make
        that exact), stamping each shard's series with ``shard=host:port`` so
        non-additive gauges (uptime, connections) never sum across shards.
        Dead or pre-metrics shards simply contribute nothing."""
        docs: list[dict[str, Any] | None] = []
        extras: list[dict[str, str] | None] = []
        for node, rb in self._shards.items():
            try:
                doc = rb.metrics_doc()
            except BackendUnavailable:
                self._mark_down(node)
                continue
            self._mark_up(node)
            if doc is None:
                continue
            docs.append(doc)
            extras.append({"shard": node})
        return merge_docs(docs, extras)

    def ping_all(self) -> dict[str, bool]:
        out: dict[str, bool] = {}
        for node, rb in self._shards.items():
            try:
                out[node] = rb.ping()
            except BackendUnavailable:
                self._mark_down(node)
                out[node] = False
        return out

    def ping(self) -> bool:
        return all(self.ping_all().values())

    @property
    def reconnects(self) -> int:
        return sum(rb.reconnects for rb in self._shards.values())

    @property
    def failover_reads(self) -> int:
        """Deprecated alias of ``repro_cluster_failover_reads_total``."""
        return int(self._m_failover_reads.value)

    @property
    def read_repairs(self) -> int:
        """Deprecated alias of ``repro_cluster_read_repairs_total``."""
        return int(self._m_read_repairs.value)

    @property
    def lease_failovers(self) -> int:
        """Deprecated alias of ``repro_cluster_lease_failovers_total``."""
        return int(self._m_lease_failovers.value)

    def shard_for(self, key: str) -> str:
        """The key's current ring primary (benchmarks pick kill victims)."""
        return self.ring.primary(key)

    def close(self) -> None:
        for rb in self._shards.values():
            rb.close()
