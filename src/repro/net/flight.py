"""Distributed single-flight: one computer per uncomputed prefix, fleet-wide.

PR 2's :class:`~repro.sched.singleflight.SingleFlight` coalesces concurrent
computes of one store key *within* a process; this extends the election
across processes using the store service's lease table.  Two levels compose:

  1. locally, threads coalesce exactly as before (followers receive the
     leader's in-memory value — no store round-trip at all);
  2. the local leader then contends for the service-side lease.  Granted →
     it is the fleet-wide leader: it computes, stores through the normal
     admission path, and releases the lease with a ``stored`` bit.  Denied →
     it blocks until the remote leader releases, then simply re-runs its
     produce function: the function's own "is it in the store?" probe now
     finds the leader's artifact and loads it.

The lease provider is anything with the ``lease_acquire``/``lease_release``
surface — a single :class:`~repro.net.client.RemoteBackend`, or a
:class:`~repro.net.sharded.ShardedBackend` that routes the election to the
key's ring primary and falls over along the ring when that shard dies:
waiters whose blocked acquire dies with the shard re-contend and re-elect
on the next live node, so exactly-once stem election survives a shard death
mid-run (the per-round store probe below is what squeezes out the rare
double-compute window a mid-election death opens).

When the remote leader did *not* store (admission gate rejected it, or the
leader crashed — crashed leaders are auto-released by the server), waiters
re-contend for the lease so computes happen one-at-a-time rather than as a
thundering herd; after ``max_rounds`` unproductive waits a caller gives up
coordinating and computes locally — progress is never hostage to the
coordination layer.  The same applies when the lease service itself is
unreachable (every shard down): the flight degrades to an uncoordinated
local compute instead of failing the run.  Unlike the in-process flight, a
remote leader's exception is *not* propagated to followers (exceptions
don't cross the wire); followers recompute and surface their own.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Protocol, runtime_checkable

from ..core.backends import BackendUnavailable
from ..obs import tracing as _tracing
from ..obs.metrics import MetricsRegistry
from ..sched.singleflight import SingleFlight
from .client import LeaseGrant


@runtime_checkable
class LeaseProvider(Protocol):
    """What the flight needs from the coordination layer: per-key leases.

    Satisfied by ``RemoteBackend`` (one server's lease table) and
    ``ShardedBackend`` (ring-primary election with failover).
    """

    def lease_acquire(
        self, key: str, *, wait: bool = True, timeout_s: float = 300.0
    ) -> LeaseGrant: ...

    def lease_release(self, key: str, token: str, *, stored: bool) -> None: ...


class DistributedSingleFlight(SingleFlight):
    """Per-key compute deduplication across threads *and* processes."""

    def __init__(
        self,
        remote: LeaseProvider,
        stored_fn: Callable[[str], bool] | None = None,
        lease_timeout_s: float = 300.0,
        max_rounds: int = 3,
        registry: MetricsRegistry | None = None,
    ) -> None:
        super().__init__(registry=registry)
        self.remote = remote
        # tells the leader whether its compute actually landed in the store
        # (the admission gate may have rejected it); wired to ``store.has``
        self.stored_fn = stored_fn
        self.lease_timeout_s = lease_timeout_s
        self.max_rounds = max_rounds
        self._m_remote_leads = self.metrics.counter(
            "repro_singleflight_remote_leads_total", "flights this process led fleet-wide"
        )
        self._m_remote_waits = self.metrics.counter(
            "repro_singleflight_remote_waits_total",
            "flights coalesced onto another process's compute",
        )
        self._m_uncoordinated = self.metrics.counter(
            "repro_singleflight_uncoordinated_total",
            "flights run without a reachable lease service",
        )
        self._m_lease_wait_s = self.metrics.histogram(
            "repro_singleflight_lease_wait_seconds",
            "time spent blocked on another process's lease",
        )

    @property
    def remote_leads(self) -> int:
        """Deprecated alias of ``repro_singleflight_remote_leads_total``."""
        return int(self._m_remote_leads.value)

    @property
    def remote_waits(self) -> int:
        """Deprecated alias of ``repro_singleflight_remote_waits_total``."""
        return int(self._m_remote_waits.value)

    @property
    def uncoordinated(self) -> int:
        """Deprecated alias of ``repro_singleflight_uncoordinated_total``."""
        return int(self._m_uncoordinated.value)

    def _stored(self, key: str) -> bool:
        if self.stored_fn is None:
            return False
        try:
            return bool(self.stored_fn(key))
        except BackendUnavailable:
            # presence undecidable (replicas down): treat as not stored —
            # worst case is a redundant compute, never a lost artifact
            return False

    def run(
        self,
        key: str,
        fn: Callable[[], Any],
        timeout: float | None = None,
    ) -> tuple[Any, bool]:
        (value, remote_leader), local_leader = super().run(
            key, lambda: self._coordinate(key, fn), timeout
        )
        return value, local_leader and remote_leader

    def _coordinate(self, key: str, fn: Callable[[], Any]) -> tuple[Any, bool]:
        # already stored: no election needed — contending would serialize
        # the fleet's *loads* behind one lease for no benefit
        if self._stored(key):
            return fn(), True
        for round_no in range(self.max_rounds):
            if round_no and self._stored(key):
                # the previous leader stored it but its release got lost with
                # a dying shard (stored bit never reached us): load, don't
                # recompute — this probe is what keeps election exactly-once
                # across a mid-run shard death
                return fn(), False
            sp = _tracing.span("lease.acquire", kind="lease", key=key)
            t0 = time.monotonic()
            try:
                with sp:
                    grant = self.remote.lease_acquire(
                        key, wait=True, timeout_s=self.lease_timeout_s
                    )
                    sp.set(granted=grant.granted)
                    if not grant.granted:
                        # the blocking acquire above *was* the wait on the
                        # fleet leader — surface it under its real name
                        sp.rename("lease.wait")
            except BackendUnavailable:
                # the whole coordination layer is unreachable: compute
                # locally rather than wedging the run on it
                self._m_uncoordinated.inc()
                return fn(), True
            if grant.granted:
                self._m_remote_leads.inc()
                try:
                    value = fn()
                except BaseException:
                    self._release(key, grant.token, stored=False)
                    raise
                self._release(key, grant.token, stored=self._stored(key))
                return value, True
            self._m_remote_waits.inc()
            self._m_waits.inc()
            self._m_lease_wait_s.observe(time.monotonic() - t0)
            if grant.stored:
                # the fleet leader stored it: fn's store probe loads it now
                return fn(), False
            # leader stored nothing (rejected/failed/timed out): contend again
        return fn(), True  # coordination exhausted — compute unilaterally

    def _release(self, key: str, token: str, *, stored: bool) -> None:
        try:
            self.remote.lease_release(key, token, stored=stored)
        except BackendUnavailable:
            # the granting shard died holding the lease: its death already
            # auto-released every lease it held, so waiters are not wedged
            pass
