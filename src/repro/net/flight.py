"""Distributed single-flight: one computer per uncomputed prefix, fleet-wide.

PR 2's :class:`~repro.sched.singleflight.SingleFlight` coalesces concurrent
computes of one store key *within* a process; this extends the election
across processes using the store server's lease table.  Two levels compose:

  1. locally, threads coalesce exactly as before (followers receive the
     leader's in-memory value — no store round-trip at all);
  2. the local leader then contends for the server-side lease.  Granted →
     it is the fleet-wide leader: it computes, stores through the normal
     admission path, and releases the lease with a ``stored`` bit.  Denied →
     it blocks until the remote leader releases, then simply re-runs its
     produce function: the function's own "is it in the store?" probe now
     finds the leader's artifact and loads it.

When the remote leader did *not* store (admission gate rejected it, or the
leader crashed — crashed leaders are auto-released by the server), waiters
re-contend for the lease so computes happen one-at-a-time rather than as a
thundering herd; after ``max_rounds`` unproductive waits a caller gives up
coordinating and computes locally — progress is never hostage to the
coordination layer.  Unlike the in-process flight, a remote leader's
exception is *not* propagated to followers (exceptions don't cross the
wire); followers recompute and surface their own.
"""
from __future__ import annotations

from typing import Any, Callable

from ..sched.singleflight import SingleFlight
from .client import RemoteBackend


class DistributedSingleFlight(SingleFlight):
    """Per-key compute deduplication across threads *and* processes."""

    def __init__(
        self,
        remote: RemoteBackend,
        stored_fn: Callable[[str], bool] | None = None,
        lease_timeout_s: float = 300.0,
        max_rounds: int = 3,
    ) -> None:
        super().__init__()
        self.remote = remote
        # tells the leader whether its compute actually landed in the store
        # (the admission gate may have rejected it); wired to ``store.has``
        self.stored_fn = stored_fn
        self.lease_timeout_s = lease_timeout_s
        self.max_rounds = max_rounds
        self.remote_leads = 0  # flights this process led fleet-wide
        self.remote_waits = 0  # flights coalesced onto another process

    def run(
        self,
        key: str,
        fn: Callable[[], Any],
        timeout: float | None = None,
    ) -> tuple[Any, bool]:
        (value, remote_leader), local_leader = super().run(
            key, lambda: self._coordinate(key, fn), timeout
        )
        return value, local_leader and remote_leader

    def _coordinate(self, key: str, fn: Callable[[], Any]) -> tuple[Any, bool]:
        # already stored: no election needed — contending would serialize
        # the fleet's *loads* behind one lease for no benefit
        if self.stored_fn is not None and self.stored_fn(key):
            return fn(), True
        for _ in range(self.max_rounds):
            grant = self.remote.lease_acquire(
                key, wait=True, timeout_s=self.lease_timeout_s
            )
            if grant.granted:
                self.remote_leads += 1
                try:
                    value = fn()
                except BaseException:
                    self.remote.lease_release(key, grant.token, stored=False)
                    raise
                stored = bool(self.stored_fn(key)) if self.stored_fn else False
                self.remote.lease_release(key, grant.token, stored=stored)
                return value, True
            with self._lock:
                self.remote_waits += 1
                self.waits += 1
            if grant.stored:
                # the fleet leader stored it: fn's store probe loads it now
                return fn(), False
            # leader stored nothing (rejected/failed/timed out): contend again
        return fn(), True  # coordination exhausted — compute unilaterally
