"""Read-through blob cache: hot prefixes at local speed, shared pool behind.

``CachingBackend`` wraps any :class:`~repro.core.backends.StorageBackend`
(in practice a :class:`~repro.net.client.RemoteBackend`) with a bounded,
digest-validated LRU over individual blobs.  The workflow access pattern
it exploits is extremely cache-friendly: a reused prefix is *immutable* —
its content-addressed key never changes meaning — so a blob fetched once
can be served locally forever, and the only invalidation that exists is
whole-artifact eviction, delivered by the server's event stream.

Every cached entry keeps the SHA-256 of its bytes and is re-verified on
hit; a corrupted entry silently falls back to a fresh fetch.  ``exists``/
meta ops are deliberately *not* cached: presence is the one question whose
answer other processes change (stores, evictions), and a stale positive
would make the planner skip a compute it still needs.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from ..core.backends import StorageBackend
from .protocol import digest


class CachingBackend(StorageBackend):
    """Bounded LRU blob cache in front of a slower (remote) backend."""

    name = "caching"

    def __init__(
        self,
        inner: StorageBackend,
        capacity_bytes: int = 256 * 1024 * 1024,
    ) -> None:
        self.inner = inner
        self.capacity_bytes = capacity_bytes
        self._lock = threading.Lock()
        self._blobs: OrderedDict[tuple[str, str], tuple[bytes, str]] = OrderedDict()
        self._nbytes = 0
        self.hits = 0
        self.misses = 0
        self.validation_failures = 0

    # -- cache bookkeeping (callers hold the lock) ---------------------------
    def _insert(self, key: str, name: str, data: bytes) -> None:
        if len(data) > self.capacity_bytes:
            return
        ck = (key, name)
        prev = self._blobs.pop(ck, None)
        if prev is not None:
            self._nbytes -= len(prev[0])
        self._blobs[ck] = (data, digest(data))
        self._nbytes += len(data)
        while self._nbytes > self.capacity_bytes and self._blobs:
            _, (old, _d) = self._blobs.popitem(last=False)
            self._nbytes -= len(old)

    def _purge(self, key: str) -> None:
        for ck in [ck for ck in self._blobs if ck[0] == key]:
            data, _ = self._blobs.pop(ck)
            self._nbytes -= len(data)

    def invalidate(self, key: str) -> None:
        """Drop every cached blob of ``key`` (wired to eviction events)."""
        with self._lock:
            self._purge(key)

    @property
    def cached_bytes(self) -> int:
        with self._lock:
            return self._nbytes

    # -- StorageBackend --------------------------------------------------------
    def write_blob(self, key: str, name: str, data: bytes) -> int:
        n = self.inner.write_blob(key, name, data)
        with self._lock:
            self._insert(key, name, data)
        return n

    def read_blob(self, key: str, name: str) -> bytes:
        with self._lock:
            entry = self._blobs.get((key, name))
            if entry is not None:
                self._blobs.move_to_end((key, name))
        if entry is not None:
            data, want = entry
            # hash OUTSIDE the lock: concurrent hits on large blobs must not
            # serialize behind each other's digest computation
            if digest(data) == want:
                with self._lock:
                    self.hits += 1
                return data
            with self._lock:
                # bit-rot in the cache: drop (if still ours) and re-fetch
                self.validation_failures += 1
                cur = self._blobs.get((key, name))
                if cur is not None and cur[0] is data:
                    self._blobs.pop((key, name))
                    self._nbytes -= len(data)
        with self._lock:
            self.misses += 1
        data = self.inner.read_blob(key, name)
        with self._lock:
            self._insert(key, name, data)
        return data

    def delete(self, key: str) -> None:
        self.inner.delete(key)
        with self._lock:
            self._purge(key)

    def exists(self, key: str) -> bool:
        return self.inner.exists(key)

    def write_meta(self, name: str, text: str) -> None:
        self.inner.write_meta(name, text)

    def read_meta(self, name: str) -> str | None:
        return self.inner.read_meta(name)

    def nbytes(self, key: str) -> int:
        return self.inner.nbytes(key)
