"""Read-through blob cache: hot prefixes at local speed, shared pool behind.

``CachingBackend`` wraps any :class:`~repro.core.backends.StorageBackend`
(in practice a :class:`~repro.net.client.RemoteBackend` or a
:class:`~repro.net.sharded.ShardedBackend`) with a bounded, digest-validated
LRU over individual blobs.  The workflow access pattern it exploits is
extremely cache-friendly: a reused prefix is *immutable* — its
content-addressed key never changes meaning — so a blob fetched once can be
served locally forever, and the only invalidation that exists is
whole-artifact eviction, delivered by the server's event stream.

Two pieces of bookkeeping keep that invalidation correct and cheap:

  * an **invalidation generation** per key — the inner fetch on a miss (and
    the inner write on a put) runs *outside* the lock, so an eviction event
    can land in between; inserting the stale bytes afterwards would
    resurrect a dead blob.  Each ``invalidate``/``delete`` bumps the key's
    generation; an insert only lands if the generation it captured before
    going to the network is still current.
  * a **key -> blob-names index** — eviction events arrive one *key* at a
    time, but the LRU is keyed by ``(key, name)``.  The index makes
    ``invalidate`` O(blobs-of-key) instead of a full O(cache) scan per
    event, which matters under a busy fleet-wide eviction stream.

Every cached entry keeps the SHA-256 of its bytes and is re-verified on
hit; a corrupted entry silently falls back to a fresh fetch.  ``exists``/
meta ops are deliberately *not* cached: presence is the one question whose
answer other processes change (stores, evictions), and a stale positive
would make the planner skip a compute it still needs.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from ..core.backends import StorageBackend
from ..obs.metrics import MetricsRegistry
from .protocol import digest


class CachingBackend(StorageBackend):
    """Bounded LRU blob cache in front of a slower (remote) backend."""

    name = "caching"

    def __init__(
        self,
        inner: StorageBackend,
        capacity_bytes: int = 256 * 1024 * 1024,
        max_entry_fraction: float = 0.5,
        registry: MetricsRegistry | None = None,
    ) -> None:
        if not 0.0 < max_entry_fraction <= 1.0:
            raise ValueError("max_entry_fraction must be in (0, 1]")
        self.inner = inner
        self.capacity_bytes = capacity_bytes
        # one blob may occupy at most this fraction of the cache: a single
        # multi-GB artifact passing through must not evict the entire hot
        # set of small, frequently-reused prefixes to buy one doomed entry
        self.max_entry_bytes = int(capacity_bytes * max_entry_fraction)
        self._lock = threading.Lock()
        self._blobs: OrderedDict[tuple[str, str], tuple[bytes, str]] = OrderedDict()
        self._names: dict[str, set[str]] = {}  # key -> cached blob names
        # invalidation fencing: _gen[key] exists only while an invalidation
        # raced an in-flight fetch of that key; _inflight counts the fetches.
        # Both dicts are bounded by current fetch concurrency, not by the
        # eviction-event volume.
        self._gen: dict[str, int] = {}  # key -> invalidation generation
        self._inflight: dict[str, int] = {}  # key -> fetches on the wire
        self._nbytes = 0
        # counters live on the unified registry; the bare attribute names
        # survive as read-only aliases below
        self.metrics = registry if registry is not None else MetricsRegistry()
        m = self.metrics
        self._m_hits = m.counter("repro_cache_hits_total", "blob reads served locally")
        self._m_misses = m.counter(
            "repro_cache_misses_total", "blob reads that went to the inner backend"
        )
        self._m_validation_failures = m.counter(
            "repro_cache_validation_failures_total",
            "cached entries that failed digest re-verification",
        )
        self._m_stale_dropped = m.counter(
            "repro_cache_stale_inserts_dropped_total",
            "fetches outrun by an invalidation",
        )
        self._m_purge_examined = m.counter(
            "repro_cache_purge_examined_total",
            "entries looked at by invalidations (O() proof)",
        )
        self._m_oversize = m.counter(
            "repro_cache_oversize_rejected_total",
            "blobs too large to be worth caching",
        )
        m.gauge(
            "repro_cache_bytes", "bytes currently held by the LRU"
        ).unlabeled.set_function(lambda: self._nbytes)
        m.gauge(
            "repro_cache_entries", "blobs currently held by the LRU"
        ).unlabeled.set_function(lambda: len(self._blobs))

    # -- deprecated counter aliases ---------------------------------------------
    @property
    def hits(self) -> int:
        """Deprecated alias of ``repro_cache_hits_total``."""
        return int(self._m_hits.value)

    @property
    def misses(self) -> int:
        """Deprecated alias of ``repro_cache_misses_total``."""
        return int(self._m_misses.value)

    @property
    def validation_failures(self) -> int:
        """Deprecated alias of ``repro_cache_validation_failures_total``."""
        return int(self._m_validation_failures.value)

    @property
    def stale_inserts_dropped(self) -> int:
        """Deprecated alias of ``repro_cache_stale_inserts_dropped_total``."""
        return int(self._m_stale_dropped.value)

    @property
    def purge_examined(self) -> int:
        """Deprecated alias of ``repro_cache_purge_examined_total``."""
        return int(self._m_purge_examined.value)

    @property
    def oversize_rejected(self) -> int:
        """Deprecated alias of ``repro_cache_oversize_rejected_total``."""
        return int(self._m_oversize.value)

    # -- cache bookkeeping (callers hold the lock) ---------------------------
    def _insert(self, key: str, name: str, data: bytes, gen: int) -> None:
        if self._gen.get(key, 0) != gen:
            # an eviction event landed while the bytes were in flight:
            # inserting now would resurrect a dead blob
            self._m_stale_dropped.inc()
            return
        if len(data) > self.max_entry_bytes:
            self._m_oversize.inc()
            return
        ck = (key, name)
        prev = self._blobs.pop(ck, None)
        if prev is not None:
            self._nbytes -= len(prev[0])
        if not isinstance(data, bytes):
            # writes may pass a memoryview over a live buffer (KV codec's
            # zero-copy path); cache an immutable snapshot, never an alias
            data = bytes(data)
        self._blobs[ck] = (data, digest(data))
        self._names.setdefault(key, set()).add(name)
        self._nbytes += len(data)
        while self._nbytes > self.capacity_bytes and self._blobs:
            okey, oname = next(iter(self._blobs))
            self._drop_entry(okey, oname)

    def _drop_entry(self, key: str, name: str) -> None:
        """Remove one blob from the LRU + byte accounting + name index —
        the single place the three structures' invariant is maintained.
        Callers hold the lock."""
        entry = self._blobs.pop((key, name), None)
        if entry is not None:
            self._nbytes -= len(entry[0])
        names = self._names.get(key)
        if names is not None:
            names.discard(name)
            if not names:
                del self._names[key]

    def _fetch_begin(self, key: str) -> int:
        """Register an about-to-go-on-the-wire fetch; returns the generation
        an eventual insert must still match.  Callers hold the lock."""
        self._inflight[key] = self._inflight.get(key, 0) + 1
        return self._gen.get(key, 0)

    def _fetch_end(self, key: str, name: str, data: bytes | None, gen: int) -> None:
        """Complete a fetch: insert (if it produced bytes and no invalidation
        outran it) and retire the fence once the last fetch lands."""
        with self._lock:
            if data is not None:
                self._insert(key, name, data, gen)
            n = self._inflight.get(key, 0) - 1
            if n <= 0:
                self._inflight.pop(key, None)
                self._gen.pop(key, None)  # no fetch left that could race it
            else:
                self._inflight[key] = n

    def _purge(self, key: str) -> None:
        """Drop every cached blob of ``key`` via the name index —
        O(blobs-of-key), never a scan of the whole LRU."""
        if key in self._inflight:
            # fence the racing fetch(es): their eventual insert must lose
            self._gen[key] = self._gen.get(key, 0) + 1
        names = self._names.pop(key, None)
        if not names:
            return
        for name in names:
            self._m_purge_examined.inc()
            self._drop_entry(key, name)

    def invalidate(self, key: str) -> None:
        """Drop every cached blob of ``key`` (wired to eviction events) and
        fence out any in-flight fetch of its stale bytes."""
        with self._lock:
            self._purge(key)

    @property
    def cached_bytes(self) -> int:
        with self._lock:
            return self._nbytes

    # -- StorageBackend --------------------------------------------------------
    def write_blob(self, key: str, name: str, data: bytes) -> int:
        with self._lock:
            gen = self._fetch_begin(key)
        ok = False
        try:
            n = self.inner.write_blob(key, name, data)
            ok = True
        finally:
            self._fetch_end(key, name, data if ok else None, gen)
        return n

    def read_blob(self, key: str, name: str) -> bytes:
        with self._lock:
            entry = self._blobs.get((key, name))
            if entry is not None:
                self._blobs.move_to_end((key, name))
        if entry is not None:
            data, want = entry
            # hash OUTSIDE the lock: concurrent hits on large blobs must not
            # serialize behind each other's digest computation
            if digest(data) == want:
                self._m_hits.inc()
                return data
            self._m_validation_failures.inc()
            with self._lock:
                # bit-rot in the cache: drop (if still ours) and re-fetch
                cur = self._blobs.get((key, name))
                if cur is not None and cur[0] is data:
                    self._drop_entry(key, name)
        self._m_misses.inc()
        with self._lock:
            gen = self._fetch_begin(key)
        data = None
        try:
            data = self.inner.read_blob(key, name)
        finally:
            self._fetch_end(key, name, data, gen)
        return data

    def delete(self, key: str) -> None:
        with self._lock:
            self._purge(key)  # fence in-flight fetches BEFORE the delete…
        self.inner.delete(key)
        with self._lock:
            self._purge(key)  # …and drop anything that slipped in since

    def exists(self, key: str) -> bool:
        return self.inner.exists(key)

    def exists_many(self, keys):
        # presence is never cached (see module docstring) — pass the batch
        # through so a deep probe walk stays one round trip
        return self.inner.exists_many(keys)

    def write_meta(self, name: str, text: str) -> None:
        self.inner.write_meta(name, text)

    def read_meta(self, name: str) -> str | None:
        return self.inner.read_meta(name)

    def nbytes(self, key: str) -> int:
        return self.inner.nbytes(key)
