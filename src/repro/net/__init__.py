"""``repro.net`` — the intermediate-data store as a cross-process service.

Everything below the ``StorageBackend`` seam can live in another process:

  * :class:`StoreServer`     — daemon owning the shared artifact pool, plus
    the lease table (fleet-wide single-flight) and the eviction-event stream;
  * :class:`RemoteBackend`   — drop-in ``StorageBackend`` speaking the framed
    TCP protocol with reconnect/retry and content-digest verification;
  * :class:`CachingBackend`  — bounded, digest-validated read-through LRU so
    hot prefixes are served at local speed;
  * :class:`DistributedSingleFlight` — two-level (threads, then processes)
    compute deduplication for uncomputed prefixes.

``python -m repro.net.serve --root DIR`` starts a server; see
``docs/remote.md`` for the protocol and deployment sketch.
"""
from .cache import CachingBackend
from .client import LeaseGrant, RemoteBackend
from .flight import DistributedSingleFlight
from .protocol import (
    ConnectionClosed,
    IntegrityError,
    ProtocolError,
    RemoteStoreError,
)
from .server import StoreServer

__all__ = [
    "CachingBackend",
    "ConnectionClosed",
    "DistributedSingleFlight",
    "IntegrityError",
    "LeaseGrant",
    "ProtocolError",
    "RemoteBackend",
    "RemoteStoreError",
    "StoreServer",
]
