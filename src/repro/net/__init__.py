"""``repro.net`` — the intermediate-data store as a cross-process service.

Everything below the ``StorageBackend`` seam can live in another process:

  * :class:`StoreServer`     — daemon owning the shared artifact pool, plus
    the lease table (fleet-wide single-flight) and the eviction-event stream;
  * :class:`RemoteBackend`   — drop-in ``StorageBackend`` speaking the framed
    TCP protocol with reconnect/retry and content-digest verification;
  * :class:`CachingBackend`  — bounded, digest-validated read-through LRU so
    hot prefixes are served at local speed;
  * :class:`DistributedSingleFlight` — two-level (threads, then processes)
    compute deduplication for uncomputed prefixes;
  * :class:`ShardedBackend` + :class:`HashRing` — **cluster mode**: N servers
    behind one consistent-hash ring with replication factor R, failover
    reads, read-repair, and ring-aware lease election
    (``Client(store_url="h:7077,h:7078,h:7079", replication=2)``).

``python -m repro.net.serve --root DIR`` starts one server (one shard); see
``docs/remote.md`` for the protocol, cluster semantics, and deployment
sketch.
"""
from .cache import CachingBackend
from .client import LeaseGrant, RemoteBackend
from .flight import DistributedSingleFlight
from .protocol import (
    DEFAULT_CHUNK_BYTES,
    PROTO_VERSION,
    ConnectionClosed,
    IntegrityError,
    ProtocolError,
    RemoteStoreError,
    StoreUnreachable,
)
from .ring import HashRing
from .server import StoreServer
from .sharded import ShardedBackend

__all__ = [
    "CachingBackend",
    "ConnectionClosed",
    "DEFAULT_CHUNK_BYTES",
    "PROTO_VERSION",
    "DistributedSingleFlight",
    "HashRing",
    "IntegrityError",
    "LeaseGrant",
    "ProtocolError",
    "RemoteBackend",
    "RemoteStoreError",
    "ShardedBackend",
    "StoreServer",
    "StoreUnreachable",
]
