"""Capacity-bounded eviction with the gain-loss-ratio criterion.

The thesis' Eq. 4.9 admission test (store iff T1 > T2) decides what *enters*
the store; Chakroborti's follow-up ("Gain-loss ratio of storing intermediate
data from workflows", arXiv 2202.06473) supplies the criterion for what
*leaves* it once a storage budget binds:

    gain(a)  = expected execution time saved by keeping artifact ``a``
             = (recompute seconds − load seconds) × expected future hits
    loss(a)  = bytes of budget the artifact occupies
    ratio(a) = gain(a) / loss(a)      — seconds saved per byte stored

Artifacts with the lowest ratio are evicted first: a huge artifact that is
cheap to recompute frees many bytes at little cost, while a small artifact
downstream of an expensive fit stays pinned almost indefinitely.  Expected
future hits are estimated from observed hits (``n_loads``), the same
frequency signal the thesis' association rules exploit.

``LRUEviction`` is kept as the classical baseline; ``bench_eviction.py``
sweeps both against the same budget.

Records are duck-typed: anything exposing ``nbytes_disk``, ``nbytes_raw``,
``save_s``, ``load_s``, ``n_loads``, ``compute_s`` and ``last_used_at`` works
— ``ArtifactRecord`` in the store and KV-snapshot records in ``ServeEngine``
share this shape, so serving memory is bounded by the same policy.
"""
from __future__ import annotations

import threading
from abc import ABC, abstractmethod
from dataclasses import dataclass
from typing import Any, Iterable, Mapping


@dataclass
class EvictionContext:
    """Store-level signals a policy may need (measured load bandwidth)."""

    load_bps: float = 1e9  # bytes/second; store passes its measured value


class EvictionPolicy(ABC):
    """Ranks records; lower score = evicted earlier.

    ``value_aware`` policies also gate *admission*: a newcomer whose score is
    below the artifacts it would displace is evicted itself instead (LRU, by
    definition, always favors the newcomer).
    """

    name = "abstract"
    value_aware = False

    @abstractmethod
    def score(self, rec: Any, ctx: EvictionContext) -> float: ...


class LRUEviction(EvictionPolicy):
    """Classical recency baseline: evict the least-recently-used artifact."""

    name = "lru"

    def score(self, rec: Any, ctx: EvictionContext) -> float:
        return rec.last_used_at


class GainLossEviction(EvictionPolicy):
    """Evict the artifact with the least execution-time gain per byte."""

    name = "gain_loss"
    value_aware = True

    def score(self, rec: Any, ctx: EvictionContext) -> float:
        return gain_loss_ratio(rec, ctx)


def gain_loss_ratio(rec: Any, ctx: EvictionContext | None = None) -> float:
    """Seconds of future execution time saved per byte of budget occupied."""
    ctx = ctx or EvictionContext()
    load_s = rec.load_s if rec.load_s else rec.nbytes_raw / max(ctx.load_bps, 1.0)
    # recompute time: measured module-chain seconds if the producer reported
    # them, else the save wall time (a write-bandwidth-shaped lower bound)
    recompute_s = rec.compute_s if rec.compute_s is not None else rec.save_s
    gain_per_hit = max(recompute_s - load_s, 0.0)
    # sub-linear frequency weighting: observed hits raise the expected-hit
    # estimate without making incumbents unseat-able by never-yet-hit
    # newcomers (the policy layer's rule mining owns the popularity signal)
    expected_hits = (1.0 + rec.n_loads) ** 0.5
    return gain_per_hit * expected_hits / max(rec.nbytes_disk, 1)


POLICIES: dict[str, type[EvictionPolicy]] = {
    "gain_loss": GainLossEviction,
    "lru": LRUEviction,
}


class EvictionManager:
    """Keeps a record set within ``capacity_bytes`` by ranked eviction."""

    def __init__(
        self,
        capacity_bytes: int | None = None,
        policy: str | EvictionPolicy = "gain_loss",
    ) -> None:
        self.capacity_bytes = capacity_bytes
        self.policy = POLICIES[policy]() if isinstance(policy, str) else policy
        self.n_evictions = 0
        self.bytes_evicted = 0
        # counters are read/written from concurrent scheduler workers; the
        # store additionally holds its own lock around select_victims calls
        self._lock = threading.Lock()

    def admits(self, nbytes: int) -> bool:
        """A single artifact larger than the whole budget is never admitted."""
        return self.capacity_bytes is None or nbytes <= self.capacity_bytes

    def select_victims(
        self,
        records: Mapping[str, Any],
        total_bytes: int,
        protect: Iterable[str] = (),
        ctx: EvictionContext | None = None,
        incoming: str | None = None,
    ) -> list[str]:
        """Keys to evict (worst score first) to bring ``total_bytes`` under budget.

        ``protect`` shields keys unconditionally.  ``incoming`` names the
        just-inserted record: under a ``value_aware`` policy it only displaces
        strictly lower-scored artifacts — if those don't free enough bytes,
        the newcomer itself is the (sole) victim.  Pure selection — the
        caller performs the deletions.
        """
        if self.capacity_bytes is None or total_bytes <= self.capacity_bytes:
            return []
        ctx = ctx or EvictionContext()
        protected = set(protect)
        incoming_score = None
        if incoming is not None and incoming in records and self.policy.value_aware:
            incoming_score = self.policy.score(records[incoming], ctx)
        ranked = sorted(
            (k for k in records if k not in protected and k != incoming),
            key=lambda k: (self.policy.score(records[k], ctx), records[k].last_used_at),
        )
        victims: list[str] = []
        excess = total_bytes - self.capacity_bytes
        for k in ranked:
            if excess <= 0:
                break
            if (
                incoming_score is not None
                and self.policy.score(records[k], ctx) > incoming_score
            ):
                break  # everything left is worth more per byte than the newcomer
            victims.append(k)
            excess -= records[k].nbytes_disk
        if excess > 0 and incoming is not None and incoming_score is not None:
            victims = [incoming]  # newcomer can't pay for the bytes it needs
        with self._lock:
            self.n_evictions += len(victims)
            self.bytes_evicted += sum(records[k].nbytes_disk for k in victims)
        return victims
