"""Storage-recommendation policies: RISP (PT) and the thesis' three baselines.

Replay protocol (thesis Ch. 4.5.1): pipelines are examined serially; for the
n-th pipeline each policy first answers "which already-stored intermediate
state can this pipeline reuse?" (vs. stores decided on pipelines 1..n-1), then
decides what to store from the n-th pipeline.

Policies:
  PT / RISP   — store the output indicated by the *longest highest-confidence*
                association rule of the pipeline under progress (Ch. 4.3.3).
  TSAR        — store every intermediate state result.
  TSPAR       — store the state indicated by the longest rule with support >= 1
                in the previous history.
  TSFR        — store only the final result.

``with_state=True`` selects the adaptive variant (Ch. 5): keys include each
module's tool-state digest so differently-parameterized runs never match.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

from .rules import RuleMiner
from .workflow import PrefixKey, Workflow


@dataclass
class StoredRecord:
    prefix: PrefixKey
    stored_at: int  # pipeline index that triggered the store
    reuse_count: int = 0


@dataclass
class Recommendation:
    """Result of observing one pipeline."""

    reuse: PrefixKey | None  # longest previously-stored prefix of this pipeline
    store: list[PrefixKey] = field(default_factory=list)  # newly admitted keys


class StoragePolicy:
    """Base class; subclasses override ``_select_stores``."""

    name = "base"

    def __init__(self, with_state: bool = False) -> None:
        self.with_state = with_state
        self.miner = RuleMiner(with_state=with_state)
        self.stored: dict[str, StoredRecord] = {}
        self.n_pipelines = 0
        self.n_reusable_pipelines = 0
        self.total_reuse_events = 0
        self.total_intermediate_states = 0
        # serializes the replay protocol (miner + counters + stored map) when
        # many scheduler runs step the same policy concurrently.  Lock order:
        # never call store methods while holding this lock (the store's evict
        # listeners mutate ``stored`` with plain GIL-atomic dict ops instead).
        self.lock = threading.RLock()

    # -- main entry point --------------------------------------------------
    def step(self, wf: Workflow) -> Recommendation:
        with self.lock:
            return self._step_locked(wf)

    def _step_locked(self, wf: Workflow) -> Recommendation:
        self.n_pipelines += 1
        self.total_intermediate_states += wf.n_intermediate_states

        reuse = self.lookup_reuse(wf)
        if reuse is not None:
            rec = self.stored[reuse.key(self.with_state)]
            rec.reuse_count += 1
            self.n_reusable_pipelines += 1
            self.total_reuse_events += 1

        stores = self._select_stores(wf)
        admitted = []
        for prefix in stores:
            key = prefix.key(self.with_state)
            if key not in self.stored:
                self.stored[key] = StoredRecord(prefix, self.n_pipelines)
                admitted.append(prefix)
        return Recommendation(reuse=reuse, store=admitted)

    def step_paths(self, workflows: "list[Workflow]") -> Recommendation:
        """Step every root-to-sink path of one DAG atomically (Ch. 3.3
        decomposition: a DAG contributes one mined pipeline per path) and
        merge the recommendations: deepest reuse wins, stores are unioned."""
        with self.lock:
            reuse: PrefixKey | None = None
            store: list[PrefixKey] = []
            seen: set[str] = set()
            for wf in workflows:
                rec = self._step_locked(wf)
                if rec.reuse is not None and (
                    reuse is None or rec.reuse.depth > reuse.depth
                ):
                    reuse = rec.reuse
                for prefix in rec.store:
                    key = prefix.key(self.with_state)
                    if key not in seen:
                        seen.add(key)
                        store.append(prefix)
            return Recommendation(reuse=reuse, store=store)

    def lookup_reuse(self, wf: Workflow) -> PrefixKey | None:
        """Longest stored prefix of ``wf`` (the deepest skip point)."""
        for k in range(len(wf), 0, -1):
            prefix = wf.prefix(k)
            if prefix.key(self.with_state) in self.stored:
                return prefix
        return None

    # -- policy-specific admission ------------------------------------------
    def _select_stores(self, wf: Workflow) -> list[PrefixKey]:
        raise NotImplementedError

    # -- reporting -----------------------------------------------------------
    @property
    def n_stored(self) -> int:
        return len(self.stored)

    @property
    def n_stored_reused(self) -> int:
        return sum(1 for r in self.stored.values() if r.reuse_count > 0)


class RISP(StoragePolicy):
    """PT: store the output of the longest among the highest-confidence
    association rules of the pipeline under progress (thesis Ch. 4.3.3).

    Only rules that were *obtained from the pipelines in the history* are
    candidates (support >= 2 counting the current pipeline, i.e. the prefix
    appeared in at least one earlier pipeline).  Without this gate a pipeline
    whose prefixes are all novel would tie at minimal confidence and store its
    final result, which contradicts the thesis' stored counts (PT stores 49
    results vs. TSPAR's 159 on the 508-workflow corpus — PT must be the more
    selective policy).  The Fig. 4.1 worked example is unaffected: the
    highest-confidence rules D1=>M1 and D1=>[M1,M2] have support 3, and the
    longest, [M1,M2], is recommended.
    """

    name = "PT"

    def _select_stores(self, wf: Workflow) -> list[PrefixKey]:
        self.miner.add(wf)
        rules = [r for r in self.miner.rules_for(wf) if r.support >= 2]
        if not rules:
            return []
        best = max(r.confidence for r in rules)
        candidates = [r for r in rules if r.confidence == best]
        chosen = max(candidates, key=lambda r: r.depth)
        return [chosen.prefix]


class TSAR(StoragePolicy):
    """Store All Results."""

    name = "TSAR"

    def _select_stores(self, wf: Workflow) -> list[PrefixKey]:
        self.miner.add(wf)
        return list(wf.prefixes())


class TSPAR(StoragePolicy):
    """Store Previously-Appeared Results: longest prefix with support >= 1 in
    the first n-1 pipelines."""

    name = "TSPAR"

    def _select_stores(self, wf: Workflow) -> list[PrefixKey]:
        seen = [p for p in wf.prefixes() if self.miner.support(p) >= 1]
        self.miner.add(wf)
        if not seen:
            return []
        return [max(seen, key=len)]


class TSFR(StoragePolicy):
    """Store the Final Result only."""

    name = "TSFR"

    def _select_stores(self, wf: Workflow) -> list[PrefixKey]:
        self.miner.add(wf)
        return [wf.prefix(len(wf))]


POLICIES: dict[str, type[StoragePolicy]] = {
    "PT": RISP,
    "TSAR": TSAR,
    "TSPAR": TSPAR,
    "TSFR": TSFR,
}


def make_policy(name: str, with_state: bool = False) -> StoragePolicy:
    return POLICIES[name](with_state=with_state)
