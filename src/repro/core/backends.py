"""Pluggable storage backends for the intermediate-data store.

``IntermediateStore`` owns *what* an artifact is (pytree flattening, per-shard
blobs, the JSON manifest, compression via a ``Codec``); a ``StorageBackend``
owns only *where bytes live*.  An artifact is a namespace ``key`` holding
named blobs (``manifest.json``, ``skeleton.pkl``, ``leaf0.bin.zst``, ...);
store-level metadata (``index.json``) lives beside the namespaces.

Backends:

  * ``LocalFSBackend`` — the seed behavior: content-addressed directories
    ``objects/<h[:2]>/<h>/`` under a root path (the thesis' HDFS-write
    analogue, Ch. 3.4).
  * ``MemoryBackend``  — dict-of-dicts; for tests and as the hot tier.
  * ``TieredBackend``  — a bounded hot tier over a durable cold tier with
    LRU promote/demote; reads served hot when possible, writes go cold
    (authoritative) and are cached hot.
"""
from __future__ import annotations

import hashlib
import io
import os
import shutil
import tempfile
import threading
from abc import ABC, abstractmethod
from collections import OrderedDict
from pathlib import Path
from typing import BinaryIO, Iterable


def _key_hash(key: str) -> str:
    return hashlib.sha256(key.encode()).hexdigest()[:24]


class BackendUnavailable(RuntimeError):
    """The backend (or every replica of a distributed one) cannot be reached.

    Distinct from ``KeyError``/``FileNotFoundError``: the artifact may well
    still exist — the bytes are just unreachable right now.  Callers above
    the backend seam (store ``has``, scheduler load paths) treat this as
    "not reusable at the moment" and fall back to recomputing rather than
    failing the run or pruning records for artifacts that are still alive.
    """


class BlobWriter(ABC):
    """Incremental sink for one blob's bytes (the streaming write seam).

    The contract that matters for torn streams: nothing a reader can observe
    changes until :meth:`commit` — a writer abandoned mid-stream (or
    explicitly :meth:`abort`-ed) leaves no partial blob behind and reclaims
    any spill space it used.  ``commit``/``abort`` are idempotent.
    """

    @abstractmethod
    def write(self, data: bytes | bytearray | memoryview) -> None:
        """Append a chunk."""

    @abstractmethod
    def commit(self) -> int:
        """Atomically publish the accumulated bytes; return bytes stored."""

    @abstractmethod
    def abort(self) -> None:
        """Discard everything written so far (reclaim spill space)."""


class _SpillBlobWriter(BlobWriter):
    """Default streaming writer for backends without a native one: chunks
    append to an anonymous spill file on disk (constant memory while the
    stream is in flight), and ``commit`` replays them through the backend's
    one-shot ``write_blob`` — partial streams never reach the backend."""

    def __init__(self, backend: "StorageBackend", key: str, name: str) -> None:
        self._backend = backend
        self._key = key
        self._name = name
        self._spill: BinaryIO | None = tempfile.TemporaryFile(prefix="repro-spill-")
        self._nbytes = 0

    def write(self, data: bytes | bytearray | memoryview) -> None:
        if self._spill is None:
            raise RuntimeError("writer already committed/aborted")
        self._spill.write(data)
        self._nbytes += len(data)

    def commit(self) -> int:
        if self._spill is None:
            return self._nbytes
        spill, self._spill = self._spill, None
        try:
            spill.seek(0)
            return self._backend.write_blob(self._key, self._name, spill.read())
        finally:
            spill.close()  # anonymous tempfile: close() reclaims the space

    def abort(self) -> None:
        if self._spill is not None:
            spill, self._spill = self._spill, None
            spill.close()


class BlobReader:
    """Sized, file-like source for one blob (the streaming read seam).

    ``raw`` is any object with ``readinto``; when it is a real file the
    consumer may use ``fileno()`` for zero-copy sends (``os.sendfile``).
    """

    def __init__(self, raw: BinaryIO, size: int) -> None:
        self.raw = raw
        self.size = size

    def readinto(self, view: memoryview) -> int:
        return self.raw.readinto(view)

    def fileno(self) -> int:
        return self.raw.fileno()  # raises for memory-backed readers

    def close(self) -> None:
        try:
            self.raw.close()
        except OSError:
            pass

    def __enter__(self) -> "BlobReader":
        return self

    def __exit__(self, *exc: object) -> None:
        self.close()


class StorageBackend(ABC):
    """Byte-level persistence for artifact namespaces."""

    name = "abstract"

    @abstractmethod
    def write_blob(self, key: str, name: str, data: bytes) -> int:
        """Persist ``data`` as blob ``name`` of artifact ``key``; return bytes stored."""

    @abstractmethod
    def read_blob(self, key: str, name: str) -> bytes:
        """Read blob ``name`` of artifact ``key`` (KeyError/FileNotFoundError if absent)."""

    @abstractmethod
    def delete(self, key: str) -> None:
        """Drop every blob of artifact ``key`` (no-op if absent)."""

    @abstractmethod
    def exists(self, key: str) -> bool:
        """True iff artifact ``key`` has a committed manifest."""

    @abstractmethod
    def write_meta(self, name: str, text: str) -> None:
        """Persist store-level metadata (e.g. ``index.json``)."""

    @abstractmethod
    def read_meta(self, name: str) -> str | None:
        """Read store-level metadata, or None if absent."""

    def nbytes(self, key: str) -> int:
        """Total stored bytes of artifact ``key`` (0 if absent)."""
        raise NotImplementedError(f"{self.name} backend does not track sizes")

    # -- streaming / batched extensions (defaults compose from the core ops) --
    def open_blob_writer(self, key: str, name: str) -> BlobWriter:
        """Incremental writer for blob ``name`` of ``key``.  The default
        spills chunks to an anonymous temp file and publishes through
        ``write_blob`` at commit; backends with a native atomic path
        (``LocalFSBackend``) override for true constant-memory commits.
        Until ``commit``, no reader observes any of the written bytes."""
        return _SpillBlobWriter(self, key, name)

    def open_blob_reader(self, key: str, name: str) -> BlobReader:
        """Sized incremental reader for blob ``name`` of ``key`` (raises
        ``KeyError``/``FileNotFoundError`` like ``read_blob`` when absent).
        The default materializes ``read_blob`` once; file-backed backends
        override to stream straight off disk."""
        data = self.read_blob(key, name)
        return BlobReader(io.BytesIO(data), len(data))

    def exists_many(self, keys: Iterable[str]) -> dict[str, "bool | None"]:
        """Presence of many artifacts at once.  ``None`` marks a key whose
        presence is *undecidable right now* (``BackendUnavailable`` — e.g.
        every replica of it unreachable in a distributed backend); plain
        backends never return it.  Remote backends override this with a
        single batched round trip — the deep-chain probe walk depends on
        that being O(1) round trips, not O(depth)."""
        out: dict[str, bool | None] = {}
        for key in keys:
            try:
                out[key] = self.exists(key)
            except BackendUnavailable:
                out[key] = None
        return out


class _FSBlobWriter(BlobWriter):
    """LocalFS streaming writer: append to a dot-tmp spill file in the object
    directory, commit via atomic rename — the same write-then-rename
    discipline as ``write_blob``, with constant memory for any blob size."""

    def __init__(self, directory: Path, name: str) -> None:
        directory.mkdir(parents=True, exist_ok=True)
        self._final = directory / name
        self._tmp = directory / f".{name}.tmp.{os.getpid()}.{threading.get_ident()}"
        self._fh: BinaryIO | None = open(self._tmp, "wb")
        self._nbytes = 0

    def write(self, data: bytes | bytearray | memoryview) -> None:
        if self._fh is None:
            raise RuntimeError("writer already committed/aborted")
        self._fh.write(data)
        self._nbytes += len(data)

    def commit(self) -> int:
        if self._fh is None:
            return self._nbytes
        fh, self._fh = self._fh, None
        fh.close()
        try:
            os.replace(self._tmp, self._final)
        except OSError:
            try:
                os.unlink(self._tmp)
            except OSError:
                pass
            raise
        return self._nbytes

    def abort(self) -> None:
        if self._fh is not None:
            fh, self._fh = self._fh, None
            fh.close()
            try:
                os.unlink(self._tmp)
            except OSError:
                pass


class LocalFSBackend(StorageBackend):
    """Filesystem backend with the seed's content-addressed layout."""

    name = "localfs"

    def __init__(self, root: str | Path) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _obj_dir(self, key: str) -> Path:
        h = _key_hash(key)
        return self.root / "objects" / h[:2] / h

    def write_blob(self, key: str, name: str, data: bytes) -> int:
        d = self._obj_dir(key)
        d.mkdir(parents=True, exist_ok=True)
        # write-then-rename (same discipline as write_meta): a reader racing
        # an overwrite sees the old or the new blob, never a torn one
        tmp = d / f".{name}.tmp.{os.getpid()}.{threading.get_ident()}"
        tmp.write_bytes(data)
        os.replace(tmp, d / name)
        return len(data)

    def read_blob(self, key: str, name: str) -> bytes:
        return (self._obj_dir(key) / name).read_bytes()

    def delete(self, key: str) -> None:
        d = self._obj_dir(key)
        if d.exists():
            shutil.rmtree(d)

    def exists(self, key: str) -> bool:
        return (self._obj_dir(key) / "manifest.json").exists()

    def write_meta(self, name: str, text: str) -> None:
        # write-then-rename: concurrent readers (and crashed writers) never
        # observe a torn index.json
        tmp = self.root / f"{name}.tmp.{os.getpid()}.{threading.get_ident()}"
        tmp.write_text(text)
        os.replace(tmp, self.root / name)

    def read_meta(self, name: str) -> str | None:
        p = self.root / name
        return p.read_text() if p.exists() else None

    def nbytes(self, key: str) -> int:
        d = self._obj_dir(key)
        if not d.exists():
            return 0
        return sum(
            f.stat().st_size
            for f in d.iterdir()
            if f.is_file() and not f.name.startswith(".")  # skip tmp leftovers
        )

    def open_blob_writer(self, key: str, name: str) -> BlobWriter:
        return _FSBlobWriter(self._obj_dir(key), name)

    def open_blob_reader(self, key: str, name: str) -> BlobReader:
        path = self._obj_dir(key) / name
        try:
            fh = open(path, "rb")
        except FileNotFoundError:
            raise KeyError(f"{key}/{name}") from None
        return BlobReader(fh, os.fstat(fh.fileno()).st_size)


class MemoryBackend(StorageBackend):
    """In-process backend: tests, ephemeral stores, and hot-tier caching."""

    name = "memory"

    def __init__(self) -> None:
        self._objects: dict[str, dict[str, bytes]] = {}
        self._meta: dict[str, str] = {}

    def write_blob(self, key: str, name: str, data: bytes) -> int:
        # callers may hand a memoryview over a live buffer (the KV codec's
        # zero-copy path); snapshot it so the stored blob can't alias it
        self._objects.setdefault(key, {})[name] = (
            data if isinstance(data, bytes) else bytes(data)
        )
        return len(data)

    def read_blob(self, key: str, name: str) -> bytes:
        return self._objects[key][name]

    def delete(self, key: str) -> None:
        self._objects.pop(key, None)

    def exists(self, key: str) -> bool:
        return "manifest.json" in self._objects.get(key, ())

    def write_meta(self, name: str, text: str) -> None:
        self._meta[name] = text

    def read_meta(self, name: str) -> str | None:
        return self._meta.get(name)

    def nbytes(self, key: str) -> int:
        return sum(len(b) for b in self._objects.get(key, {}).values())

    def open_blob_writer(self, key: str, name: str) -> BlobWriter:
        # the destination is memory anyway: accumulate directly, publish on
        # commit (the dict assignment is the atomic step)
        backend = self

        class _MemWriter(BlobWriter):
            def __init__(self) -> None:
                self._parts: list[bytes] | None = []

            def write(self, data: bytes | bytearray | memoryview) -> None:
                if self._parts is None:
                    raise RuntimeError("writer already committed/aborted")
                self._parts.append(bytes(data))

            def commit(self) -> int:
                if self._parts is None:
                    return 0
                parts, self._parts = self._parts, None
                return backend.write_blob(key, name, b"".join(parts))

            def abort(self) -> None:
                self._parts = None

        return _MemWriter()


class TieredBackend(StorageBackend):
    """Hot/cold tiering: bounded memory tier over a durable backend.

    Writes land on ``cold`` (authoritative) and are mirrored hot; reads hit
    the hot tier first and promote on miss.  When the hot tier exceeds
    ``hot_capacity_bytes``, least-recently-used *artifacts* (whole
    namespaces, so a manifest never outlives its blobs) are demoted —
    dropped from memory only; cold copies are untouched.

    Thread-safety: one lock guards the hot-tier bookkeeping (LRU order,
    byte accounting, the memory tier itself) so a concurrent ``_shrink_hot``
    can never race a promote into inconsistent accounting or crash an LRU
    iteration mid-scan; a read that loses its hot entry mid-flight falls
    back to the (authoritative) cold tier.  Cold-tier I/O — potentially a
    slow disk or a network hop — always happens *outside* the lock.
    """

    name = "tiered"

    def __init__(
        self,
        cold: StorageBackend,
        hot: MemoryBackend | None = None,
        hot_capacity_bytes: int = 256 * 1024 * 1024,
    ) -> None:
        self.cold = cold
        self.hot = hot or MemoryBackend()
        self.hot_capacity_bytes = hot_capacity_bytes
        self._lock = threading.RLock()
        self._lru: OrderedDict[str, None] = OrderedDict()  # key -> (LRU order)
        self._hot_nbytes = 0  # running total; avoids O(keys) rescans
        self.promotions = 0
        self.demotions = 0

    # -- hot-tier bookkeeping (callers hold self._lock) -----------------------
    def _touch(self, key: str) -> None:
        self._lru.pop(key, None)
        self._lru[key] = None

    def _hot_bytes(self) -> int:
        with self._lock:
            return self._hot_nbytes

    def _hot_write(self, key: str, name: str, data: bytes) -> None:
        prev = self.hot._objects.get(key, {}).get(name)
        self._hot_nbytes += len(data) - (len(prev) if prev is not None else 0)
        self.hot.write_blob(key, name, data)
        self._touch(key)

    def _hot_drop(self, key: str) -> None:
        self._hot_nbytes -= self.hot.nbytes(key)
        self.hot.delete(key)
        self._lru.pop(key, None)

    def _shrink_hot(self) -> None:
        while self._lru and self._hot_nbytes > self.hot_capacity_bytes:
            victim = next(iter(self._lru))
            self._hot_drop(victim)
            self.demotions += 1

    # -- StorageBackend ------------------------------------------------------
    def write_blob(self, key: str, name: str, data: bytes) -> int:
        n = self.cold.write_blob(key, name, data)
        if len(data) <= self.hot_capacity_bytes:
            with self._lock:
                self._hot_write(key, name, data)
                self._shrink_hot()
        return n

    def read_blob(self, key: str, name: str) -> bytes:
        with self._lock:
            try:
                data = self.hot.read_blob(key, name)
            except KeyError:
                # demoted by a concurrent _shrink_hot/delete mid-read: the
                # cold tier is authoritative, fall through to it
                pass
            else:
                self._touch(key)
                return data
        data = self.cold.read_blob(key, name)
        if len(data) <= self.hot_capacity_bytes:
            with self._lock:
                self._hot_write(key, name, data)
                self.promotions += 1
                self._shrink_hot()
        return data

    def delete(self, key: str) -> None:
        with self._lock:
            self._hot_drop(key)
        self.cold.delete(key)
        # a read that fetched cold bytes before the delete may promote them
        # concurrently; drop again so the hot tier doesn't keep orphan bytes
        with self._lock:
            self._hot_drop(key)

    def exists(self, key: str) -> bool:
        # cold is authoritative: every write lands there, and hot may briefly
        # hold resurrected blobs from a promote racing a delete — those must
        # not make an evicted artifact look alive
        return self.cold.exists(key)

    def exists_many(self, keys: Iterable[str]) -> dict[str, bool | None]:
        return self.cold.exists_many(keys)

    def open_blob_writer(self, key: str, name: str) -> BlobWriter:
        # streamed blobs skip the hot mirror on purpose: anything big enough
        # to stream would evict the whole hot set for one entry (the next
        # read promotes it if it actually fits)
        return self.cold.open_blob_writer(key, name)

    def open_blob_reader(self, key: str, name: str) -> BlobReader:
        # serve streams straight from cold: correct (authoritative tier) and
        # constant-memory; small blobs keep using read_blob and the hot path
        return self.cold.open_blob_reader(key, name)

    def write_meta(self, name: str, text: str) -> None:
        self.cold.write_meta(name, text)

    def read_meta(self, name: str) -> str | None:
        return self.cold.read_meta(name)

    def nbytes(self, key: str) -> int:
        return self.cold.nbytes(key)
