"""Workflow data model: Modules, ToolStates, Workflows, and prefix keys.

Mirrors the thesis' formalization (Ch. 6.3.1):

    W = (D, M, E, ID, O)  — input dataset D, modules M, edges E, intermediate
    data ID, output O.  A module is m => <id, I, O, C, S, T, Id> where C is the
    parameter-configuration set and T the tool state.

For rule mining the thesis treats pipelines as *sequential* module chains
(Ch. 3.3: "For simplicity we are considering only sequential module processing
in workflows"); general DAGs are decomposed into root-to-node paths.
"""
from __future__ import annotations

import hashlib
import json
import re
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Mapping, Sequence

_ADDR_RE = re.compile(r" at 0x[0-9a-fA-F]+")


def _canonical(obj: Any, invertible: bool = False) -> Any:
    """Reduce ``obj`` to a JSON-safe form whose rendering is identical across
    processes.  ``repr`` fallbacks that embed memory addresses would make the
    digest unique per run — silently defeating cross-process reuse — so
    address-bearing reprs are rejected rather than hashed.

    ``invertible=True`` selects the tool-state-parameter variant: every
    encoding must be reversible by :func:`_decanonical`, so tuples are tagged
    (vs. lists), bytes/arrays carry their raw content instead of a digest, and
    ``repr`` fallbacks are only accepted when ``ast.literal_eval`` can undo
    them.  Values that cannot round-trip raise ``TypeError`` loudly instead of
    silently degrading to strings at execution time.
    """
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        return obj
    if isinstance(obj, bytes):
        if invertible:
            return {"__hexbytes__": obj.hex()}
        return {"__bytes__": hashlib.sha256(obj).hexdigest()}
    if isinstance(obj, Mapping):
        # encoded as a tagged sorted pair-list, not a plain JSON object, so a
        # user dict like {"__set__": [...]} can never forge the sentinel
        # encodings below (which would collide with the real set/array/bytes)
        if not invertible or all(isinstance(k, str) for k in obj):
            return {
                "__map__": [
                    [str(k), _canonical(v, invertible)]
                    for k, v in sorted(obj.items(), key=lambda kv: str(kv[0]))
                ]
            }
        # non-str keys: encode both sides and sort by rendering, so the
        # encoding is insertion-order independent like every other container
        return {
            "__dictitems__": sorted(
                (
                    [_canonical(k, True), _canonical(v, True)]
                    for k, v in obj.items()
                ),
                key=lambda kv: json.dumps(kv, sort_keys=True),
            )
        }
    elif isinstance(obj, tuple):
        if invertible:
            return {"__tuple__": [_canonical(x, invertible) for x in obj]}
        return [_canonical(x) for x in obj]
    elif isinstance(obj, list):
        return [_canonical(x, invertible) for x in obj]
    elif isinstance(obj, (set, frozenset)):
        tag = "__frozenset__" if invertible and isinstance(obj, frozenset) else "__set__"
        return {
            tag: sorted(
                json.dumps(_canonical(x, invertible), sort_keys=True) for x in obj
            )
        }
    # array-likes (numpy / jax / ml_dtypes): digest dtype + shape + raw bytes
    elif hasattr(obj, "dtype") and hasattr(obj, "shape") and hasattr(obj, "tobytes"):
        import numpy as np

        arr = np.ascontiguousarray(obj)
        if invertible:
            return {
                "__ndarray__": str(arr.dtype),
                "shape": list(arr.shape),
                "hex": arr.tobytes().hex(),
            }
        return {
            "__array__": str(arr.dtype),
            "shape": list(arr.shape),
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
        }
    r = repr(obj)
    if _ADDR_RE.search(r):
        raise TypeError(
            f"cannot stably hash {type(obj).__name__!r}: repr embeds a memory "
            "address; give it a value-based __repr__ or pass primitives/arrays"
        )
    if invertible:
        import ast

        try:
            ast.literal_eval(r)
        except (ValueError, SyntaxError) as e:
            raise TypeError(
                f"tool-state parameter of type {type(obj).__name__!r} is not "
                f"value-recoverable (repr {r!r} is not a Python literal); pass "
                "primitives, tuples/lists/dicts/sets of them, or arrays"
            ) from e
    return {"__repr__": r}


def _decanonical(obj: Any) -> Any:
    """Invert :func:`_canonical` (invertible mode) back to Python values."""
    if isinstance(obj, dict):
        if "__tuple__" in obj:
            return tuple(_decanonical(x) for x in obj["__tuple__"])
        if "__map__" in obj:
            return {k: _decanonical(v) for k, v in obj["__map__"]}
        if "__set__" in obj:
            return {_decanonical(json.loads(s)) for s in obj["__set__"]}
        if "__frozenset__" in obj:
            return frozenset(
                _decanonical(json.loads(s)) for s in obj["__frozenset__"]
            )
        if "__dictitems__" in obj:
            return {
                _decanonical(k): _decanonical(v) for k, v in obj["__dictitems__"]
            }
        if "__hexbytes__" in obj:
            return bytes.fromhex(obj["__hexbytes__"])
        if "__ndarray__" in obj:
            import numpy as np

            raw = bytes.fromhex(obj["hex"])
            arr = np.frombuffer(raw, dtype=np.dtype(obj["__ndarray__"]))
            return arr.reshape(obj["shape"]).copy()
        if "__repr__" in obj:
            import ast

            try:
                return ast.literal_eval(obj["__repr__"])
            except (ValueError, SyntaxError):
                return obj["__repr__"]
        raise TypeError(f"cannot decode digest-only encoding {sorted(obj)!r}")
    if isinstance(obj, list):
        return [_decanonical(x) for x in obj]
    return obj


def encode_param(value: Any) -> str:
    """Canonical, *invertible* rendering of one tool-state parameter value.

    The encoding is deterministic across processes (same guarantees as
    ``_stable_hash``'s canonical form) and :func:`decode_param` recovers the
    original value exactly — including tuples, floats, nested containers,
    bytes, and small arrays.  Non-recoverable values raise ``TypeError`` at
    construction time instead of degrading to strings at execution time.
    """
    return json.dumps(_canonical(value, invertible=True), sort_keys=True)


def decode_param(encoded: str) -> Any:
    """Inverse of :func:`encode_param`.

    Legacy ``repr()``-encoded params (pre-canonical ``ToolState``s, e.g. from
    persisted specs) fall back to ``ast.literal_eval`` — the deprecated
    :func:`repro.core.executor.eval_repr` behaviour — so old documents keep
    resolving.
    """
    try:
        payload = json.loads(encoded)
    except (ValueError, TypeError):
        # legacy repr() encoding ("'s'", "(1, 2)", "{'a': 1}", ...)
        import ast

        try:
            return ast.literal_eval(encoded)
        except (ValueError, SyntaxError):
            return encoded
    return _decanonical(payload)


def _stable_hash(obj: Any) -> str:
    """SHA-256 of a canonical-JSON rendering; used for tool states & datasets.

    Deterministic across processes: unhashable leaves are canonicalized (arrays
    by content digest) or rejected, never ``repr``-ed into ``<... at 0x...>``.
    """
    payload = json.dumps(_canonical(obj), sort_keys=True).encode()
    return hashlib.sha256(payload).hexdigest()[:16]


@dataclass(frozen=True)
class ToolState:
    """Parameter configuration of a module (thesis Ch. 5: 'tool state').

    Two invocations of the same module with different parameter sets are
    different tool states and must not share intermediate data.
    """

    params: tuple[tuple[str, str], ...] = ()

    @classmethod
    def from_config(cls, config: Mapping[str, Any] | None) -> "ToolState":
        """Canonicalize a parameter mapping.

        Values are rendered through :func:`encode_param` — deterministic
        across processes and exactly invertible by :meth:`to_config` (tuples
        stay tuples, floats keep full precision, nested containers survive).
        Values that cannot round-trip raise ``TypeError`` here rather than
        silently degrading to strings when a module is executed.
        """
        if not config:
            return cls()
        items = tuple(sorted((str(k), encode_param(v)) for k, v in config.items()))
        return cls(items)

    def to_config(self) -> dict[str, Any]:
        """Recover the parameter mapping (inverse of :meth:`from_config`).

        The decoded mapping is computed once and cached on the instance
        (immutable after construction, so the decode can never go stale):
        the registry resolves params on every node execution and both the
        recommender index and the catalog decode whole chains — without the
        cache each pays a full ``decode_param`` pass per visit.  Callers get
        a fresh shallow copy so mutating the returned dict cannot corrupt
        the cache.
        """
        cached = getattr(self, "_decoded", None)
        if cached is None:
            cached = {k: decode_param(v) for k, v in self.params}
            # frozen dataclass: bypass the immutability guard for the memo.
            # eq/hash are unaffected (they only consider declared fields).
            object.__setattr__(self, "_decoded", cached)
        return dict(cached)

    @property
    def digest(self) -> str:
        return _stable_hash(self.params) if self.params else "default"

    def __str__(self) -> str:  # pragma: no cover - debug aid
        return self.digest


@dataclass(frozen=True)
class ModuleRef:
    """A module occurrence inside a workflow: id + tool state."""

    module_id: str
    state: ToolState = field(default_factory=ToolState)

    def key(self, with_state: bool) -> str:
        return f"{self.module_id}@{self.state.digest}" if with_state else self.module_id


@dataclass(frozen=True)
class Workflow:
    """A sequential pipeline applied to one input dataset."""

    dataset_id: str
    modules: tuple[ModuleRef, ...]
    workflow_id: str = ""

    def __post_init__(self) -> None:
        if not self.modules:
            raise ValueError("a workflow needs at least one module")

    @classmethod
    def build(
        cls,
        dataset_id: str,
        steps: Sequence[str | tuple[str, Mapping[str, Any] | None]],
        workflow_id: str = "",
    ) -> "Workflow":
        refs = []
        for step in steps:
            if isinstance(step, str):
                refs.append(ModuleRef(step))
            else:
                mod, cfg = step
                refs.append(ModuleRef(mod, ToolState.from_config(cfg)))
        return cls(dataset_id, tuple(refs), workflow_id)

    def __len__(self) -> int:
        return len(self.modules)

    def prefixes(self) -> Iterator["PrefixKey"]:
        """All prefixes D=>[M1..Mk], k=1..n — one per storable intermediate state.

        The thesis derives one association rule per storable result including
        the final one (Ch. 4.3.1: 4 rules from a 4-module pipeline).
        """
        for k in range(1, len(self.modules) + 1):
            yield self.prefix(k)

    def prefix(self, k: int) -> "PrefixKey":
        if not 1 <= k <= len(self.modules):
            raise IndexError(f"prefix length {k} out of range 1..{len(self.modules)}")
        return PrefixKey(self.dataset_id, self.modules[:k])

    @property
    def n_intermediate_states(self) -> int:
        """Storable states incl. the final result (thesis counts both)."""
        return len(self.modules)


@dataclass(frozen=True)
class PrefixKey:
    """Canonical identity of an intermediate state: dataset + module prefix.

    ``key(with_state=True)`` is the *adaptive RISP* identity (Ch. 5) — it
    includes each module's tool-state digest; ``with_state=False`` is the plain
    Ch. 4 identity.
    """

    dataset_id: str
    modules: tuple[ModuleRef, ...]

    def key(self, with_state: bool = False) -> str:
        mods = ">".join(m.key(with_state) for m in self.modules)
        return f"{self.dataset_id}::{mods}"

    def __len__(self) -> int:
        return len(self.modules)

    @property
    def depth(self) -> int:
        return len(self.modules)

    def parent(self) -> "PrefixKey | None":
        if len(self.modules) == 1:
            return None
        return PrefixKey(self.dataset_id, self.modules[:-1])


@dataclass
class ModuleSpec:
    """An executable module registered with the SWfMS executor.

    ``fn`` maps (input pytree, **params) -> output pytree. ``cost_hint``
    optionally estimates seconds for scheduling/reporting.
    """

    module_id: str
    fn: Callable[..., Any]
    default_params: dict[str, Any] = field(default_factory=dict)
    cost_hint: float | None = None

    def ref(self, params: Mapping[str, Any] | None = None) -> ModuleRef:
        merged = dict(self.default_params)
        if params:
            merged.update(params)
        return ModuleRef(self.module_id, ToolState.from_config(merged))
