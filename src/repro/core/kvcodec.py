"""Deterministic KV-pytree codec: device cache -> host bytes -> any backend.

A serving engine's prefix KV snapshot is a pytree of device arrays (per-layer
key/value blocks, or MLA's compressed ``c_kv``/``k_rope``).  This module turns
that pytree into a first-class *artifact* — the same "namespace key holding
named blobs" shape :class:`~repro.core.store.IntermediateStore` uses — so a
snapshot can live on any :class:`~repro.core.backends.StorageBackend` and be
reused across serving processes.

Design constraints, in order:

* **Deterministic.**  Two processes snapshotting the same cache produce
  byte-identical blobs and manifests: dict keys are walked sorted, leaf bytes
  are the raw C-contiguous little-endian buffer, and the manifest is
  canonical JSON (sorted keys, no whitespace).  Determinism is what makes
  cross-process reuse content-addressable rather than trust-based.
* **Exact.**  The round trip is bit-exact — a loaded snapshot must produce
  logits identical to a fresh prefill (tested in ``tests/test_serve_fabric``).
  Per-leaf SHA-256 of the *raw* bytes rides in the manifest so corruption is
  detectable regardless of which compression codec wrapped the payload.
* **Stream once.**  Leaf payloads are handed to ``write_blob`` as a
  ``memoryview`` over the host array — the only materialization.  A
  ``RemoteBackend`` slices that view into wire-v2 chunk frames, so a
  multi-GB snapshot crosses the wire without a second in-memory copy.
* **Registry-pluggable compression.**  The per-leaf payload codec is any
  codec from :mod:`repro.core.codecs` (``resolve_codec``); the manifest
  records which one so readers need no out-of-band configuration.  The
  default is ``"none"``: KV activations are high-entropy floats and the
  zero-copy raw path is the point.

Blob layout of one snapshot artifact ``key``::

    manifest.json        canonical JSON: leaf table + length + provenance
    kv0.bin[.zst]        leaf 0 payload (raw or codec-compressed)
    kv1.bin[.zst]        ...

``manifest.json`` is written **last**, so a torn writer never publishes a
readable-but-partial snapshot (``StorageBackend.exists`` keys off the
manifest blob, same as workflow artifacts).
"""
from __future__ import annotations

import hashlib
import json
import time
from dataclasses import dataclass, field
from typing import Any, Iterator, Mapping

import numpy as np

from .backends import StorageBackend
from .codecs import resolve_codec

__all__ = ["KV_FORMAT", "KVSnapshotInfo", "load_kv", "read_kv_info", "save_kv"]

#: manifest blob name — deliberately the same name the workflow store uses,
#: because every backend's ``exists``/``exists_many`` treats a committed
#: ``manifest.json`` as *the* presence marker for an artifact key
MANIFEST = "manifest.json"
KV_FORMAT = 1


def _flatten(tree: Any, path: tuple = ()) -> Iterator[tuple[tuple, Any]]:
    """Deterministic (path, leaf) walk over dict/list/tuple pytrees.

    Dict keys are visited sorted and must be strings (they travel as JSON);
    anything that is not a container is a leaf.
    """
    if isinstance(tree, Mapping):
        for k in sorted(tree):
            if not isinstance(k, str):
                raise TypeError(f"KV pytree dict keys must be str, got {k!r}")
            yield from _flatten(tree[k], path + (["d", k],))
    elif isinstance(tree, (list, tuple)):
        tag = "l" if isinstance(tree, list) else "t"
        for i, v in enumerate(tree):
            yield from _flatten(v, path + ([tag, i],))
    else:
        yield path, tree


def _unflatten(items: list[tuple[list, Any]]) -> Any:
    """Rebuild the pytree from ``_flatten``'s (path, leaf) pairs.

    Every path step carries its container tag, so the original container
    kinds (dict vs list vs tuple) are restored exactly.
    """
    if not items:
        raise ValueError("empty KV snapshot")

    def build(group: list[tuple[list, Any]], depth: int) -> Any:
        first_path = group[0][0]
        if len(first_path) == depth:
            if len(group) != 1:
                raise ValueError("KV manifest paths collide")
            return group[0][1]
        tag = first_path[depth][0]
        children: dict[Any, list[tuple[list, Any]]] = {}
        for path, leaf in group:
            children.setdefault(path[depth][1], []).append((path, leaf))
        if tag == "d":
            return {k: build(v, depth + 1) for k, v in sorted(children.items())}
        seq = [build(children[i], depth + 1) for i in sorted(children)]
        return tuple(seq) if tag == "t" else seq

    return build(items, 0)


def _resolve_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        # jax low-precision dtypes (bfloat16, float8_*) register with numpy
        # through ml_dtypes scalar types, not through dtype-string lookup
        import ml_dtypes  # noqa: PLC0415 — optional, jax always ships it

        return np.dtype(getattr(ml_dtypes, name))


def _raw_view(host: np.ndarray) -> memoryview:
    """Flat little-endian byte view of a host array — zero copy."""
    if host.dtype.byteorder == ">":  # pragma: no cover - no BE producers here
        host = host.astype(host.dtype.newbyteorder("<"))
    flat = np.ascontiguousarray(host).reshape(-1)
    return memoryview(flat.view(np.uint8))


@dataclass(frozen=True)
class KVSnapshotInfo:
    """Manifest-level description of one stored KV snapshot."""

    key: str
    length: int  # valid cache positions (the prefix length in tokens)
    n_leaves: int
    nbytes_raw: int
    nbytes_disk: int
    codec: str
    prefill_s: float  # measured seconds to recompute this prefix from scratch
    created_at: float
    meta: Mapping[str, Any] = field(default_factory=dict)


def save_kv(
    backend: StorageBackend,
    key: str,
    cache: Any,
    length: int,
    *,
    codec: str | None = "none",
    level: int | None = None,
    prefill_s: float = 0.0,
    meta: Mapping[str, Any] | None = None,
) -> KVSnapshotInfo:
    """Encode ``cache`` (pytree of device/host arrays) as artifact ``key``.

    Each leaf is moved device->host once (``np.asarray``) and handed to the
    backend as a memoryview over that buffer; the manifest commits last.
    Returns the :class:`KVSnapshotInfo` the manifest records.
    """
    c = resolve_codec(codec, level)
    entries: list[dict[str, Any]] = []
    nbytes_raw = 0
    nbytes_disk = 0
    for i, (path, leaf) in enumerate(_flatten(cache)):
        host = np.asarray(leaf)  # device -> host (no-op for numpy leaves)
        mv = _raw_view(host)
        name = f"kv{i}.bin{c.suffix}"
        if c.name == "none":
            disk = backend.write_blob(key, name, mv)
        else:
            disk = backend.write_blob(key, name, c.compress(bytes(mv)))
        nbytes_raw += mv.nbytes
        nbytes_disk += disk
        entries.append(
            {
                "path": [list(p) for p in path],
                "name": name,
                "dtype": str(host.dtype),
                "shape": list(host.shape),
                "nbytes": mv.nbytes,
                "sha256": hashlib.sha256(mv).hexdigest(),
            }
        )
    created_at = time.time()
    doc = {
        "kind": "kv",
        "format": KV_FORMAT,
        "codec": c.name,
        "length": int(length),
        "prefill_s": float(prefill_s),
        "created_at": created_at,
        "leaves": entries,
        "meta": dict(meta or {}),
    }
    manifest = json.dumps(doc, sort_keys=True, separators=(",", ":")).encode()
    nbytes_disk += backend.write_blob(key, MANIFEST, manifest)
    return KVSnapshotInfo(
        key=key,
        length=int(length),
        n_leaves=len(entries),
        nbytes_raw=nbytes_raw,
        nbytes_disk=nbytes_disk,
        codec=c.name,
        prefill_s=float(prefill_s),
        created_at=created_at,
        meta=dict(meta or {}),
    )


def _read_manifest(backend: StorageBackend, key: str) -> dict[str, Any]:
    doc = json.loads(bytes(backend.read_blob(key, MANIFEST)))
    if doc.get("kind") != "kv":
        raise ValueError(f"artifact {key!r} is not a KV snapshot")
    if int(doc.get("format", 0)) > KV_FORMAT:
        raise ValueError(
            f"KV snapshot {key!r} has format {doc.get('format')}, "
            f"newer than this reader ({KV_FORMAT})"
        )
    return doc


def read_kv_info(backend: StorageBackend, key: str) -> KVSnapshotInfo:
    """Manifest-only read: size/cost/length without touching leaf payloads.

    Raises ``KeyError``/``FileNotFoundError`` when the snapshot is absent —
    same contract as ``read_blob``.
    """
    doc = _read_manifest(backend, key)
    leaves = doc.get("leaves", [])
    return KVSnapshotInfo(
        key=key,
        length=int(doc.get("length", 0)),
        n_leaves=len(leaves),
        nbytes_raw=sum(int(e["nbytes"]) for e in leaves),
        nbytes_disk=0,
        codec=str(doc.get("codec", "none")),
        prefill_s=float(doc.get("prefill_s", 0.0) or 0.0),
        created_at=float(doc.get("created_at", 0.0) or 0.0),
        meta=doc.get("meta", {}),
    )


def load_kv(
    backend: StorageBackend,
    key: str,
    *,
    verify: bool = False,
) -> tuple[Any, int, KVSnapshotInfo]:
    """Decode artifact ``key`` back into ``(host pytree, length, info)``.

    Raw (codec ``"none"``) leaves stream through ``open_blob_reader`` into a
    preallocated array — constant extra memory on file-backed backends.
    ``verify=True`` re-hashes every leaf against the manifest (transport
    integrity is already covered by the wire protocol's digests; this guards
    bytes at rest).
    """
    doc = _read_manifest(backend, key)
    c = resolve_codec(str(doc.get("codec", "none")))
    items: list[tuple[list, Any]] = []
    nbytes_disk = 0
    for entry in doc.get("leaves", []):
        dtype = _resolve_dtype(entry["dtype"])
        shape = tuple(int(s) for s in entry["shape"])
        want = int(entry["nbytes"])
        if c.name == "none":
            out = np.empty(want, np.uint8)
            view = memoryview(out)
            with backend.open_blob_reader(key, entry["name"]) as reader:
                nbytes_disk += reader.size
                got = 0
                while got < want:
                    n = reader.readinto(view[got:])
                    if n <= 0:
                        raise ValueError(
                            f"KV leaf {key}/{entry['name']}: short read "
                            f"({got}/{want} bytes)"
                        )
                    got += n
            raw: Any = out
        else:
            payload = backend.read_blob(key, entry["name"])
            nbytes_disk += len(payload)
            raw = np.frombuffer(c.decompress(payload), np.uint8)
            if raw.nbytes != want:
                raise ValueError(
                    f"KV leaf {key}/{entry['name']}: decompressed to "
                    f"{raw.nbytes} bytes, manifest says {want}"
                )
        if verify and hashlib.sha256(raw).hexdigest() != entry["sha256"]:
            raise ValueError(f"KV leaf {key}/{entry['name']} failed digest check")
        arr = raw.view(dtype).reshape(shape)
        items.append(([tuple(p) for p in entry["path"]], arr))
    info = KVSnapshotInfo(
        key=key,
        length=int(doc.get("length", 0)),
        n_leaves=len(items),
        nbytes_raw=sum(a.nbytes for _, a in items),
        nbytes_disk=nbytes_disk,
        codec=c.name,
        prefill_s=float(doc.get("prefill_s", 0.0) or 0.0),
        created_at=float(doc.get("created_at", 0.0) or 0.0),
        meta=doc.get("meta", {}),
    )
    return _unflatten([(list(map(tuple, p)), a) for p, a in items]), info.length, info
