"""Compression-codec registry for intermediate-data blobs.

The seed store hard-required ``zstandard``; on a bare environment that broke
import of the whole ``repro.core`` package.  Codecs are now pluggable:

  * ``zstd`` — best ratio/speed; registered only if ``zstandard`` imports.
  * ``zlib`` — stdlib fallback, always available.
  * ``none`` — identity; for ``MemoryBackend`` hot tiers where the bytes are
    re-read constantly and compression would only burn CPU.

``resolve_codec(None)`` picks the best available (zstd > zlib), so existing
callers keep their compression without naming a codec.  The codec *name* is
recorded in each artifact manifest, so a store written with zstd refuses
cleanly (rather than corrupting) when read on a host without zstandard.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Callable


@dataclass(frozen=True)
class Codec:
    """A named pair of bytes->bytes transforms plus the blob-file suffix."""

    name: str
    compress: Callable[[bytes], bytes]
    decompress: Callable[[bytes], bytes]
    suffix: str  # appended to blob file names, e.g. ".zst"


_REGISTRY: dict[str, Codec] = {}


def register_codec(codec: Codec) -> None:
    _REGISTRY[codec.name] = codec


def available_codecs() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def resolve_codec(spec: str | Codec | None, level: int | None = None) -> Codec:
    """Resolve a codec name (or None => best available) to a Codec.

    ``level`` selects the compression level for codecs that support one
    (zstd/zlib); ``None`` keeps the registry default.
    """
    if isinstance(spec, Codec):
        return spec
    if spec is None:
        for name in ("zstd", "zlib"):
            if name in _REGISTRY:
                spec = name
                break
        else:  # pragma: no cover - none/zlib are always registered
            spec = "none"
    if spec not in _REGISTRY:
        raise KeyError(
            f"unknown codec {spec!r}; available: {available_codecs()}"
            + (" (install 'zstandard' for zstd)" if spec == "zstd" else "")
        )
    if level is not None and spec in _LEVELED:
        return _LEVELED[spec](level)
    return _REGISTRY[spec]


register_codec(Codec("none", lambda b: b, lambda b: b, ""))
register_codec(
    Codec(
        "zlib",
        lambda b: zlib.compress(b, 6),
        zlib.decompress,
        ".z",
    )
)

_LEVELED: dict[str, Callable[[int], Codec]] = {
    "zlib": lambda lvl: Codec(
        "zlib", lambda b: zlib.compress(b, min(lvl, 9)), zlib.decompress, ".z"
    ),
}

try:  # optional dependency: zstd gives ~2x better ratio at similar speed
    import zstandard as _zstd

    def _make_zstd(level: int = 3) -> Codec:
        cctx = _zstd.ZstdCompressor(level=level)
        dctx = _zstd.ZstdDecompressor()
        return Codec("zstd", cctx.compress, dctx.decompress, ".zst")

    register_codec(_make_zstd())
    _LEVELED["zstd"] = _make_zstd
    HAVE_ZSTD = True
except ImportError:  # pragma: no cover - exercised on bare environments
    HAVE_ZSTD = False
