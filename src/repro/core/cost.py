"""Execution-time cost model (thesis Ch. 4.5.4, Eq. 4.9).

  T1 = time to execute modules M1..Mk (+ store the result)
  T2 = time to retrieve the stored result
  Execution-time gain = T1 - T2; storing pays off iff T1 > T2.

The model tracks per-(module, state) execution-time EMAs and the store's
measured save/load bandwidth so the executor can do cost-aware admission
("t1_gt_t2" mode) the way the thesis applies Eq. 4.9 to the P2IRC cluster.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .store import IntermediateStore
from .workflow import ModuleRef, PrefixKey


@dataclass
class CostModel:
    store: IntermediateStore | None = None
    ema_alpha: float = 0.4
    _exec_s: dict[str, float] = field(default_factory=dict)
    _out_bytes: dict[str, float] = field(default_factory=dict)

    def observe(self, ref: ModuleRef, seconds: float, out_bytes: int) -> None:
        k = ref.key(with_state=True)
        prev = self._exec_s.get(k)
        self._exec_s[k] = seconds if prev is None else (
            self.ema_alpha * seconds + (1 - self.ema_alpha) * prev
        )
        prevb = self._out_bytes.get(k)
        self._out_bytes[k] = out_bytes if prevb is None else (
            self.ema_alpha * out_bytes + (1 - self.ema_alpha) * prevb
        )

    def exec_seconds(self, ref: ModuleRef, default: float = 0.0) -> float:
        return self._exec_s.get(ref.key(with_state=True), default)

    def prefix_exec_seconds(self, prefix: PrefixKey) -> float:
        return sum(self.exec_seconds(m) for m in prefix.modules)

    def out_bytes(self, ref: ModuleRef, default: float = 0.0) -> float:
        return self._out_bytes.get(ref.key(with_state=True), default)

    # -- Eq. 4.9 --------------------------------------------------------------
    def t1(self, prefix: PrefixKey, measured_exec_s: float | None = None) -> float:
        exec_s = (
            measured_exec_s
            if measured_exec_s is not None
            else self.prefix_exec_seconds(prefix)
        )
        store_s = 0.0
        if self.store is not None:
            b = self.out_bytes(prefix.modules[-1])
            store_s = b / self.store.save_throughput()
        return exec_s + store_s

    def t2(self, prefix: PrefixKey) -> float:
        if self.store is None:
            return 0.0
        b = self.out_bytes(prefix.modules[-1])
        return b / self.store.load_throughput()

    def gain(self, prefix: PrefixKey, measured_exec_s: float | None = None) -> float:
        return self.t1(prefix, measured_exec_s) - self.t2(prefix)

    def should_store(self, prefix: PrefixKey, measured_exec_s: float | None = None) -> bool:
        return self.gain(prefix, measured_exec_s) > 0.0

    # -- gain-loss ratio (arXiv 2202.06473) -----------------------------------
    def recompute_seconds(
        self, prefix: PrefixKey, measured_exec_s: float | None = None
    ) -> float:
        """Best estimate of re-executing the prefix from scratch — the *gain*
        numerator of the eviction criterion.  Prefers the EMA (covers modules
        skipped in the measuring run) but never under-reports a measurement."""
        return max(self.prefix_exec_seconds(prefix), measured_exec_s or 0.0)

    def gain_per_byte(
        self, prefix: PrefixKey, measured_exec_s: float | None = None
    ) -> float:
        """Seconds saved per stored byte if this prefix's artifact is kept —
        the same ratio :func:`repro.core.eviction.gain_loss_ratio` computes
        from store records, here predicted *before* the artifact exists."""
        b = self.out_bytes(prefix.modules[-1])
        return self.gain(prefix, measured_exec_s) / max(b, 1.0)
