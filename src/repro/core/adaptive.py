"""Adaptive RISP (thesis Ch. 5): tool-state-aware recommendation.

The adaptive variant is the same association-rule machinery with prefix keys
that include each module's parameter-configuration digest — built by passing
``with_state=True`` to any policy.  This module provides the convenience
constructors and the parameter-matching helper used by the serving layer.
"""
from __future__ import annotations

from .risp import RISP, TSAR, TSFR, TSPAR, StoragePolicy
from .workflow import ModuleRef, PrefixKey


def adaptive_risp() -> RISP:
    return RISP(with_state=True)


def adaptive_policy(name: str) -> StoragePolicy:
    from .risp import make_policy

    return make_policy(name, with_state=True)


def states_match(a: ModuleRef, b: ModuleRef) -> bool:
    """Ch. 5: a stored prefix is reusable only if module ids AND parameter
    configurations match."""
    return a.module_id == b.module_id and a.state.digest == b.state.digest


def prefix_state_match(stored: PrefixKey, wanted: PrefixKey) -> bool:
    if stored.dataset_id != wanted.dataset_id or len(stored) != len(wanted):
        return False
    return all(states_match(x, y) for x, y in zip(stored.modules, wanted.modules))


__all__ = [
    "RISP",
    "TSAR",
    "TSPAR",
    "TSFR",
    "adaptive_risp",
    "adaptive_policy",
    "states_match",
    "prefix_state_match",
]
