"""JSONL provenance log of workflow executions (thesis: CouchDB run records)."""
from __future__ import annotations

import json
import threading
import time
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Any, Iterator


@dataclass
class RunRecord:
    workflow_id: str
    dataset_id: str
    modules: list[str]
    module_seconds: list[float]
    reused_prefix_depth: int
    load_seconds: float
    stored_keys: list[str]
    store_seconds: float
    total_seconds: float
    n_requests: int  # module execs + store/loads — the Table 6.1 "requests" proxy
    failed_at: int | None = None
    recovered_from_depth: int = 0
    timestamp: float = field(default_factory=time.time)
    extra: dict[str, Any] = field(default_factory=dict)


class ProvenanceLog:
    def __init__(self, path: str | Path | None = None) -> None:
        self.path = Path(path) if path else None
        self.records: list[RunRecord] = []
        self._lock = threading.Lock()  # concurrent runs append from workers
        if self.path and self.path.exists():
            for line in self.path.read_text().splitlines():
                if line.strip():
                    self.records.append(RunRecord(**json.loads(line)))

    def append(self, rec: RunRecord) -> None:
        with self._lock:
            self.records.append(rec)
            if self.path:
                with self.path.open("a") as f:
                    f.write(json.dumps(asdict(rec)) + "\n")

    def __iter__(self) -> Iterator[RunRecord]:
        return iter(self.records)

    def __len__(self) -> int:
        return len(self.records)

    def totals(self) -> dict[str, float]:
        return {
            "runs": len(self.records),
            "total_seconds": sum(r.total_seconds for r in self.records),
            "exec_seconds": sum(sum(r.module_seconds) for r in self.records),
            "load_seconds": sum(r.load_seconds for r in self.records),
            "store_seconds": sum(r.store_seconds for r in self.records),
            "requests": sum(r.n_requests for r in self.records),
            "reused_runs": sum(1 for r in self.records if r.reused_prefix_depth > 0),
        }
