"""Core RISP library: the paper's contribution as composable components."""
from .adaptive import adaptive_policy, adaptive_risp
from .backends import LocalFSBackend, MemoryBackend, StorageBackend, TieredBackend
from .codecs import Codec, available_codecs, register_codec, resolve_codec
from .corpus import CorpusSpec, galaxy_ch4_corpus, galaxy_ch5_corpus, generate_corpus
from .cost import CostModel
from .eviction import (
    EvictionManager,
    EvictionPolicy,
    GainLossEviction,
    LRUEviction,
    gain_loss_ratio,
)
from .executor import RunResult, WorkflowError, WorkflowExecutor
from .kvcodec import KVSnapshotInfo, load_kv, read_kv_info, save_kv
from .metrics import PolicyReport, evaluate_all, evaluate_policy
from .provenance import ProvenanceLog, RunRecord
from .registry import ModuleRegistry, ToolStateError, UnknownModuleError
from .risp import RISP, TSAR, TSFR, TSPAR, Recommendation, StoragePolicy, make_policy
from .rules import Rule, RuleMiner
from .store import ArtifactRecord, IntermediateStore, PutResult
from .workflow import (
    ModuleRef,
    ModuleSpec,
    PrefixKey,
    ToolState,
    Workflow,
    decode_param,
    encode_param,
)

__all__ = [
    "ArtifactRecord",
    "Codec",
    "CorpusSpec",
    "CostModel",
    "EvictionManager",
    "EvictionPolicy",
    "GainLossEviction",
    "IntermediateStore",
    "KVSnapshotInfo",
    "LRUEviction",
    "LocalFSBackend",
    "MemoryBackend",
    "ModuleRef",
    "ModuleRegistry",
    "ModuleSpec",
    "PolicyReport",
    "PrefixKey",
    "ProvenanceLog",
    "PutResult",
    "RISP",
    "Recommendation",
    "Rule",
    "RuleMiner",
    "RunRecord",
    "RunResult",
    "StoragePolicy",
    "StorageBackend",
    "TSAR",
    "TSFR",
    "TSPAR",
    "TieredBackend",
    "ToolState",
    "ToolStateError",
    "UnknownModuleError",
    "Workflow",
    "WorkflowError",
    "WorkflowExecutor",
    "adaptive_policy",
    "adaptive_risp",
    "available_codecs",
    "decode_param",
    "encode_param",
    "evaluate_all",
    "evaluate_policy",
    "gain_loss_ratio",
    "galaxy_ch4_corpus",
    "galaxy_ch5_corpus",
    "generate_corpus",
    "load_kv",
    "make_policy",
    "read_kv_info",
    "register_codec",
    "resolve_codec",
    "save_kv",
]
