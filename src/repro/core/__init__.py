"""Core RISP library: the paper's contribution as composable components."""
from .adaptive import adaptive_policy, adaptive_risp
from .corpus import CorpusSpec, galaxy_ch4_corpus, galaxy_ch5_corpus, generate_corpus
from .cost import CostModel
from .executor import RunResult, WorkflowError, WorkflowExecutor
from .metrics import PolicyReport, evaluate_all, evaluate_policy
from .provenance import ProvenanceLog, RunRecord
from .risp import RISP, TSAR, TSFR, TSPAR, Recommendation, StoragePolicy, make_policy
from .rules import Rule, RuleMiner
from .store import IntermediateStore
from .workflow import ModuleRef, ModuleSpec, PrefixKey, ToolState, Workflow

__all__ = [
    "CorpusSpec",
    "CostModel",
    "IntermediateStore",
    "ModuleRef",
    "ModuleSpec",
    "PolicyReport",
    "PrefixKey",
    "ProvenanceLog",
    "RISP",
    "Recommendation",
    "Rule",
    "RuleMiner",
    "RunRecord",
    "RunResult",
    "StoragePolicy",
    "TSAR",
    "TSFR",
    "TSPAR",
    "ToolState",
    "Workflow",
    "WorkflowError",
    "WorkflowExecutor",
    "adaptive_policy",
    "adaptive_risp",
    "evaluate_all",
    "evaluate_policy",
    "galaxy_ch4_corpus",
    "galaxy_ch5_corpus",
    "generate_corpus",
    "make_policy",
]
