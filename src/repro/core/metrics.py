"""Replay evaluator computing the thesis' four measures (Ch. 4.5.2 / 5.4.2).

  LR    = pipelines that could reuse previously stored results / pipelines x100
  PSRR  = stored results reused at least once / stored results x100
  FRSR  = total reuse events / stored results
  PISRS = stored results / total intermediate states x100
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Sequence

from .risp import StoragePolicy, make_policy
from .workflow import Workflow


@dataclass
class PolicyReport:
    name: str
    n_pipelines: int
    n_reusable_pipelines: int
    n_stored: int
    n_stored_reused: int
    total_reuse_events: int
    total_intermediate_states: int

    @property
    def lr(self) -> float:
        return 100.0 * self.n_reusable_pipelines / max(self.n_pipelines, 1)

    @property
    def psrr(self) -> float:
        return 100.0 * self.n_stored_reused / max(self.n_stored, 1)

    @property
    def frsr(self) -> float:
        return self.total_reuse_events / max(self.n_stored, 1)

    @property
    def pisrs(self) -> float:
        return 100.0 * self.n_stored / max(self.total_intermediate_states, 1)

    def row(self) -> dict[str, float | int | str]:
        return {
            "policy": self.name,
            "pipelines": self.n_pipelines,
            "reusable_pipelines": self.n_reusable_pipelines,
            "stored": self.n_stored,
            "LR_pct": round(self.lr, 2),
            "PSRR_pct": round(self.psrr, 2),
            "FRSR": round(self.frsr, 2),
            "PISRS_pct": round(self.pisrs, 2),
        }


def evaluate_policy(policy: StoragePolicy, corpus: Iterable[Workflow]) -> PolicyReport:
    for wf in corpus:
        policy.step(wf)
    return PolicyReport(
        name=policy.name,
        n_pipelines=policy.n_pipelines,
        n_reusable_pipelines=policy.n_reusable_pipelines,
        n_stored=policy.n_stored,
        n_stored_reused=policy.n_stored_reused,
        total_reuse_events=policy.total_reuse_events,
        total_intermediate_states=policy.total_intermediate_states,
    )


def evaluate_all(
    corpus: Sequence[Workflow],
    names: Sequence[str] = ("PT", "TSAR", "TSPAR", "TSFR"),
    with_state: bool = False,
) -> dict[str, PolicyReport]:
    return {
        name: evaluate_policy(make_policy(name, with_state=with_state), corpus)
        for name in names
    }
