"""Shared module registry: one source of truth for executable modules.

Before this existed, every front door (``WorkflowExecutor``, ``DagScheduler``
via ``WorkflowService``, and ad-hoc dicts in examples) kept its own
``dict[str, ModuleSpec]`` with duplicated ``register``/``register_fn``
bookkeeping — a module registered on the service was invisible to a
standalone executor sharing the same store, so their runs silently diverged.
:class:`ModuleRegistry` is the single registry all engines consume (it is a
``MutableMapping``, so any code written against the plain dict keeps
working), plus the declarative conveniences the ``repro.api`` facade builds
on: a ``@registry.module(...)`` decorator, default-parameter merging, and
tool-state validation against the module's call signature.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Iterable, Iterator, Mapping, MutableMapping

from .workflow import ModuleRef, ModuleSpec, ToolState


class UnknownModuleError(KeyError):
    """A workflow references a module id nobody registered."""

    def __str__(self) -> str:  # KeyError quotes its arg; keep the message flat
        return self.args[0] if self.args else ""


class ToolStateError(ValueError):
    """A tool state names parameters the module's function cannot accept."""


class ModuleRegistry(MutableMapping[str, ModuleSpec]):
    """Mapping of ``module_id -> ModuleSpec`` shared by every engine.

    Construction accepts nothing, an iterable of specs, or an existing
    ``dict`` — a plain dict is adopted *by reference*, so legacy code that
    still mutates the raw dict stays in sync with engines holding the
    registry.
    """

    def __init__(
        self,
        specs: Mapping[str, ModuleSpec] | Iterable[ModuleSpec] | None = None,
    ) -> None:
        if specs is None:
            self._specs: dict[str, ModuleSpec] = {}
        elif isinstance(specs, ModuleRegistry):
            self._specs = specs._specs  # share, don't copy: one source of truth
        elif isinstance(specs, dict):
            self._specs = specs  # adopt by reference (legacy shared-dict setups)
        elif isinstance(specs, Mapping):
            self._specs = dict(specs)
        else:
            self._specs = {s.module_id: s for s in specs}

    # -- mapping protocol ----------------------------------------------------
    def __getitem__(self, module_id: str) -> ModuleSpec:
        try:
            return self._specs[module_id]
        except KeyError:
            known = ", ".join(sorted(self._specs)[:8]) or "<none>"
            raise UnknownModuleError(
                f"unknown module {module_id!r}; registered modules: {known}"
                + ("..." if len(self._specs) > 8 else "")
            ) from None

    def __setitem__(self, module_id: str, spec: ModuleSpec) -> None:
        if not isinstance(spec, ModuleSpec):
            raise TypeError(f"expected ModuleSpec, got {type(spec).__name__}")
        if spec.module_id != module_id:
            raise ValueError(
                f"key {module_id!r} does not match spec.module_id {spec.module_id!r}"
            )
        self._specs[module_id] = spec

    def __delitem__(self, module_id: str) -> None:
        del self._specs[module_id]

    def __iter__(self) -> Iterator[str]:
        return iter(self._specs)

    def __len__(self) -> int:
        return len(self._specs)

    def __repr__(self) -> str:
        return f"ModuleRegistry({sorted(self._specs)})"

    # -- registration ----------------------------------------------------------
    def register(self, spec: ModuleSpec) -> ModuleSpec:
        self[spec.module_id] = spec
        return spec

    def register_fn(
        self,
        module_id: str,
        fn: Callable[..., Any],
        cost_hint: float | None = None,
        **default_params: Any,
    ) -> ModuleSpec:
        return self.register(
            ModuleSpec(module_id, fn, dict(default_params), cost_hint)
        )

    def module(
        self,
        module_id: str | None = None,
        *,
        cost_hint: float | None = None,
        **default_params: Any,
    ) -> Callable[[Callable[..., Any]], Callable[..., Any]]:
        """Decorator registration::

            @registry.module("normalize")
            def normalize(x, eps=1e-6): ...

            @registry.module()          # id defaults to the function name
            def featurize(x): ...

        The decorated function is returned unchanged (it stays directly
        callable); defaults passed to the decorator become the module's
        default tool state.
        """

        def deco(fn: Callable[..., Any]) -> Callable[..., Any]:
            mid = module_id or fn.__name__
            self.register_fn(mid, fn, cost_hint=cost_hint, **default_params)
            return fn

        return deco

    def ensure(
        self,
        module_id: str,
        fn: Callable[..., Any] | None = None,
        cost_hint: float | None = None,
        **default_params: Any,
    ) -> ModuleSpec:
        """Register ``module_id`` if absent; return its spec either way.

        Used by engines that synthesize module occurrences from observed
        work units (the serving engine's prompt chunks): ``fn=None`` records
        a non-executable placeholder so the module universe is introspectable
        without pretending the unit can be re-run by a workflow engine.
        """
        if module_id in self._specs:
            spec = self._specs[module_id]
            if cost_hint is not None and spec.cost_hint is None:
                spec.cost_hint = cost_hint
            return spec
        if fn is None:

            def _placeholder(*a: Any, **k: Any) -> Any:
                raise NotImplementedError(
                    f"module {module_id!r} was observed (not registered with an "
                    "executable function); it cannot be run by a workflow engine"
                )

            fn = _placeholder
        return self.register_fn(module_id, fn, cost_hint=cost_hint, **default_params)

    # -- resolution / validation ----------------------------------------------
    def ref(
        self,
        module_id: str,
        params: Mapping[str, Any] | None = None,
        validate: bool = True,
    ) -> ModuleRef:
        """Resolve ``(module_id, params)`` to a :class:`ModuleRef` whose tool
        state merges the module's registered defaults — the identity every
        engine must agree on for cross-engine artifact reuse."""
        spec = self[module_id]
        if validate:
            self.validate_state(module_id, params)
        return spec.ref(params)

    def validate_state(
        self, module_id: str, params: Mapping[str, Any] | None
    ) -> None:
        """Reject tool states the module's function could never accept.

        Checks parameter *names* against the function signature (anything
        goes when the function takes ``**kwargs``); value encodability is
        enforced separately by ``ToolState.from_config``.
        """
        spec = self[module_id]
        if not params:
            return
        try:
            sig = inspect.signature(spec.fn)
        except (TypeError, ValueError):  # builtins / C callables: no signature
            return
        accepts_kwargs = any(
            p.kind is inspect.Parameter.VAR_KEYWORD
            for p in sig.parameters.values()
        )
        if accepts_kwargs:
            return
        # the first positionally-fillable parameter receives the flowing
        # value, not a tool-state param; everything keyword-passable after it
        # is fair game
        allowed: set[str] = set()
        data_arg_seen = False
        for n, p in sig.parameters.items():
            if p.kind in (
                inspect.Parameter.POSITIONAL_ONLY,
                inspect.Parameter.POSITIONAL_OR_KEYWORD,
            ):
                if not data_arg_seen:
                    data_arg_seen = True
                    continue
                if p.kind is inspect.Parameter.POSITIONAL_OR_KEYWORD:
                    allowed.add(n)
            elif p.kind is inspect.Parameter.KEYWORD_ONLY:
                allowed.add(n)
        unknown = sorted(set(map(str, params)) - allowed)
        if unknown:
            raise ToolStateError(
                f"module {module_id!r} does not accept parameter(s) "
                f"{unknown}; accepted: {sorted(allowed) or '<none>'}"
            )

    def resolve_params(self, ref: ModuleRef) -> dict[str, Any]:
        """Concrete call kwargs for one module occurrence: registered defaults
        overlaid with the ref's decoded tool state."""
        spec = self[ref.module_id]
        params = dict(spec.default_params)
        params.update(ref.state.to_config())
        return params

    def make_state(
        self, module_id: str, params: Mapping[str, Any] | None = None
    ) -> ToolState:
        return self.ref(module_id, params).state
