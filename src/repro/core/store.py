"""Shard-aware, content-addressed intermediate-data store.

The thesis stored intermediate states in HDFS via Python pickle (Ch. 3.4).
Here each artifact is a pytree of arrays; every *addressable shard* of every
leaf is written as an independent compressed blob, so on a multi-host pod each
host persists exactly its local shards (the HDFS-write analogue) and restores
them without gathering.  A JSON manifest records the global shapes/dtypes/
shard indices plus measured save/load timings — the inputs to the thesis'
``T1 > T2`` admission test (Eq. 4.9).

The store splits three concerns across three pluggable layers:

  * serialization — pytree flattening, manifests, codec compression (here);
  * persistence   — a :class:`~repro.core.backends.StorageBackend`
    (filesystem, memory, or tiered hot/cold);
  * retention     — an optional :class:`~repro.core.eviction.EvictionManager`
    that keeps ``total_disk_bytes`` under ``capacity_bytes`` by gain-loss-
    ratio (or LRU) eviction, notifying listeners (the executor's policy
    bookkeeping) of every evicted key.
"""
from __future__ import annotations

import json
import pickle
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Sequence

import numpy as np

import jax

from ..obs import tracing as _tracing
from ..obs.metrics import MetricsRegistry
from .backends import BackendUnavailable, LocalFSBackend, StorageBackend
from .codecs import Codec, resolve_codec
from .eviction import EvictionContext, EvictionManager

_LEAF = "__repro_leaf__"


@dataclass
class ArtifactRecord:
    key: str
    nbytes_raw: int
    nbytes_disk: int
    save_s: float
    load_s: float | None = None
    n_loads: int = 0
    created_at: float = field(default_factory=time.time)
    compute_s: float | None = None  # producer-reported recompute seconds
    last_used_at: float = 0.0

    def __post_init__(self) -> None:
        if not self.last_used_at:
            self.last_used_at = self.created_at


@dataclass
class PutResult:
    key: str
    nbytes_raw: int
    nbytes_disk: int
    seconds: float
    deduped: bool = False
    admitted: bool = True  # False: artifact exceeded the whole budget
    evicted: tuple[str, ...] = ()  # keys evicted to make room


class IntermediateStore:
    """Content-addressed artifact store with per-shard blobs.

    Parameters
    ----------
    root: directory for the default :class:`LocalFSBackend`; ignored when an
        explicit ``backend`` is given.
    compression_level: level for the selected codec (zstd/zlib).
    backend: storage backend; defaults to ``LocalFSBackend(root)``.
    codec: codec name (``"zstd"``/``"zlib"``/``"none"``) or ``Codec``;
        ``None`` picks the best available (zstd if installed, else zlib).
    capacity_bytes: optional storage budget; when set, every ``put`` evicts
        lowest-value artifacts (per ``eviction``) until the store fits.
    eviction: ``"gain_loss"`` (default) or ``"lru"``, or an
        :class:`EvictionPolicy` instance.
    index_flush_every: persist ``index.json`` after at most this many index
        mutations (puts/evicts/hit-stat updates) ...
    index_flush_interval_s: ... or when the last flush is older than this,
        whichever comes first.  ``index.json`` is a crash-safe *cache* of
        stats, not the source of truth — artifact existence is always
        re-verified against the backend, so a crash between flushes loses
        at most some hit statistics, never correctness.
    """

    def __init__(
        self,
        root: str | Path | None = None,
        compression_level: int = 3,
        *,
        backend: StorageBackend | None = None,
        codec: str | Codec | None = None,
        capacity_bytes: int | None = None,
        eviction: str | Any = "gain_loss",
        index_flush_every: int = 64,
        index_flush_interval_s: float = 1.0,
        registry: MetricsRegistry | None = None,
    ) -> None:
        if backend is None:
            if root is None:
                raise ValueError("pass either root or backend")
            backend = LocalFSBackend(root)
        self.backend = backend
        self.codec = resolve_codec(codec, level=compression_level)
        self.evictor = EvictionManager(capacity_bytes, eviction)
        self.records: dict[str, ArtifactRecord] = {}
        self._evict_listeners: list[Callable[[str], None]] = []
        self.index_flush_every = max(1, index_flush_every)
        self.index_flush_interval_s = index_flush_interval_s
        self._dirty = False
        self._mutations_since_flush = 0
        self._last_flush = time.monotonic()
        self._shared_index_cache: tuple[float, bytes | str | None, dict[str, Any]] | None = None
        # one reentrant lock serializes index/manifest mutation so concurrent
        # scheduler workers can't corrupt ``records`` or interleave partial
        # writes of ``index.json`` (evict listeners run while it is held —
        # they must not call back into the store or take the policy lock)
        self._lock = threading.RLock()
        self.metrics = registry if registry is not None else MetricsRegistry()
        m = self.metrics
        self._m_puts = m.counter("repro_store_puts_total", "artifacts persisted by the store")
        self._m_gets = m.counter("repro_store_gets_total", "artifact loads served by the store")
        self._m_put_seconds = m.histogram("repro_store_put_seconds", "store put latency")
        self._m_get_seconds = m.histogram("repro_store_get_seconds", "store get latency")
        self._m_evictions = m.counter(
            "repro_store_evictions_total", "artifacts deleted by budget eviction"
        )
        self._m_evicted_bytes = m.counter(
            "repro_store_evicted_bytes_total", "disk bytes reclaimed by eviction"
        )
        self._m_reuse_hits = m.counter(
            "repro_reuse_hits_total", "artifact loads that replaced a recompute"
        )
        self._m_saved = m.counter(
            "repro_reuse_seconds_saved_total",
            "estimated compute seconds avoided by reuse (paper Ch. 4 time gain)",
        )
        m.gauge(
            "repro_store_disk_bytes", "current disk footprint of stored artifacts"
        ).unlabeled.set_function(lambda: self.total_disk_bytes)
        m.gauge(
            "repro_store_artifacts", "artifacts currently recorded"
        ).unlabeled.set_function(lambda: len(self.records))
        self._load_index()

    @property
    def capacity_bytes(self) -> int | None:
        return self.evictor.capacity_bytes

    # -- index persistence -------------------------------------------------
    def _load_index(self) -> None:
        raw = self.backend.read_meta("index.json")
        if raw:
            for k, v in json.loads(raw).items():
                self.records[k] = ArtifactRecord(**v)

    def _flush_index(self) -> None:
        self.backend.write_meta(
            "index.json", json.dumps({k: vars(v) for k, v in self.records.items()})
        )
        self._dirty = False
        self._mutations_since_flush = 0
        self._last_flush = time.monotonic()

    def _mark_dirty(self) -> None:
        """Record an index mutation; flush on a count/age threshold rather
        than per mutation (a store with n artifacts would otherwise rewrite
        the O(n) index n times — O(n^2) churn).  Callers hold ``_lock``."""
        self._dirty = True
        self._mutations_since_flush += 1
        if (
            self._mutations_since_flush >= self.index_flush_every
            or time.monotonic() - self._last_flush >= self.index_flush_interval_s
        ):
            self._flush_index()

    def flush(self) -> None:
        """Persist the index now if it has unflushed mutations."""
        with self._lock:
            if self._dirty:
                self._flush_index()

    def close(self) -> None:
        self.flush()

    def __enter__(self) -> "IntermediateStore":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def __del__(self) -> None:  # best-effort: never raise during teardown
        try:
            self.flush()
        except Exception:  # noqa: BLE001
            pass

    # -- helpers -------------------------------------------------------------
    def has(self, key: str) -> bool:
        """True iff ``key`` is loadable right now (collapses ``unreachable``
        to False — use :meth:`has_state` where the difference matters)."""
        return self.has_state(key) == "present"

    def has_state(self, key: str) -> str:
        """``"present"`` | ``"absent"`` | ``"unreachable"``.

        ``absent`` is authoritative (every replica was reachable and the
        artifact is gone — callers may prune bookkeeping); ``unreachable``
        means the pool, or every replica of this key in a sharded pool,
        cannot be reached: *not reusable right now*, but NOT gone — neither
        the record nor any policy bookkeeping should be dropped, and
        accounting converges when the pool returns.
        """
        with self._lock:
            try:
                alive = self.backend.exists(key)
            except BackendUnavailable:
                return "unreachable"
            return self._classify_presence(key, alive)

    def has_state_many(self, keys: "Sequence[str]") -> dict[str, str]:
        """Batched :meth:`has_state`: one backend round trip for any number
        of keys (``exists_many`` coalesces into a single ``batch`` request on
        a remote pool; a sharded pool fans it out once per involved shard).
        Same per-key answers AND same side effects — phantom records are
        pruned, sibling artifacts adopted — so a deep reuse-probe walk costs
        O(1) round trips instead of O(depth)."""
        keys = list(dict.fromkeys(keys))
        if not keys:
            return {}
        with self._lock:
            try:
                presence = self.backend.exists_many(keys)
            except BackendUnavailable:
                return {k: "unreachable" for k in keys}
            out: dict[str, str] = {}
            for k in keys:
                alive = presence.get(k)
                if alive is None:
                    out[k] = "unreachable"
                else:
                    out[k] = self._classify_presence(k, bool(alive))
            return out

    def _classify_presence(self, key: str, alive: bool) -> str:
        """Map one key's backend-reported presence to a ``has_state`` answer,
        applying the bookkeeping side effects.  Callers hold ``_lock``."""
        if key in self.records:
            if alive:
                return "present"
            # phantom record: the artifact vanished without us hearing
            # (evicted fleet-wide before we connected, crashed writer,
            # stale shared index).  Prune it so budget accounting never
            # counts bytes that are not there, and tell listeners so
            # policy bookkeeping converges like any other eviction.
            del self.records[key]
            self._dirty = True
            self._mutations_since_flush += 1
            for fn in self._evict_listeners:
                fn(key)
            return "absent"
        # a sibling process sharing this backend (remote store) may have
        # persisted the artifact after our index snapshot: adopt it
        if alive:
            self._adopt_record(key)
            return "present"
        return "absent"

    def _shared_index(self) -> dict[str, Any]:
        """The pool's ``index.json``, parsed, cached for one flush interval —
        adopting k sibling artifacts must not cost k full-index transfers.
        When the TTL lapses but the raw bytes come back unchanged, the cached
        parse is reused: deep-chain probes against a quiet pool pay a transfer
        but never an O(artifacts) JSON decode.  Callers hold ``_lock``."""
        now = time.monotonic()
        cached = self._shared_index_cache
        if cached is not None and now - cached[0] < max(self.index_flush_interval_s, 1.0):
            return cached[2]
        try:
            raw = self.backend.read_meta("index.json")
        except BackendUnavailable:
            raw = None  # stats cache unreachable: synthesize records instead
        if cached is not None and raw == cached[1]:
            self._shared_index_cache = (now, cached[1], cached[2])
            return cached[2]
        parsed: dict[str, Any] = {}
        if raw:
            try:
                parsed = json.loads(raw)
            except json.JSONDecodeError:
                parsed = {}
        self._shared_index_cache = (now, raw, parsed)
        return parsed

    def _adopt_record(self, key: str) -> None:
        """Create a local record for an artifact another process stored.

        Prefer the shared ``index.json`` entry (it carries real stats); when
        the writer has not flushed yet (or our cached view predates it),
        synthesize a minimal record from the backend's byte count.  Callers
        hold ``_lock``."""
        entry = self._shared_index().get(key)
        if entry:
            self.records[key] = ArtifactRecord(**entry)
            return
        try:
            nb = int(self.backend.nbytes(key))
        except (NotImplementedError, BackendUnavailable):
            nb = 0
        self.records[key] = ArtifactRecord(key, nbytes_raw=nb, nbytes_disk=nb, save_s=0.0)

    def _blob_name(self, stem: str) -> str:
        return f"{stem}.npy{self.codec.suffix}"

    def _write_blob(self, key: str, name: str, arr: np.ndarray) -> int:
        # raw bytes + manifest-recorded dtype/shape: survives ml_dtypes
        # (bfloat16 etc.) that the npy format would degrade to void types
        blob = self.codec.compress(np.ascontiguousarray(arr).tobytes())
        return self.backend.write_blob(key, name, blob)

    def _read_blob(
        self, key: str, name: str, codec: Codec, dtype: str, shape: list[int]
    ) -> np.ndarray:
        raw = codec.decompress(self.backend.read_blob(key, name))
        return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape)

    # -- eviction ------------------------------------------------------------
    def add_evict_listener(self, fn: Callable[[str], None]) -> None:
        """``fn(key)`` is called for every artifact the budget evicts."""
        if fn not in self._evict_listeners:
            self._evict_listeners.append(fn)

    def remove_evict_listener(self, fn: Callable[[str], None]) -> None:
        """Unregister a listener (e.g. when an executor is discarded but the
        store lives on)."""
        if fn in self._evict_listeners:
            self._evict_listeners.remove(fn)

    def evict(self, key: str) -> None:
        """Drop an artifact and notify listeners (policy bookkeeping)."""
        with self._lock:
            self._evict_batch([key])
            self._mark_dirty()

    def on_external_evict(self, key: str) -> None:
        """A sibling process evicted ``key`` at the shared backend: drop the
        local record and notify listeners — the backend delete already
        happened remotely.  Wired to the remote store's eviction-event
        stream so every client's ``policy.stored`` view converges.

        Runs on the backend's event thread, so it only *marks* the index
        dirty (no ``_mark_dirty`` threshold check): an inline flush would be
        a synchronous network write back into the backend from its own
        event loop — the next regular mutation or ``flush()`` persists it.
        """
        with self._lock:
            if key in self.records:
                del self.records[key]
                self._dirty = True
                self._mutations_since_flush += 1
            for fn in self._evict_listeners:
                fn(key)

    def _evict_batch(self, keys: list[str]) -> None:
        """Drop artifacts + notify listeners without flushing per victim;
        callers flush the index once afterwards."""
        sp = _tracing.span("store.evict", kind="store", n=len(keys)) if keys else None
        for key in keys:
            rec = self.records.get(key)
            if rec is not None:
                self.backend.delete(key)
                del self.records[key]
                self._m_evictions.inc()
                self._m_evicted_bytes.inc(rec.nbytes_disk)
            for fn in self._evict_listeners:
                fn(key)
        if sp is not None:
            sp.end()

    def _enforce_budget(self, incoming: str) -> tuple[str, ...]:
        victims = self.evictor.select_victims(
            self.records,
            self.total_disk_bytes,
            ctx=EvictionContext(load_bps=self.load_throughput()),
            incoming=incoming,
        )
        self._evict_batch(victims)
        return tuple(victims)

    # -- public API ----------------------------------------------------------
    def put(
        self, key: str, value: Any, *, compute_seconds: float | None = None
    ) -> PutResult:
        """Persist a pytree under ``key``.

        ``compute_seconds`` is the producer's measured cost of recomputing the
        value (the executor passes the prefix's module seconds) — the *gain*
        numerator of the eviction criterion.
        """
        with _tracing.span("store.put", kind="store", key=key) as sp:
            with self._lock:
                res = self._put_locked(key, value, compute_seconds)
            sp.set(nbytes=res.nbytes_disk, deduped=res.deduped, admitted=res.admitted)
        self._m_puts.inc()
        self._m_put_seconds.observe(res.seconds)
        return res

    def _put_locked(
        self, key: str, value: Any, compute_seconds: float | None
    ) -> PutResult:
        if self.has(key):
            rec = self.records[key]
            if compute_seconds is not None:
                rec.compute_s = compute_seconds
            return PutResult(key, rec.nbytes_raw, rec.nbytes_disk, 0.0, deduped=True)
        t0 = time.perf_counter()

        leaves, treedef = jax.tree_util.tree_flatten(value)
        # pre-write admission: an artifact whose RAW size already exceeds the
        # whole budget is rejected before any bytes are compressed or written
        # (compression below 1x would not change the verdict in practice)
        est_raw = sum(int(getattr(leaf, "nbytes", 0) or 0) for leaf in leaves)
        if not self.evictor.admits(est_raw) and self.codec.name == "none":
            return PutResult(key, est_raw, est_raw, 0.0, admitted=False)
        if (
            self.evictor.capacity_bytes is not None
            and est_raw > 4 * self.evictor.capacity_bytes
        ):
            # even generous 4x compression could not fit it; don't write 100GB
            # into a 1GB-budget store just to find out
            return PutResult(key, est_raw, est_raw, 0.0, admitted=False)
        manifest: dict[str, Any] = {"key": key, "codec": self.codec.name, "leaves": []}
        nbytes_raw = 0
        nbytes_disk = 0
        for i, leaf in enumerate(leaves):
            entry: dict[str, Any] = {"index": i}
            if isinstance(leaf, jax.Array) and len(leaf.addressable_shards) > 1:
                # one blob per local shard: each host writes only its shards
                entry["kind"] = "sharded"
                entry["shape"] = list(leaf.shape)
                entry["dtype"] = str(leaf.dtype)
                entry["shards"] = []
                for s in leaf.addressable_shards:
                    arr = np.asarray(s.data)
                    name = self._blob_name(f"leaf{i}.shard{s.device.id}")
                    nbytes_disk += self._write_blob(key, name, arr)
                    nbytes_raw += arr.nbytes
                    entry["shards"].append(
                        {
                            "device": s.device.id,
                            "index": [[sl.start, sl.stop] for sl in s.index],
                            "shape": list(arr.shape),
                            "file": name,
                        }
                    )
            else:
                arr = np.asarray(leaf)
                entry["kind"] = "dense"
                entry["shape"] = list(arr.shape)
                entry["dtype"] = str(arr.dtype)
                name = self._blob_name(f"leaf{i}")
                nbytes_disk += self._write_blob(key, name, arr)
                nbytes_raw += arr.nbytes
                entry["file"] = name
            manifest["leaves"].append(entry)

        if not self.evictor.admits(nbytes_disk):
            # bigger than the whole budget: storing it could never fit
            self.backend.delete(key)
            return PutResult(key, nbytes_raw, nbytes_disk, 0.0, admitted=False)

        self.backend.write_blob(key, "skeleton.pkl", pickle.dumps(treedef))
        self.backend.write_blob(key, "manifest.json", json.dumps(manifest).encode())
        dt = time.perf_counter() - t0
        self.records[key] = ArtifactRecord(
            key, nbytes_raw, nbytes_disk, dt, compute_s=compute_seconds
        )
        evicted = self._enforce_budget(incoming=key)
        self._mark_dirty()
        # a value-aware policy may decide the newcomer itself is the victim:
        # it displaces only artifacts worth less per byte than itself
        return PutResult(
            key, nbytes_raw, nbytes_disk, dt, admitted=key not in evicted,
            evicted=evicted,
        )

    def get(self, key: str, sharding: jax.sharding.Sharding | None = None) -> Any:
        with _tracing.span("store.get", kind="store", key=key) as sp:
            t0 = time.perf_counter()
            with self._lock:
                value = self._get_locked(key, sharding)
                rec = self.records.get(key)
                compute_s = rec.compute_s if rec is not None else None
            dt = time.perf_counter() - t0
            self._m_gets.inc()
            self._m_get_seconds.observe(dt)
            # every successful load is a reuse hit: the caller was about to
            # recompute this prefix.  The realized time gain is the producer's
            # measured compute cost minus what the load actually took.
            self._m_reuse_hits.inc()
            saved = max(0.0, (compute_s or 0.0) - dt)
            if saved > 0.0:
                self._m_saved.inc(saved)
            sp.set(source="store", saved_s=round(saved, 6))
        return value

    def _get_locked(self, key: str, sharding: jax.sharding.Sharding | None) -> Any:
        t0 = time.perf_counter()
        # optimistic read: the manifest itself is the existence proof, so a
        # fully-cached get costs ZERO backend round trips (has() would pay an
        # uncacheable exists() probe per call — presence stays authoritative
        # for *planning*, but a load can trust the blob it actually got)
        try:
            manifest = json.loads(self.backend.read_blob(key, "manifest.json"))
        except (KeyError, FileNotFoundError):
            raise KeyError(key) from None
        if key not in self.records:
            self._adopt_record(key)  # stored by a sibling process
        treedef = pickle.loads(self.backend.read_blob(key, "skeleton.pkl"))
        # pre-codec manifests (seed layout) were always zstd-compressed
        codec = resolve_codec(manifest.get("codec", "zstd"))
        leaves = []
        for entry in manifest["leaves"]:
            if entry["kind"] == "sharded":
                out = np.empty(entry["shape"], dtype=np.dtype(entry["dtype"]))
                for s in entry["shards"]:
                    idx = tuple(slice(a, b) for a, b in s["index"])
                    out[idx] = self._read_blob(
                        key, s["file"], codec, entry["dtype"], s["shape"]
                    )
                arr = out
            else:
                arr = self._read_blob(
                    key, entry["file"], codec, entry["dtype"], entry["shape"]
                )
            if sharding is not None:
                leaves.append(jax.device_put(arr, sharding))
            else:
                leaves.append(jax.numpy.asarray(arr))
        value = jax.tree_util.tree_unflatten(treedef, leaves)
        dt = time.perf_counter() - t0
        rec = self.records[key]
        rec.load_s = dt
        rec.n_loads += 1
        # deliberately wall-clock (unlike deadline math elsewhere): record
        # timestamps are persisted in index.json and compared across
        # processes/restarts, where monotonic readings are meaningless
        rec.last_used_at = time.time()
        # hit statistics drive eviction ranking, so they should survive
        # restarts of read-only sessions; the batched-flush thresholds bound
        # both the rewrite frequency and the window of lost stats
        self._mark_dirty()
        return value

    def delete(self, key: str) -> None:
        with self._lock:
            if key in self.records:
                self.backend.delete(key)
                del self.records[key]
                self._mark_dirty()

    # -- accounting ----------------------------------------------------------
    @property
    def total_disk_bytes(self) -> int:
        with self._lock:
            return sum(r.nbytes_disk for r in self.records.values())

    @property
    def total_raw_bytes(self) -> int:
        with self._lock:
            return sum(r.nbytes_raw for r in self.records.values())

    def save_throughput(self) -> float:
        """Mean observed store bandwidth (raw bytes/s) for T1 estimation."""
        with self._lock:
            pairs = [
                (r.nbytes_raw, r.save_s) for r in self.records.values() if r.save_s > 0
            ]
        if not pairs:
            return 1e9
        tot_b = sum(b for b, _ in pairs)
        tot_s = sum(s for _, s in pairs)
        return tot_b / max(tot_s, 1e-9)

    def load_throughput(self) -> float:
        with self._lock:
            pairs = [
                (r.nbytes_raw, r.load_s)
                for r in self.records.values()
                if r.load_s and r.load_s > 0
            ]
        if not pairs:
            return self.save_throughput() * 2.0
        tot_b = sum(b for b, _ in pairs)
        tot_s = sum(s for _, s in pairs)
        return tot_b / max(tot_s, 1e-9)
