"""Shard-aware, content-addressed intermediate-data store.

The thesis stored intermediate states in HDFS via Python pickle (Ch. 3.4).
Here each artifact is a pytree of arrays; every *addressable shard* of every
leaf is written as an independent zstd-compressed npy blob, so on a multi-host
pod each host persists exactly its local shards (the HDFS-write analogue) and
restores them without gathering.  A JSON manifest records the global
shapes/dtypes/shard indices plus measured save/load timings — the inputs to
the thesis' ``T1 > T2`` admission test (Eq. 4.9).
"""
from __future__ import annotations

import hashlib
import io
import json
import pickle
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any

import numpy as np
import zstandard as zstd

import jax

_LEAF = "__repro_leaf__"


def _key_hash(key: str) -> str:
    return hashlib.sha256(key.encode()).hexdigest()[:24]


@dataclass
class ArtifactRecord:
    key: str
    nbytes_raw: int
    nbytes_disk: int
    save_s: float
    load_s: float | None = None
    n_loads: int = 0
    created_at: float = field(default_factory=time.time)


@dataclass
class PutResult:
    key: str
    nbytes_raw: int
    nbytes_disk: int
    seconds: float
    deduped: bool = False


class IntermediateStore:
    """Content-addressed artifact store with per-shard blobs."""

    def __init__(self, root: str | Path, compression_level: int = 3) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._cctx = zstd.ZstdCompressor(level=compression_level)
        self._dctx = zstd.ZstdDecompressor()
        self.records: dict[str, ArtifactRecord] = {}
        self._load_index()

    # -- index persistence -------------------------------------------------
    @property
    def _index_path(self) -> Path:
        return self.root / "index.json"

    def _load_index(self) -> None:
        if self._index_path.exists():
            raw = json.loads(self._index_path.read_text())
            for k, v in raw.items():
                self.records[k] = ArtifactRecord(**v)

    def _flush_index(self) -> None:
        self._index_path.write_text(
            json.dumps({k: vars(v) for k, v in self.records.items()})
        )

    # -- helpers -------------------------------------------------------------
    def _obj_dir(self, key: str) -> Path:
        h = _key_hash(key)
        return self.root / "objects" / h[:2] / h

    def has(self, key: str) -> bool:
        return key in self.records and self._obj_dir(key).exists()

    def _write_blob(self, path: Path, arr: np.ndarray) -> int:
        # raw bytes + manifest-recorded dtype/shape: survives ml_dtypes
        # (bfloat16 etc.) that the npy format would degrade to void types
        blob = self._cctx.compress(np.ascontiguousarray(arr).tobytes())
        path.write_bytes(blob)
        return len(blob)

    def _read_blob(self, path: Path, dtype: str, shape: list[int]) -> np.ndarray:
        raw = self._dctx.decompress(path.read_bytes())
        return np.frombuffer(raw, dtype=np.dtype(dtype)).reshape(shape)

    # -- public API ----------------------------------------------------------
    def put(self, key: str, value: Any) -> PutResult:
        if self.has(key):
            rec = self.records[key]
            return PutResult(key, rec.nbytes_raw, rec.nbytes_disk, 0.0, deduped=True)
        t0 = time.perf_counter()
        d = self._obj_dir(key)
        d.mkdir(parents=True, exist_ok=True)

        leaves, treedef = jax.tree_util.tree_flatten(value)
        manifest: dict[str, Any] = {"key": key, "leaves": []}
        nbytes_raw = 0
        nbytes_disk = 0
        for i, leaf in enumerate(leaves):
            entry: dict[str, Any] = {"index": i}
            if isinstance(leaf, jax.Array) and len(leaf.addressable_shards) > 1:
                # one blob per local shard: each host writes only its shards
                entry["kind"] = "sharded"
                entry["shape"] = list(leaf.shape)
                entry["dtype"] = str(leaf.dtype)
                entry["shards"] = []
                for s in leaf.addressable_shards:
                    arr = np.asarray(s.data)
                    p = d / f"leaf{i}.shard{s.device.id}.npy.zst"
                    nbytes_disk += self._write_blob(p, arr)
                    nbytes_raw += arr.nbytes
                    entry["shards"].append(
                        {
                            "device": s.device.id,
                            "index": [[sl.start, sl.stop] for sl in s.index],
                            "shape": list(arr.shape),
                            "file": p.name,
                        }
                    )
            else:
                arr = np.asarray(leaf)
                entry["kind"] = "dense"
                entry["shape"] = list(arr.shape)
                entry["dtype"] = str(arr.dtype)
                p = d / f"leaf{i}.npy.zst"
                nbytes_disk += self._write_blob(p, arr)
                nbytes_raw += arr.nbytes
                entry["file"] = p.name
            manifest["leaves"].append(entry)

        (d / "skeleton.pkl").write_bytes(pickle.dumps(treedef))
        (d / "manifest.json").write_text(json.dumps(manifest))
        dt = time.perf_counter() - t0
        self.records[key] = ArtifactRecord(key, nbytes_raw, nbytes_disk, dt)
        self._flush_index()
        return PutResult(key, nbytes_raw, nbytes_disk, dt)

    def get(self, key: str, sharding: jax.sharding.Sharding | None = None) -> Any:
        if not self.has(key):
            raise KeyError(key)
        t0 = time.perf_counter()
        d = self._obj_dir(key)
        manifest = json.loads((d / "manifest.json").read_text())
        treedef = pickle.loads((d / "skeleton.pkl").read_bytes())
        leaves = []
        for entry in manifest["leaves"]:
            if entry["kind"] == "sharded":
                out = np.empty(entry["shape"], dtype=np.dtype(entry["dtype"]))
                for s in entry["shards"]:
                    idx = tuple(slice(a, b) for a, b in s["index"])
                    out[idx] = self._read_blob(d / s["file"], entry["dtype"], s["shape"])
                arr = out
            else:
                arr = self._read_blob(d / entry["file"], entry["dtype"], entry["shape"])
            if sharding is not None:
                leaves.append(jax.device_put(arr, sharding))
            else:
                leaves.append(jax.numpy.asarray(arr))
        value = jax.tree_util.tree_unflatten(treedef, leaves)
        dt = time.perf_counter() - t0
        rec = self.records[key]
        rec.load_s = dt
        rec.n_loads += 1
        return value

    def delete(self, key: str) -> None:
        if key in self.records:
            d = self._obj_dir(key)
            if d.exists():
                for p in d.iterdir():
                    p.unlink()
                d.rmdir()
            del self.records[key]
            self._flush_index()

    # -- accounting ----------------------------------------------------------
    @property
    def total_disk_bytes(self) -> int:
        return sum(r.nbytes_disk for r in self.records.values())

    @property
    def total_raw_bytes(self) -> int:
        return sum(r.nbytes_raw for r in self.records.values())

    def save_throughput(self) -> float:
        """Mean observed store bandwidth (raw bytes/s) for T1 estimation."""
        pairs = [(r.nbytes_raw, r.save_s) for r in self.records.values() if r.save_s > 0]
        if not pairs:
            return 1e9
        tot_b = sum(b for b, _ in pairs)
        tot_s = sum(s for _, s in pairs)
        return tot_b / max(tot_s, 1e-9)

    def load_throughput(self) -> float:
        pairs = [
            (r.nbytes_raw, r.load_s)
            for r in self.records.values()
            if r.load_s and r.load_s > 0
        ]
        if not pairs:
            return self.save_throughput() * 2.0
        tot_b = sum(b for b, _ in pairs)
        tot_s = sum(s for _, s in pairs)
        return tot_b / max(tot_s, 1e-9)
