"""Galaxy-calibrated synthetic workflow-history generator.

The thesis mined 508 (Ch. 4) / 534 (Ch. 5) real Galaxy workflows; those JSONs
are not redistributable here, so this generator produces histories matched to
the published corpus-level statistics:

  * ~7165 intermediate states over 508 pipelines -> mean length ~ 14.1
  * TSFR stores 457/508 finals                   -> ~10% exact-duplicate reruns
  * PT stores ~49 results reused ~5.4x each      -> heavy per-dataset protocol
    sharing: pipelines on a dataset start from a small set of standard
    "protocol stems" (FastQC -> trim -> align ...) and diverge in the tail.

Generative model: datasets with Zipf popularity; each dataset owns 1-3
protocol templates; a new pipeline on dataset d either (a) exactly re-runs a
previous pipeline, or (b) keeps a (usually full) prefix of a template and
regenerates the suffix — the thesis' "users frequently run similar workflows
by changing only a few modules".  The adaptive variant attaches per-module
tool states and perturbs them with a small probability (Ch. 5: state
mismatches reduce reuse from ~52% to ~40%).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .workflow import ModuleRef, ToolState, Workflow


@dataclass
class CorpusSpec:
    n_workflows: int = 508
    n_datasets: int = 26
    zipf_a: float = 1.15
    n_modules: int = 220  # Galaxy tool vocabulary scale
    mean_len: float = 14.1
    min_len: int = 3
    stem_frac: float = 0.62  # fraction of a pipeline that is protocol stem
    p_dup: float = 0.11  # exact re-run of a previous pipeline on same dataset
    p_fresh: float = 0.26  # completely novel pipeline (no protocol template)
    p_partial_stem: float = 0.25  # chance of truncating the stem
    templates_per_dataset: tuple[int, int] = (1, 3)
    # adaptive variant:
    with_state: bool = False
    states_per_module: int = 3
    p_state_perturb: float = 0.3  # chance a pipeline perturbs one stem state
    seed: int = 0


class _Gen:
    def __init__(self, spec: CorpusSpec):
        self.spec = spec
        self.rng = np.random.default_rng(spec.seed)
        self.templates: dict[str, list[list[ModuleRef]]] = {}
        self.history: dict[str, list[list[ModuleRef]]] = {}

    def _state(self, module: int) -> ToolState:
        if not self.spec.with_state:
            return ToolState()
        s = int(min(self.rng.geometric(0.6) - 1, self.spec.states_per_module - 1))
        return ToolState.from_config({"cfg": f"m{module}s{s}"})

    def _chain(self, n: int) -> list[ModuleRef]:
        # first-order walk over the tool vocabulary (tools cluster into stages)
        cur = int(self.rng.integers(self.spec.n_modules))
        out = []
        for _ in range(n):
            out.append(ModuleRef(f"M{cur}", self._state(cur)))
            cur = (cur + int(self.rng.integers(1, 6))) % self.spec.n_modules
        return out

    def _length(self) -> int:
        s = self.spec
        return max(s.min_len, int(self.rng.poisson(s.mean_len - s.min_len)) + s.min_len)

    def _dataset_templates(self, d: str) -> list[list[ModuleRef]]:
        if d not in self.templates:
            lo, hi = self.spec.templates_per_dataset
            k = int(self.rng.integers(lo, hi + 1))
            stem_len = max(2, int(round(self.spec.mean_len * self.spec.stem_frac)))
            self.templates[d] = [self._chain(stem_len) for _ in range(k)]
        return self.templates[d]

    def _perturb_states(self, mods: list[ModuleRef]) -> list[ModuleRef]:
        if not self.spec.with_state or not mods:
            return mods
        if self.rng.random() < self.spec.p_state_perturb:
            i = int(self.rng.integers(len(mods)))
            mods = list(mods)
            mid = int(mods[i].module_id[1:])
            mods[i] = ModuleRef(mods[i].module_id, self._state(mid + 7))
        return mods

    def pipeline(self, d: str) -> list[ModuleRef]:
        hist = self.history.setdefault(d, [])
        r = self.rng.random()
        if hist and r < self.spec.p_dup:
            mods = list(hist[int(self.rng.integers(len(hist)))])
        elif r < self.spec.p_dup + self.spec.p_fresh:
            mods = self._chain(self._length())
        else:
            templates = self._dataset_templates(d)
            # skew toward the dataset's primary protocol
            w = np.asarray([2.0**-i for i in range(len(templates))])
            t = templates[int(self.rng.choice(len(templates), p=w / w.sum()))]
            keep = len(t)
            if self.rng.random() < self.spec.p_partial_stem:
                keep = int(self.rng.integers(1, len(t) + 1))
            mods = list(t[:keep])
            n_suffix = max(1, self._length() - keep)
            mods = mods + self._chain(n_suffix)
            mods = self._perturb_states(mods)
        hist.append(mods)
        return mods


def generate_corpus(spec: CorpusSpec | None = None, **overrides) -> list[Workflow]:
    if spec is None:
        spec = CorpusSpec(**overrides)
    elif overrides:
        raise ValueError("pass either spec or overrides, not both")
    gen = _Gen(spec)
    rng = gen.rng

    ranks = np.arange(1, spec.n_datasets + 1, dtype=np.float64)
    probs = ranks ** (-spec.zipf_a)
    probs /= probs.sum()

    corpus: list[Workflow] = []
    for i in range(spec.n_workflows):
        d = f"D{int(rng.choice(spec.n_datasets, p=probs))}"
        mods = gen.pipeline(d)
        corpus.append(Workflow(d, tuple(mods), workflow_id=f"W{i}"))
    return corpus


def galaxy_ch4_corpus(seed: int = 0) -> list[Workflow]:
    """~508 pipelines, no tool states (thesis Ch. 4 setting)."""
    return generate_corpus(CorpusSpec(seed=seed))


def galaxy_ch5_corpus(seed: int = 0) -> list[Workflow]:
    """~534 pipelines with per-module tool states (thesis Ch. 5 setting)."""
    return generate_corpus(
        CorpusSpec(
            n_workflows=534,
            mean_len=15.9,
            with_state=True,
            p_state_perturb=0.5,
            seed=seed,
        )
    )
