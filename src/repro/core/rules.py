"""Association-rule mining over workflow histories (thesis Ch. 4.3 / 5.2).

A rule is ``D => [M1..Mk]`` — "workflows on dataset D tend to start with the
module sequence M1..Mk".

    support(D => prefix) = number of pipelines in history generating the rule
    support(D)           = number of pipelines using dataset D
    confidence           = support(D => prefix) / support(D)

The miner is incremental: feeding pipelines one at a time matches the thesis'
replay protocol ("while examining the n-th pipeline ... analyzes association
rules from the previous n-1 pipelines").
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass

from .workflow import PrefixKey, Workflow


@dataclass(frozen=True)
class Rule:
    prefix: PrefixKey
    support: int
    dataset_support: int

    @property
    def confidence(self) -> float:
        return self.support / self.dataset_support if self.dataset_support else 0.0

    @property
    def depth(self) -> int:
        return self.prefix.depth


class RuleMiner:
    """Incremental support/confidence bookkeeping.

    ``with_state=True`` gives the adaptive (tool-state-aware) variant: rules
    only match when every module in the prefix has an identical parameter
    configuration (Ch. 5 example: M3 run with C3' does not extend the
    M1,M2,M3 rule mined from runs with C3).
    """

    def __init__(self, with_state: bool = False) -> None:
        self.with_state = with_state
        self._prefix_support: dict[str, int] = defaultdict(int)
        self._dataset_support: dict[str, int] = defaultdict(int)
        self._prefix_by_key: dict[str, PrefixKey] = {}
        self.n_pipelines = 0

    # -- updates ---------------------------------------------------------
    def add(self, wf: Workflow) -> None:
        self.n_pipelines += 1
        self._dataset_support[wf.dataset_id] += 1
        for prefix in wf.prefixes():
            key = prefix.key(self.with_state)
            self._prefix_support[key] += 1
            self._prefix_by_key.setdefault(key, prefix)

    # -- queries ---------------------------------------------------------
    def support(self, prefix: PrefixKey) -> int:
        return self._prefix_support.get(prefix.key(self.with_state), 0)

    def support_of_key(self, key: str) -> int:
        """Support by pre-rendered prefix key (the recommender's hot path)."""
        return self._prefix_support.get(key, 0)

    def iter_prefixes(self):
        """All distinct mined prefixes (one per rule)."""
        return iter(self._prefix_by_key.values())

    def dataset_support(self, dataset_id: str) -> int:
        return self._dataset_support.get(dataset_id, 0)

    def rule(self, prefix: PrefixKey) -> Rule:
        return Rule(
            prefix=prefix,
            support=self.support(prefix),
            dataset_support=self.dataset_support(prefix.dataset_id),
        )

    def rules_for(self, wf: Workflow) -> list[Rule]:
        """All rules derivable from ``wf`` with current history counts."""
        return [self.rule(p) for p in wf.prefixes()]

    def distinct_rules(self) -> list[Rule]:
        out = []
        for key, prefix in self._prefix_by_key.items():
            out.append(
                Rule(
                    prefix=prefix,
                    support=self._prefix_support[key],
                    dataset_support=self._dataset_support[prefix.dataset_id],
                )
            )
        return out

    @property
    def n_distinct_rules(self) -> int:
        return len(self._prefix_by_key)
