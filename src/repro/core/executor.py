"""Prefix-skipping workflow executor with RISP-guided storing + error recovery.

Execution of a pipeline ``D -> M1 -> ... -> Mn``:

 1. Ask the storage policy for the longest previously-stored prefix whose
    artifact is still present in the store; load it and skip those modules
    (thesis Ch. 3: "skipping procedure ... increases the flexibility and
    reusability to analyze fractions of pipelines in low cost").
 2. Execute the remaining modules, timing each (block_until_ready).
 3. Store whatever the policy admits — optionally gated by the Eq. 4.9 cost
    test (admission="t1_gt_t2").
 4. On module failure, persist the last good intermediate state so a resumed
    run restarts at the failure point (thesis Ch. 3.5.2 error recovery).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import jax

from ..obs import tracing as _tracing
from .backends import BackendUnavailable
from .cost import CostModel
from .provenance import ProvenanceLog, RunRecord
from .registry import ModuleRegistry
from .risp import Recommendation, StoragePolicy, StoredRecord
from .store import IntermediateStore
from .workflow import ModuleRef, ModuleSpec, PrefixKey, Workflow


class WorkflowError(RuntimeError):
    def __init__(self, message: str, workflow: Workflow, failed_at: int, cause: Exception):
        super().__init__(message)
        self.workflow = workflow
        self.failed_at = failed_at  # 0-based module index that failed
        self.cause = cause


@dataclass
class RunResult:
    output: Any
    workflow: Workflow
    module_seconds: list[float]
    reused_prefix: PrefixKey | None
    load_seconds: float
    stored_keys: list[str]
    store_seconds: float
    total_seconds: float
    n_skipped: int
    recovered_from_depth: int = 0

    @property
    def exec_seconds(self) -> float:
        return sum(self.module_seconds)


def _nbytes(value: Any) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(value):
        total += getattr(leaf, "nbytes", 0) or 0
    return int(total)


# -- shared helpers (sequential executor + repro.sched.DagScheduler) ----------
def probe_reusable_prefix(
    store: IntermediateStore,
    policy: StoragePolicy,
    candidate: PrefixKey | None,
    keep: frozenset[str] | set[str] = frozenset(),
) -> tuple[PrefixKey | None, Any, float]:
    """Load the longest stored prefix at-or-below ``candidate``.

    Walks parents of ``candidate`` until one has a live artifact; stale
    policy bookkeeping for evicted prefixes is dropped along the way —
    except keys in ``keep``: the caller's *planned* stores for the current
    run, which legitimately have no artifact yet.
    Returns ``(prefix, value, load_seconds)`` — ``(None, None, 0.0)`` when
    nothing is reusable.

    The whole parent chain is probed in ONE batched presence round trip
    (``store.has_state_many``) instead of one per link: a depth-d chain
    against a remote pool used to cost d round trips before the first byte
    of a reusable artifact moved.
    """
    chain: list[tuple[PrefixKey, str]] = []
    node = candidate
    while node is not None:
        chain.append((node, node.key(policy.with_state)))
        node = node.parent()
    sp = _tracing.span("probe.prefix", kind="probe", depth=len(chain))
    with sp:
        states = store.has_state_many([key for _, key in chain]) if chain else {}
        sp.set(present=sum(1 for s in states.values() if s == "present"))
    for candidate, key in chain:
        state = states.get(key, "unreachable")
        if state == "present":
            t0 = time.perf_counter()
            try:
                value = store.get(key)
            except KeyError:  # evicted between the batched probe and get()
                policy.stored.pop(key, None)
                continue
            except BackendUnavailable:
                # shard(s) holding it died between the probe and get(): the
                # bytes may survive, so keep bookkeeping, try a shorter prefix
                continue
            return candidate, value, time.perf_counter() - t0
        # artifact evicted: drop stale bookkeeping, try shorter prefix —
        # but only on authoritative absence; an unreachable artifact keeps
        # its bookkeeping (the bytes are still out there)
        if state == "absent" and key not in keep:
            policy.stored.pop(key, None)
    return None, None, 0.0


def admit_and_store(
    store: IntermediateStore,
    policy: StoragePolicy,
    cost_model: CostModel,
    admission: str,
    prefix: PrefixKey,
    value: Any,
    measured_exec_s: float | None,
    catalog: Any = None,
) -> tuple[str | None, float]:
    """Run one policy-recommended store through cost gating + budget admission.

    Returns ``(key, seconds)`` with ``key=None`` when the Eq. 4.9 gate or the
    store budget rejected the artifact (policy bookkeeping is cleaned up so it
    is never recommended for reuse).

    ``catalog`` (a :class:`repro.catalog.Catalog`, duck-typed to keep the
    core layer free of upward imports) is the provenance index's admission
    hook: this is the only seam that still holds the structured ``prefix``
    the flat store key was rendered from, so the publish happens here —
    after ``put`` returns, never under the store lock.
    """
    key = prefix.key(policy.with_state)
    if admission == "t1_gt_t2" and not cost_model.should_store(prefix, measured_exec_s):
        policy.stored.pop(key, None)
        return None, 0.0
    res = store.put(
        key,
        value,
        compute_seconds=cost_model.recompute_seconds(prefix, measured_exec_s),
    )
    if not res.admitted:  # artifact exceeds the whole store budget: never stored
        policy.stored.pop(key, None)
        return None, res.seconds
    if catalog is not None:
        catalog.publish(prefix, key, store.records.get(key))
    return key, res.seconds


@dataclass
class WorkflowExecutor:
    """Sequential front door.  ``registry`` is the shared
    :class:`~repro.core.registry.ModuleRegistry`; a plain dict is adopted by
    reference for backward compatibility.  New code should construct engines
    through :class:`repro.api.Client`, which wires one registry + store +
    policy across the sequential executor and the DAG scheduler."""

    store: IntermediateStore
    policy: StoragePolicy
    registry: ModuleRegistry = field(default_factory=ModuleRegistry)
    admission: str = "always"  # "always" | "t1_gt_t2"
    provenance: ProvenanceLog | None = None
    cost_model: CostModel | None = None
    catalog: Any = None  # optional repro.catalog.Catalog (duck-typed)

    def __post_init__(self) -> None:
        if not isinstance(self.registry, ModuleRegistry):
            self.registry = ModuleRegistry(self.registry)
        if self.cost_model is None:
            self.cost_model = CostModel(store=self.store)
        if self.admission not in ("always", "t1_gt_t2"):
            raise ValueError(f"unknown admission mode {self.admission!r}")
        # budget evictions must also clear the policy's stored-key map, or the
        # policy would keep recommending reuse of artifacts that are gone
        self.store.add_evict_listener(self._on_store_evict)

    def _on_store_evict(self, key: str) -> None:
        self.policy.stored.pop(key, None)
        # runs under the store lock: Catalog.discard is in-memory only
        if self.catalog is not None:
            self.catalog.discard(key)

    # -- registration (delegates to the shared registry) ----------------------
    def register(self, spec: ModuleSpec) -> None:
        self.registry.register(spec)

    def register_fn(self, module_id: str, fn, **default_params) -> None:
        self.registry.register_fn(module_id, fn, **default_params)

    # -- workflow construction -------------------------------------------------
    def make_workflow(
        self,
        dataset_id: str,
        steps: Sequence[str | tuple[str, Mapping[str, Any] | None]],
        workflow_id: str = "",
    ) -> Workflow:
        refs = []
        for step in steps:
            if isinstance(step, str):
                mod, params = step, None
            else:
                mod, params = step
            spec = self.registry[mod]
            refs.append(spec.ref(params))
        return Workflow(dataset_id, tuple(refs), workflow_id)

    # -- execution --------------------------------------------------------------
    def run(
        self,
        dataset_id: str,
        data: Any,
        steps: Sequence[str | tuple[str, Mapping[str, Any] | None]],
        workflow_id: str = "",
    ) -> RunResult:
        wf = self.make_workflow(dataset_id, steps, workflow_id)
        return self.run_workflow(wf, data)

    def _params_for(self, ref: ModuleRef) -> dict[str, Any]:
        return self.registry.resolve_params(ref)

    def run_workflow(self, wf: Workflow, data: Any) -> RunResult:
        with _tracing.span(
            "run", kind="run", workflow=wf.workflow_id or wf.dataset_id
        ) as run_sp:
            result = self._run_workflow_traced(wf, data)
            run_sp.set(n_skipped=result.n_skipped, stored=len(result.stored_keys))
        return result

    def _run_workflow_traced(self, wf: Workflow, data: Any) -> RunResult:
        t_start = time.perf_counter()
        rec: Recommendation = self.policy.step(wf)

        # 1) reuse the longest stored prefix whose artifact still exists.
        # Probe from the FULL chain, not just the policy's recommendation:
        # the store may hold prefixes this policy instance never admitted —
        # another process/engine sharing the (possibly remote) store put
        # them there, and content-addressed keys make them interchangeable.
        # Cost: up to len(wf) presence probes per run (file stats locally,
        # ~ms round trips remotely) — presence must stay authoritative, and
        # any cheaper hint (records / shared index) would miss exactly the
        # cross-process artifacts this probe exists to find.
        candidate = wf.prefix(len(wf)) if len(wf) else None
        planned = {p.key(self.policy.with_state) for p in rec.store}
        reused, loaded, load_s = probe_reusable_prefix(
            self.store, self.policy, candidate, keep=planned
        )
        if reused is not None:
            # adopt the fact into local bookkeeping so later planning
            # (and eviction listeners) see what we just relied on
            reused_key = reused.key(self.policy.with_state)
            self.policy.stored.setdefault(
                reused_key, StoredRecord(reused, self.policy.n_pipelines)
            )
            if self.catalog is not None:  # refresh reuse counters for ranking
                self.catalog.touch(reused_key, self.store.records.get(reused_key))
        start_idx = reused.depth if reused is not None else 0
        value = loaded if reused is not None else data

        # 2) execute the suffix, retaining stage outputs for storing
        module_seconds = [0.0] * len(wf)
        stage_values: dict[int, Any] = {}  # depth -> value (1-based)
        failed_at: int | None = None
        for i in range(start_idx, len(wf)):
            ref = wf.modules[i]
            spec = self.registry[ref.module_id]
            params = self._params_for(ref)
            t0 = time.perf_counter()
            try:
                value = spec.fn(value, **params)
                value = jax.block_until_ready(value)
            except Exception as e:  # noqa: BLE001 - module code is user code
                failed_at = i
                self._persist_recovery_point(wf, i, stage_values, reused)
                raise WorkflowError(
                    f"module {ref.module_id} failed at step {i}: {e}", wf, i, e
                ) from e
            dt = time.perf_counter() - t0
            module_seconds[i] = dt
            assert self.cost_model is not None
            self.cost_model.observe(ref, dt, _nbytes(value))
            stage_values[i + 1] = value

        # 3) store what the policy admitted (cost-gated if requested)
        stored_keys: list[str] = []
        store_s = 0.0
        assert self.cost_model is not None
        for prefix in rec.store:
            depth = prefix.depth
            if depth not in stage_values:
                # inside the skipped prefix: normally stored by an earlier run,
                # but a budget eviction may have dropped it while a deeper
                # prefix survived — don't let the policy believe it exists.
                # Authoritative absence only: unreachable shards are not
                # evidence of eviction (see has_state)
                key = prefix.key(self.policy.with_state)
                if self.store.has_state(key) == "absent":
                    self.policy.stored.pop(key, None)
                continue
            key, dt = admit_and_store(
                self.store,
                self.policy,
                self.cost_model,
                self.admission,
                prefix,
                stage_values[depth],
                sum(module_seconds[:depth]) or None,
                catalog=self.catalog,
            )
            store_s += dt
            if key is not None:
                stored_keys.append(key)

        total = time.perf_counter() - t_start
        result = RunResult(
            output=value,
            workflow=wf,
            module_seconds=module_seconds,
            reused_prefix=reused,
            load_seconds=load_s,
            stored_keys=stored_keys,
            store_seconds=store_s,
            total_seconds=total,
            n_skipped=start_idx,
        )
        if self.provenance is not None:
            n_requests = (len(wf) - start_idx) + len(stored_keys) + (1 if reused else 0)
            self.provenance.append(
                RunRecord(
                    workflow_id=wf.workflow_id,
                    dataset_id=wf.dataset_id,
                    modules=[m.key(True) for m in wf.modules],
                    module_seconds=module_seconds,
                    reused_prefix_depth=start_idx,
                    load_seconds=load_s,
                    stored_keys=stored_keys,
                    store_seconds=store_s,
                    total_seconds=total,
                    n_requests=n_requests,
                    failed_at=failed_at,
                    recovered_from_depth=start_idx if reused else 0,
                )
            )
        return result

    # -- error recovery -----------------------------------------------------------
    def _persist_recovery_point(
        self,
        wf: Workflow,
        failed_idx: int,
        stage_values: dict[int, Any],
        reused: PrefixKey | None,
    ) -> None:
        """Store the last good intermediate state so a retry skips to it."""
        depth = failed_idx  # output of module failed_idx-1 has depth failed_idx
        if depth in stage_values:
            prefix = wf.prefix(depth)
            key = prefix.key(self.policy.with_state)
            state = self.store.has_state(key)
            if state == "unreachable":
                # the pool is gone: a put would fail (masking the module
                # error being recovered), and claiming the prefix as stored
                # without bytes anywhere would be a phantom — skip both
                return
            if state == "absent":
                self.store.put(key, stage_values[depth])
                if self.catalog is not None:
                    self.catalog.publish(prefix, key, self.store.records.get(key))
            self.policy.stored.setdefault(
                key, StoredRecord(prefix, self.policy.n_pipelines)
            )
        # if nothing was computed yet, the reused prefix (if any) already covers it


def eval_repr(v: str) -> Any:
    """Deprecated inverse of the ``repr()`` encoding old ``ToolState``s used.

    ``ToolState.from_config`` now renders params through the canonical,
    invertible :func:`repro.core.workflow.encode_param`; decode with
    :func:`repro.core.workflow.decode_param`, which still falls back to this
    literal-eval behaviour for legacy repr-encoded params.  Kept only so
    persisted pre-canonical states (and external callers) keep resolving.
    """
    import ast

    try:
        return ast.literal_eval(v)
    except (ValueError, SyntaxError):
        return v
