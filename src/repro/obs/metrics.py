"""Thread-safe metrics registry — the one home for every counter in the fabric.

Three instrument kinds, all label-aware:

- :class:`Counter` — monotonic float, ``inc(n)``.
- :class:`Gauge` — settable value, or a callback sampled at collection time
  (``set_function``) so "current bytes"/"pending runs" never go stale.
- :class:`Histogram` — **fixed log buckets** (half-decade steps, 10 µs → 31.6 s
  by default).  Fixed bounds make histograms *mergeable*: two processes'
  bucket-count vectors add element-wise, which is what lets
  ``ShardedBackend`` fold N shards' ``metrics`` docs into one cluster view.

A registry serializes to a JSON-able *doc* (:meth:`MetricsRegistry.to_doc`)
that travels over the wire as the ``metrics`` op reply; :func:`merge_docs`
combines docs (optionally stamping each with an extra label such as
``shard=host:port``), and :func:`render_prometheus` renders a doc in the
Prometheus text exposition format for the gateway's ``GET /metrics``.

Naming scheme (enforced by :func:`lint_registry` and a tier-1 lint test):
``repro_<subsystem>_<what>[_unit]``; counters end in ``_total``; label names
come from the small fixed vocabulary below so dashboards can join across
subsystems.
"""
from __future__ import annotations

import json
import math
import re
import threading
from bisect import bisect_left
from typing import Any, Callable, Iterable, Mapping

__all__ = [
    "ALLOWED_LABELS",
    "DEFAULT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "lint_doc",
    "lint_registry",
    "merge_docs",
    "render_prometheus",
]

_NAME_RE = re.compile(r"^repro(_[a-z0-9]+)+$")
_LABEL_RE = re.compile(r"^[a-z][a-z0-9_]*$")

#: label vocabulary shared by every subsystem — new labels are a deliberate
#: API decision, not a drive-by (the lint test fails on anything else)
ALLOWED_LABELS = frozenset(
    {"op", "shard", "tenant", "namespace", "dir", "status", "source", "event", "policy"}
)

#: half-decade log buckets, 1e-5 s .. 31.6 s (rounded so bounds are stable
#: dict keys across processes — a merge requires *identical* bounds)
DEFAULT_BUCKETS: tuple[float, ...] = tuple(
    float(f"{10.0 ** (k / 2.0):.6g}") for k in range(-10, 4)
)


def _labels_key(labelnames: tuple[str, ...], kw: Mapping[str, str]) -> tuple[str, ...]:
    if set(kw) != set(labelnames):
        raise ValueError(f"expected labels {labelnames}, got {tuple(kw)}")
    return tuple(str(kw[k]) for k in labelnames)


class Counter:
    """Monotonic counter child (one label combination)."""

    __slots__ = ("_lock", "_v")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._v = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        v = self._v
        return int(v) if float(v).is_integer() else v


class Gauge:
    """Settable gauge child; ``set_function`` makes it a live callback."""

    __slots__ = ("_lock", "_v", "_fn")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._v = 0.0
        self._fn: Callable[[], float] | None = None

    def set(self, v: float) -> None:
        with self._lock:
            self._v = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._v += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    def set_function(self, fn: Callable[[], float]) -> None:
        self._fn = fn

    @property
    def value(self) -> float:
        if self._fn is not None:
            try:
                v = float(self._fn())
            except Exception:  # noqa: BLE001 — a dead callback must not kill a scrape
                v = float("nan")
        else:
            v = self._v
        return int(v) if v == v and float(v).is_integer() else v


class Histogram:
    """Fixed-bucket latency histogram child (cumulative on render, raw
    per-bucket counts internally so merging is element-wise addition)."""

    __slots__ = ("_lock", "bounds", "counts", "sum", "count")

    def __init__(self, bounds: tuple[float, ...]) -> None:
        self._lock = threading.Lock()
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # last slot = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        idx = bisect_left(self.bounds, v)
        with self._lock:
            self.counts[idx] += 1
            self.sum += v
            self.count += 1

    def snapshot(self) -> dict[str, Any]:
        with self._lock:
            return {"counts": list(self.counts), "sum": self.sum, "count": self.count}


class _Family:
    """A named metric plus its labeled children."""

    def __init__(
        self,
        name: str,
        kind: str,
        help: str,
        labelnames: tuple[str, ...],
        factory: Callable[[], Any],
    ) -> None:
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = labelnames
        self._factory = factory
        self._lock = threading.Lock()
        self._children: dict[tuple[str, ...], Any] = {}
        if not labelnames:  # pre-create the unlabeled child for hot paths
            self._children[()] = factory()

    def labels(self, **kw: str) -> Any:
        key = _labels_key(self.labelnames, kw)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._factory())
        return child

    @property
    def unlabeled(self) -> Any:
        return self._children[()]

    # convenience pass-throughs for label-free hot paths
    def inc(self, n: float = 1.0) -> None:
        self._children[()].inc(n)

    def observe(self, v: float) -> None:
        self._children[()].observe(v)

    @property
    def value(self) -> float:
        return self._children[()].value

    def series(self) -> list[dict[str, Any]]:
        with self._lock:
            items = list(self._children.items())
        out = []
        for key, child in items:
            labels = dict(zip(self.labelnames, key))
            if self.kind == "histogram":
                out.append({"labels": labels, "hist": child.snapshot()})
            else:
                v = child.value
                out.append({"labels": labels, "value": None if v != v else v})
        return out


class MetricsRegistry:
    """Thread-safe family registry.  Re-registering an existing name returns
    the existing family (so components sharing one registry compose) but a
    kind/label mismatch raises — one name, one meaning."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def _register(
        self,
        name: str,
        kind: str,
        help: str,
        labels: Iterable[str],
        factory: Callable[[], Any],
    ) -> _Family:
        labelnames = tuple(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name!r} already registered as {fam.kind}"
                        f"{fam.labelnames}, not {kind}{labelnames}"
                    )
                return fam
            fam = _Family(name, kind, help, labelnames, factory)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "", labels: Iterable[str] = ()) -> _Family:
        return self._register(name, "counter", help, labels, Counter)

    def gauge(self, name: str, help: str = "", labels: Iterable[str] = ()) -> _Family:
        return self._register(name, "gauge", help, labels, Gauge)

    def histogram(
        self,
        name: str,
        help: str = "",
        labels: Iterable[str] = (),
        buckets: tuple[float, ...] = DEFAULT_BUCKETS,
    ) -> _Family:
        bounds = tuple(buckets)
        fam = self._register(name, "histogram", help, labels, lambda: Histogram(bounds))
        fam.buckets = bounds
        return fam

    def families(self) -> list[_Family]:
        with self._lock:
            return list(self._families.values())

    def to_doc(self) -> dict[str, Any]:
        """JSON-able snapshot — the wire shape of the ``metrics`` op."""
        doc: dict[str, Any] = {}
        for fam in self.families():
            entry: dict[str, Any] = {
                "type": fam.kind,
                "help": fam.help,
                "labels": list(fam.labelnames),
                "series": fam.series(),
            }
            if fam.kind == "histogram":
                entry["bounds"] = list(getattr(fam, "buckets", DEFAULT_BUCKETS))
            doc[fam.name] = entry
        return doc


def _merge_series(kind: str, into: list[dict[str, Any]], more: list[dict[str, Any]]) -> None:
    index = {json.dumps(s["labels"], sort_keys=True): s for s in into}
    for s in more:
        k = json.dumps(s["labels"], sort_keys=True)
        cur = index.get(k)
        if cur is None:
            index[k] = s
            into.append(s)
        elif kind == "histogram":
            a, b = cur["hist"], s["hist"]
            if len(a["counts"]) == len(b["counts"]):
                a["counts"] = [x + y for x, y in zip(a["counts"], b["counts"])]
                a["sum"] += b["sum"]
                a["count"] += b["count"]
        else:  # counters and gauges both add — gauges here are extensive
            # quantities (bytes, pending runs); per-shard gauges that are not
            # additive carry a distinguishing ``shard`` label and never collide
            if s.get("value") is not None:
                cur["value"] = (cur.get("value") or 0) + s["value"]


def merge_docs(
    docs: Iterable[dict[str, Any] | None],
    extra_labels: Iterable[Mapping[str, str] | None] | None = None,
) -> dict[str, Any]:
    """Merge metric docs from N processes into one cluster doc.

    ``extra_labels[i]`` (e.g. ``{"shard": "host:port"}``) is stamped onto
    every series of ``docs[i]`` first, so per-process series stay
    distinguishable and non-additive gauges never sum across shards.
    """
    merged: dict[str, Any] = {}
    extras = list(extra_labels) if extra_labels is not None else None
    for i, doc in enumerate(docs):
        if not doc:
            continue
        extra = extras[i] if extras else None
        for name, entry in doc.items():
            series = [
                {**s, "labels": {**s["labels"], **(extra or {})}} for s in entry["series"]
            ]
            cur = merged.get(name)
            if cur is None:
                cur = {k: v for k, v in entry.items() if k != "series"}
                if extra:
                    cur["labels"] = sorted(set(cur.get("labels", [])) | set(extra))
                cur["series"] = []
                merged[name] = cur
            _merge_series(entry["type"], cur["series"], series)
    return merged


def _fmt_labels(labels: Mapping[str, str], extra: Mapping[str, str] | None = None) -> str:
    items = {**labels, **(extra or {})}
    if not items:
        return ""
    body = ",".join(
        f'{k}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in sorted(items.items())
    )
    return "{" + body + "}"


def _fmt_value(v: Any) -> str:
    if v is None or v != v:
        return "NaN"
    if v in (math.inf, -math.inf):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v)) if not float(v).is_integer() else str(int(v))


def render_prometheus(doc: Mapping[str, Any]) -> str:
    """Render a metrics doc in the Prometheus text exposition format."""
    lines: list[str] = []
    for name in sorted(doc):
        entry = doc[name]
        if entry["help"]:
            lines.append(f"# HELP {name} {entry['help']}")
        lines.append(f"# TYPE {name} {entry['type']}")
        for s in entry["series"]:
            if entry["type"] == "histogram":
                hist = s["hist"]
                bounds = entry.get("bounds", list(DEFAULT_BUCKETS))
                cum = 0
                for i, le in enumerate(list(bounds) + [math.inf]):
                    cum += hist["counts"][i] if i < len(hist["counts"]) else 0
                    le_s = "+Inf" if le == math.inf else _fmt_value(le)
                    lines.append(
                        f"{name}_bucket{_fmt_labels(s['labels'], {'le': le_s})} {cum}"
                    )
                lines.append(f"{name}_sum{_fmt_labels(s['labels'])} {_fmt_value(hist['sum'])}")
                lines.append(f"{name}_count{_fmt_labels(s['labels'])} {hist['count']}")
            else:
                lines.append(f"{name}{_fmt_labels(s['labels'])} {_fmt_value(s.get('value'))}")
    return "\n".join(lines) + "\n"


def lint_doc(doc: Mapping[str, Any]) -> list[str]:
    """Return naming-scheme violations for a metrics doc (empty = clean)."""
    problems: list[str] = []
    for name, entry in doc.items():
        if not _NAME_RE.match(name):
            problems.append(f"{name}: name does not match {_NAME_RE.pattern}")
        if entry["type"] == "counter" and not name.endswith("_total"):
            problems.append(f"{name}: counter names must end in _total")
        if entry["type"] == "histogram" and not name.endswith(("_seconds", "_bytes")):
            problems.append(f"{name}: histogram names must end in _seconds/_bytes")
        if not entry.get("help"):
            problems.append(f"{name}: missing help text")
        for label in entry.get("labels", []):
            if label == "le" or not _LABEL_RE.match(label):
                problems.append(f"{name}: malformed label {label!r}")
            elif label not in ALLOWED_LABELS:
                problems.append(
                    f"{name}: label {label!r} not in ALLOWED_LABELS "
                    f"(extend the vocabulary deliberately if needed)"
                )
    return problems


def lint_registry(registry: MetricsRegistry) -> list[str]:
    return lint_doc(registry.to_doc())
