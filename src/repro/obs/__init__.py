"""repro.obs — unified observability for the workflow fabric.

One metrics home (:mod:`repro.obs.metrics`), cross-process run tracing
(:mod:`repro.obs.tracing`), structured logging (:mod:`repro.obs.logging`),
and a trace-tree CLI (``python -m repro.obs.trace``).  Everything is
stdlib-only so every layer of the fabric can depend on it without cycles.
"""
from __future__ import annotations

from .metrics import MetricsRegistry, lint_registry, merge_docs, render_prometheus
from .tracing import TraceContext, bind, configure_tracing, current_traceparent, span

__all__ = [
    "MetricsRegistry",
    "TraceContext",
    "bind",
    "configure_tracing",
    "current_traceparent",
    "lint_registry",
    "merge_docs",
    "render_prometheus",
    "span",
]
