"""Trace-tree CLI: stitch NDJSON spans from every process into one tree.

Usage::

    python -m repro.obs.trace --dir traces/ --list
    python -m repro.obs.trace --dir traces/ <trace_id>

Renders the span tree (service, duration, key attrs), marks the critical
path (the chain of spans that bounds the run's wall time) with ``*``, and
rolls up "seconds saved by reuse" — the per-run realization of the paper's
Ch. 4 time-gain claim: for every artifact served from the store instead of
recomputed, the saving is its recorded compute cost minus the load time.
"""
from __future__ import annotations

import argparse
import os
import sys
from collections import defaultdict
from typing import Any, Iterable

from .tracing import iter_spans

__all__ = ["build_trace", "critical_path", "render_trace", "main"]


def build_trace(spans: Iterable[dict[str, Any]], trace_id: str) -> dict[str, Any]:
    """Index one trace's spans: children map, roots, service set."""
    by_id: dict[str, dict[str, Any]] = {}
    for s in spans:
        if s.get("trace") == trace_id and s.get("span"):
            by_id[s["span"]] = s
    children: dict[str | None, list[dict[str, Any]]] = defaultdict(list)
    roots: list[dict[str, Any]] = []
    for s in by_id.values():
        parent = s.get("parent")
        if parent and parent in by_id:
            children[parent].append(s)
        else:
            roots.append(s)  # true root, or an orphan from a lost parent file
    for lst in children.values():
        lst.sort(key=lambda s: s.get("start", 0.0))
    roots.sort(key=lambda s: s.get("start", 0.0))
    processes = {(s.get("svc"), s.get("pid")) for s in by_id.values()}
    return {
        "trace_id": trace_id,
        "spans": by_id,
        "children": children,
        "roots": roots,
        "services": sorted({s.get("svc") or "?" for s in by_id.values()}),
        "processes": sorted(processes, key=str),
    }


def critical_path(tree: dict[str, Any]) -> list[str]:
    """Span ids on the critical path: from the root, repeatedly descend into
    the child that *finishes last* (the one the parent's end waits on)."""
    if not tree["roots"]:
        return []
    root = max(tree["roots"], key=lambda s: s.get("start", 0) + s.get("dur", 0))
    path = [root["span"]]
    node = root
    while True:
        kids = tree["children"].get(node["span"], [])
        if not kids:
            break
        node = max(kids, key=lambda s: s.get("start", 0) + s.get("dur", 0))
        path.append(node["span"])
    return path


def reuse_rollup(tree: dict[str, Any]) -> dict[str, float]:
    hits, saved = 0, 0.0
    for s in tree["spans"].values():
        attrs = s.get("attrs") or {}
        if "saved_s" in attrs:
            hits += 1
            saved += float(attrs["saved_s"] or 0.0)
    return {"reuse_hits": hits, "seconds_saved": round(saved, 6)}


_SHOWN_ATTRS = ("op", "node", "module", "source", "key", "tenant", "run_id", "saved_s", "error")


def _fmt_span(s: dict[str, Any], on_path: bool) -> str:
    attrs = s.get("attrs") or {}
    shown = " ".join(f"{k}={attrs[k]}" for k in _SHOWN_ATTRS if k in attrs)
    mark = "*" if on_path else " "
    dur_ms = (s.get("dur") or 0.0) * 1e3
    return f"{mark} {s.get('name')} [{s.get('svc')}/{s.get('pid')}] {dur_ms:.1f}ms {shown}".rstrip()


def render_trace(tree: dict[str, Any]) -> str:
    path = set(critical_path(tree))
    lines = [f"trace {tree['trace_id']}  ({len(tree['spans'])} spans, "
             f"{len(tree['processes'])} processes: {', '.join(tree['services'])})"]

    def walk(span: dict[str, Any], depth: int) -> None:
        lines.append("  " * depth + _fmt_span(span, span["span"] in path))
        for child in tree["children"].get(span["span"], []):
            walk(child, depth + 1)

    for root in tree["roots"]:
        walk(root, 1)
    roll = reuse_rollup(tree)
    if tree["roots"]:
        t0 = min(s.get("start", 0.0) for s in tree["spans"].values())
        t1 = max(s.get("start", 0.0) + s.get("dur", 0.0) for s in tree["spans"].values())
        lines.append(f"  wall: {(t1 - t0) * 1e3:.1f}ms  critical path: {len(path)} spans")
    lines.append(
        f"  reuse: {int(roll['reuse_hits'])} hits, {roll['seconds_saved']:.3f}s saved"
    )
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.trace", description=__doc__.splitlines()[0]
    )
    ap.add_argument("trace_id", nargs="?", help="trace to render (omit with --list)")
    ap.add_argument("--dir", default="traces", help="span NDJSON directory (default: traces/)")
    ap.add_argument("--list", action="store_true", help="list trace ids seen in --dir")
    args = ap.parse_args(argv)

    spans = list(iter_spans(args.dir))
    if args.list or not args.trace_id:
        seen: dict[str, dict[str, Any]] = {}
        for s in spans:
            t = s.get("trace")
            if not t:
                continue
            agg = seen.setdefault(t, {"n": 0, "start": s.get("start", 0.0), "name": ""})
            agg["n"] += 1
            if s.get("parent") is None or s.get("kind") == "run":
                agg["name"] = s.get("name", "")
        for t, agg in sorted(seen.items(), key=lambda kv: kv[1]["start"]):
            print(f"{t}  {agg['n']:4d} spans  {agg['name']}")
        if not seen:
            print(f"no spans under {args.dir!r}", file=sys.stderr)
            return 1
        return 0

    tree = build_trace(spans, args.trace_id)
    if not tree["spans"]:
        print(f"trace {args.trace_id} not found under {args.dir!r}", file=sys.stderr)
        return 1
    print(render_trace(tree))
    return 0


if __name__ == "__main__":
    try:
        raise SystemExit(main())
    except BrokenPipeError:  # e.g. `... | head` closed the pipe
        os.close(sys.stdout.fileno())
        raise SystemExit(0)
