"""The canonical metric-name map for the fabric's legacy stats surfaces.

Before ``repro.obs`` there were three divergent stats shapes —
``StoreServer.stats()``, ``ShardedBackend.server_stats()``, and
``GatewayServer.stats_doc()`` — each naming the same quantity differently
("requests" vs "ops", "singleflight_waits" under ``fabric`` but ``waits``
on the flight object).  The registry is now the single home; the old dict
keys survive as **deprecated aliases** so existing callers keep working.

``ALIASES`` pins the mapping: ``"<surface>:<dotted.key>"`` → canonical
registry metric (``{label=value}`` marks the series the alias reads).
``tests/test_obs.py::test_stats_alias_mapping_pinned`` fails if an alias
disappears or a canonical name drifts.
"""
from __future__ import annotations

__all__ = ["ALIASES", "SURFACES"]

#: the legacy stats surfaces and the accessor that produces each
SURFACES = {
    "store_server": "repro.net.server.StoreServer.stats()",
    "cluster": "repro.net.sharded.ShardedBackend.server_stats()",
    "gateway": "repro.gateway.server.GatewayServer.stats_doc()",
    "serve": "repro.serve.ServeEngine.aggregate_stats()",
}

ALIASES: dict[str, str] = {
    # -- StoreServer.stats() ------------------------------------------------
    "store_server:requests": "repro_store_server_requests_total",
    "store_server:ops.*": "repro_store_server_requests_total{op=*}",
    "store_server:streaming.chunks_in": "repro_store_server_stream_chunks_total{dir=in}",
    "store_server:streaming.chunks_out": "repro_store_server_stream_chunks_total{dir=out}",
    "store_server:streaming.bytes_in": "repro_store_server_stream_bytes_total{dir=in}",
    "store_server:streaming.bytes_out": "repro_store_server_stream_bytes_total{dir=out}",
    "store_server:streaming.streamed_writes": "repro_store_server_requests_total{op=write_blob_chunked}",
    "store_server:active_leases": "repro_store_server_active_leases",
    "store_server:connections": "repro_store_server_connections",
    "store_server:subscribers": "repro_store_server_subscribers",
    "store_server:catalog_records": "repro_store_server_catalog_records",
    "store_server:uptime_s": "repro_store_server_uptime_seconds",
    # -- ShardedBackend.server_stats() (per-shard docs are StoreServer.stats()
    # shapes; the aggregate keys below sum them) ----------------------------
    "cluster:requests": "repro_store_server_requests_total",
    "cluster:ops.*": "repro_store_server_requests_total{op=*}",
    # client-side cluster counters (attribute aliases)
    "cluster:failover_reads": "repro_cluster_failover_reads_total",
    "cluster:read_repairs": "repro_cluster_read_repairs_total",
    "cluster:lease_failovers": "repro_cluster_lease_failovers_total",
    "cluster:reconnects": "repro_remote_reconnects_total",
    # -- GatewayServer.stats_doc() ------------------------------------------
    "gateway:fabric.runs": "repro_runs_total",
    "gateway:fabric.failures": "repro_runs_total{status=failed}",
    "gateway:fabric.stored": "repro_run_stored_total",
    "gateway:fabric.singleflight_waits": "repro_singleflight_waits_total",
    "gateway:fabric.pending_runs": "repro_service_pending_runs",
    "gateway:fabric.rejected_runs": "repro_service_rejected_total",
    "gateway:gateway.*": "repro_gateway_requests_total{op=*}",
    "gateway:gateway.http_*": "repro_gateway_http_responses_total{status=*}",
    "gateway:tenant.runs": "repro_tenant_runs_total{tenant=*}",
    "gateway:tenant.rejected": "repro_tenant_rejected_total{tenant=*}",
    "gateway:tenant.in_flight": "repro_tenant_inflight{tenant=*}",
    "gateway:tenant.bytes_stored": "repro_tenant_stored_bytes{tenant=*}",
    # -- ServeEngine.aggregate_stats() (AggregateStats shape; ISSUE 10 moved
    # the engine's ad-hoc tallies onto the registry — the dataclass fields
    # below are reconstructed from these canonical series) ------------------
    "serve:runs": "repro_serve_requests_total",
    "serve:busy_seconds": "repro_serve_busy_seconds_total",
    "serve:units_total": "repro_serve_chunks_total",
    "serve:units_skipped": "repro_serve_chunks_skipped_total",
    "serve:stored": "repro_serve_snapshots_stored_total",
    # snapshot-store accounting (SnapshotStore attribute aliases)
    "serve:n_snapshots": "repro_serve_snapshots",
    "serve:snapshot_bytes": "repro_serve_snapshot_stored_bytes",
    "serve:n_snapshot_evictions": "repro_serve_snapshot_evictions_total{source=*}",
}
