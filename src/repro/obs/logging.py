"""Structured logging wired to the tracing context.

``configure_logging`` sets up the ``repro`` logger hierarchy with either a
human-readable line format or JSON lines; every record passes through
:class:`TraceInjectFilter`, which stamps ``trace_id`` / ``run_id`` /
``tenant`` from the active span and :func:`repro.obs.tracing.bind` baggage
— so one ``grep trace_id`` correlates logs with the span tree.
"""
from __future__ import annotations

import json
import logging
import sys
import time
from typing import Any

from . import tracing

__all__ = ["TraceInjectFilter", "JsonFormatter", "configure_logging", "get_logger"]

_LEVELS = {"debug", "info", "warning", "error", "critical"}


class TraceInjectFilter(logging.Filter):
    def filter(self, record: logging.LogRecord) -> bool:
        span = tracing.current_span()
        bag = tracing.current_baggage()
        record.trace_id = (span.trace_id if span else None) or bag.get("trace_id") or "-"
        record.run_id = bag.get("run_id") or "-"
        record.tenant = bag.get("tenant") or "-"
        return True


class JsonFormatter(logging.Formatter):
    def format(self, record: logging.LogRecord) -> str:
        doc: dict[str, Any] = {
            "ts": round(time.time(), 3),
            "level": record.levelname.lower(),
            "logger": record.name,
            "msg": record.getMessage(),
            "trace_id": getattr(record, "trace_id", "-"),
            "run_id": getattr(record, "run_id", "-"),
            "tenant": getattr(record, "tenant", "-"),
        }
        if record.exc_info:
            doc["exc"] = self.formatException(record.exc_info)
        return json.dumps(doc, separators=(",", ":"))


def configure_logging(
    level: str = "info",
    *,
    json_lines: bool = False,
    stream: Any = None,
) -> logging.Logger:
    """Configure the ``repro`` logger tree (idempotent — replaces handlers
    installed by a previous call, so tests can reconfigure freely)."""
    if level.lower() not in _LEVELS:
        raise ValueError(f"unknown log level {level!r} (expected one of {sorted(_LEVELS)})")
    logger = logging.getLogger("repro")
    logger.setLevel(getattr(logging, level.upper()))
    for h in [h for h in logger.handlers if getattr(h, "_repro_obs", False)]:
        logger.removeHandler(h)
    handler = logging.StreamHandler(stream or sys.stderr)
    handler._repro_obs = True  # type: ignore[attr-defined]
    handler.addFilter(TraceInjectFilter())
    if json_lines:
        handler.setFormatter(JsonFormatter())
    else:
        handler.setFormatter(
            logging.Formatter(
                "%(asctime)s %(levelname)-7s %(name)s [%(trace_id)s %(run_id)s %(tenant)s] %(message)s"
            )
        )
    logger.addHandler(handler)
    logger.propagate = False
    return logger


def get_logger(name: str) -> logging.Logger:
    return logging.getLogger(name if name.startswith("repro") else f"repro.{name}")
