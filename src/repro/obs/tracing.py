"""Cross-process run tracing: TraceContext, spans, NDJSON sinks.

A *trace* follows one workflow run across every process it touches:
gateway → ``WorkflowService`` → ``DagScheduler`` workers → ``RemoteBackend``
RPCs → ``StoreServer`` shards.  Each process appends finished spans to an
NDJSON file in a shared trace directory; ``python -m repro.obs.trace``
stitches them back into one tree by ``(trace_id, span_id, parent_id)``.

Propagation formats
-------------------
- HTTP (gateway): a W3C-style ``traceparent`` header,
  ``00-<32 hex trace_id>-<16 hex span_id>-01``.
- ``repro.net`` frames: an optional ``"tp"`` field carrying the same string
  in the request header.  Servers that predate tracing simply ignore the
  unknown field (the same forward-compat contract the v2 streaming
  negotiation relies on), so no handshake is needed.

Fast path
---------
Tracing is **off by default**.  When off, :func:`span` returns a shared
no-op object after one module-global check — the hot paths
(``store.get``, RPC dispatch) pay a function call and a branch, nothing
else.  ``benchmarks/bench_obs.py`` pins this.

Cross-thread propagation is explicit: the current span lives in a
``contextvars.ContextVar``, and code that hops threads (scheduler workers)
re-activates the parent with :func:`activate` or passes ``parent=``.
"""
from __future__ import annotations

import contextvars
import json
import os
import threading
import time
from typing import Any, Iterator, Mapping

__all__ = [
    "NOOP_SPAN",
    "Span",
    "TraceContext",
    "activate",
    "bind",
    "configure_tracing",
    "current_baggage",
    "current_span",
    "current_traceparent",
    "span",
    "tracing_enabled",
]

_HEX = "0123456789abcdef"


def _rand_hex(nbytes: int) -> str:
    return os.urandom(nbytes).hex()


class TraceContext:
    """An addressable point in a trace: ``trace_id`` + ``span_id``.

    Also the cross-process wire form (``traceparent``) and a valid
    ``parent=`` for :func:`span`, so a server can adopt an inbound context
    directly.
    """

    __slots__ = ("trace_id", "span_id")

    def __init__(self, trace_id: str, span_id: str) -> None:
        self.trace_id = trace_id
        self.span_id = span_id

    @classmethod
    def new(cls) -> "TraceContext":
        return cls(_rand_hex(16), _rand_hex(8))

    def to_traceparent(self) -> str:
        return f"00-{self.trace_id}-{self.span_id}-01"

    @classmethod
    def from_traceparent(cls, header: str | None) -> "TraceContext | None":
        if not header or not isinstance(header, str):
            return None
        parts = header.strip().split("-")
        if len(parts) < 3:
            return None
        _, trace_id, span_id = parts[0], parts[1], parts[2]
        if len(trace_id) != 32 or len(span_id) != 16:
            return None
        if any(c not in _HEX for c in trace_id + span_id):
            return None
        if trace_id == "0" * 32 or span_id == "0" * 16:
            return None
        return cls(trace_id, span_id)

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        return f"TraceContext({self.trace_id!r}, {self.span_id!r})"


class _SpanWriter:
    """Appends finished spans as NDJSON lines to ``<dir>/<service>-<pid>.ndjson``."""

    def __init__(self, directory: str, service: str) -> None:
        self.directory = directory
        self.service = service
        self._lock = threading.Lock()
        self._fh: Any = None

    def write(self, rec: dict[str, Any]) -> None:
        line = json.dumps(rec, separators=(",", ":")) + "\n"
        with self._lock:
            if self._fh is None:
                os.makedirs(self.directory, exist_ok=True)
                path = os.path.join(
                    self.directory, f"{self.service}-{os.getpid()}.ndjson"
                )
                self._fh = open(path, "a", encoding="utf-8")
            try:
                self._fh.write(line)
                self._fh.flush()
            except Exception:  # noqa: BLE001 — tracing must never break the fabric
                pass

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                try:
                    self._fh.close()
                except Exception:  # noqa: BLE001
                    pass
                self._fh = None


# module state: one writer per process; svc can still be overridden per span
# (in-process test clusters give each StoreServer its own service name)
_writer: _SpanWriter | None = None
_enabled = False
_env_checked = False
_current: contextvars.ContextVar[Any] = contextvars.ContextVar("repro_span", default=None)
_baggage: contextvars.ContextVar[Mapping[str, Any]] = contextvars.ContextVar(
    "repro_baggage", default={}
)


def configure_tracing(
    directory: str | None,
    service: str = "repro",
    *,
    enabled: bool = True,
) -> None:
    """Enable (or disable with ``enabled=False``/``directory=None``) span
    recording for this process.  Also reachable via the ``REPRO_TRACE_DIR``
    and ``REPRO_SERVICE`` environment variables."""
    global _writer, _enabled, _env_checked
    _env_checked = True
    old = _writer
    if directory is None or not enabled:
        _writer, _enabled = None, False
    else:
        _writer, _enabled = _SpanWriter(directory, service), True
    if old is not None:
        old.close()


def _ensure_env() -> None:
    global _env_checked
    if not _env_checked:
        _env_checked = True
        d = os.environ.get("REPRO_TRACE_DIR")
        if d:
            configure_tracing(d, os.environ.get("REPRO_SERVICE", "repro"))


def tracing_enabled() -> bool:
    _ensure_env()
    return _enabled


class Span:
    __slots__ = (
        "trace_id",
        "span_id",
        "parent_id",
        "name",
        "kind",
        "svc",
        "attrs",
        "_t0",
        "_start",
        "_token",
        "_ended",
    )

    def __init__(
        self,
        name: str,
        kind: str,
        trace_id: str,
        parent_id: str | None,
        svc: str | None,
        attrs: dict[str, Any],
    ) -> None:
        self.name = name
        self.kind = kind
        self.trace_id = trace_id
        self.span_id = _rand_hex(8)
        self.parent_id = parent_id
        self.svc = svc
        self.attrs = attrs
        self._t0 = time.monotonic()
        self._start = time.time()
        self._token: contextvars.Token | None = None
        self._ended = False

    def set(self, **attrs: Any) -> None:
        self.attrs.update(attrs)

    def rename(self, name: str) -> None:
        self.name = name

    def end(self) -> None:
        if self._ended:
            return
        self._ended = True
        w = _writer
        if w is None:
            return
        w.write(
            {
                "trace": self.trace_id,
                "span": self.span_id,
                "parent": self.parent_id,
                "name": self.name,
                "kind": self.kind,
                "svc": self.svc or w.service,
                "pid": os.getpid(),
                "start": round(self._start, 6),
                "dur": round(time.monotonic() - self._t0, 6),
                "attrs": self.attrs,
            }
        )

    def __enter__(self) -> "Span":
        self._token = _current.set(self)
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> None:
        if self._token is not None:
            _current.reset(self._token)
            self._token = None
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.end()


class _NoopSpan:
    """Shared do-nothing span — the disabled-tracing fast path."""

    __slots__ = ()
    trace_id = None
    span_id = None
    parent_id = None

    def set(self, **attrs: Any) -> None:
        pass

    def rename(self, name: str) -> None:
        pass

    def end(self) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass


NOOP_SPAN = _NoopSpan()


def span(
    name: str,
    *,
    kind: str = "internal",
    parent: Any = None,
    svc: str | None = None,
    **attrs: Any,
) -> Any:
    """Open a span (use as a context manager).

    ``parent`` may be a :class:`Span`, a :class:`TraceContext`, or ``None``
    (inherit the context-local current span; fresh trace if there is none).
    Returns :data:`NOOP_SPAN` when tracing is disabled.
    """
    _ensure_env()
    if not _enabled:
        return NOOP_SPAN
    if parent is None:
        parent = _current.get()
    if parent is not None and getattr(parent, "trace_id", None):
        return Span(name, kind, parent.trace_id, parent.span_id, svc, attrs)
    return Span(name, kind, _rand_hex(16), None, svc, attrs)


def current_span() -> Span | None:
    s = _current.get()
    return s if isinstance(s, Span) else None


def current_context() -> TraceContext | None:
    s = _current.get()
    if s is None or not getattr(s, "trace_id", None):
        return None
    return TraceContext(s.trace_id, s.span_id)


def current_traceparent() -> str | None:
    """Wire form of the current span, or ``None`` outside any span (or with
    tracing off) — callers attach it to outbound frames/requests only when
    non-None, so disabled tracing adds zero bytes to the wire."""
    ctx = current_context()
    return ctx.to_traceparent() if ctx is not None else None


class activate:
    """Re-activate a span/context on another thread::

        with tracing.activate(parent_ctx):
            ...  # span() calls here parent under parent_ctx
    """

    def __init__(self, target: Any) -> None:
        self._target = target
        self._token: contextvars.Token | None = None

    def __enter__(self) -> Any:
        self._token = _current.set(self._target)
        return self._target

    def __exit__(self, *exc: Any) -> None:
        if self._token is not None:
            _current.reset(self._token)


class bind:
    """Attach log baggage (``run_id``, ``tenant``, …) to the current context;
    the :mod:`repro.obs.logging` filter stamps it onto every record."""

    def __init__(self, **kw: Any) -> None:
        self._kw = kw
        self._token: contextvars.Token | None = None

    def __enter__(self) -> "bind":
        merged = {**_baggage.get(), **self._kw}
        self._token = _baggage.set(merged)
        return self

    def __exit__(self, *exc: Any) -> None:
        if self._token is not None:
            _baggage.reset(self._token)


def current_baggage() -> Mapping[str, Any]:
    return _baggage.get()


def iter_spans(directory: str) -> Iterator[dict[str, Any]]:
    """Yield every span record found under ``directory`` (all processes)."""
    try:
        names = sorted(os.listdir(directory))
    except OSError:
        return
    for fname in names:
        if not fname.endswith(".ndjson"):
            continue
        try:
            with open(os.path.join(directory, fname), encoding="utf-8") as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        yield json.loads(line)
                    except ValueError:
                        continue  # torn tail line from a live writer
        except OSError:
            continue
