"""GatedGCN (arXiv:1711.07553 / benchmarking-GNNs arXiv:2003.00982).

Message passing via ``jax.ops.segment_sum`` over an edge-index -> node
scatter (JAX sparse is BCOO-only; the segment-op formulation IS the system's
SpMM layer).  Layer update (with edge features, residuals, and norm):

    e'_ij = e_ij + ReLU(Norm(A h_i + B h_j + C e_ij))
    eta_ij = sigma(e'_ij) / (sum_j sigma(e'_ij) + eps)
    h'_i  = h_i + ReLU(Norm(U h_i + sum_j eta_ij * (V h_j)))
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import GNNConfig
from .layers import PSpec, layer_norm


def gnn_specs(cfg: GNNConfig, d_feat: int) -> dict:
    L, h, de = cfg.n_layers, cfg.d_hidden, cfg.d_edge
    return {
        "node_encoder": PSpec((d_feat, h), ("node_feat", "hidden")),
        "edge_encoder": PSpec((de, h), ("edge_feat", "hidden")),
        "layers": {
            "A": PSpec((L, h, h), ("layers", "hidden", "hidden")),
            "B": PSpec((L, h, h), ("layers", "hidden", "hidden")),
            "C": PSpec((L, h, h), ("layers", "hidden", "hidden")),
            "U": PSpec((L, h, h), ("layers", "hidden", "hidden")),
            "V": PSpec((L, h, h), ("layers", "hidden", "hidden")),
            "ln_h_scale": PSpec((L, h), ("layers", "hidden"), init="ones"),
            "ln_h_bias": PSpec((L, h), ("layers", "hidden"), init="zeros"),
            "ln_e_scale": PSpec((L, h), ("layers", "hidden"), init="ones"),
            "ln_e_bias": PSpec((L, h), ("layers", "hidden"), init="zeros"),
        },
        "readout": PSpec((h, cfg.n_classes), ("hidden", "classes")),
    }


def _gated_layer(p: dict, h: jax.Array, e: jax.Array, src: jax.Array, dst: jax.Array):
    n = h.shape[0]
    h_src = jnp.take(h, src, axis=0)
    h_dst = jnp.take(h, dst, axis=0)
    e_new = (
        jnp.einsum("ed,df->ef", h_dst, p["A"])
        + jnp.einsum("ed,df->ef", h_src, p["B"])
        + jnp.einsum("ed,df->ef", e, p["C"])
    )
    e_new = jax.nn.relu(layer_norm(e_new, p["ln_e_scale"], p["ln_e_bias"]))
    e = e + e_new

    eta = jax.nn.sigmoid(e)
    msg = eta * jnp.einsum("ed,df->ef", h_src, p["V"])
    num = jax.ops.segment_sum(msg, dst, num_segments=n)
    den = jax.ops.segment_sum(eta, dst, num_segments=n) + 1e-6
    agg = num / den
    h_new = jnp.einsum("nd,df->nf", h, p["U"]) + agg
    h_new = jax.nn.relu(layer_norm(h_new, p["ln_h_scale"], p["ln_h_bias"]))
    return h + h_new, e


def forward(
    params: dict,
    cfg: GNNConfig,
    node_feat: jax.Array,  # [N, d_feat]
    edge_index: jax.Array,  # [2, E] (src, dst)
    *,
    unroll: int = 1,
    remat=None,
) -> jax.Array:
    """Returns per-node class logits [N, n_classes]."""
    src, dst = edge_index[0], edge_index[1]
    h = jnp.einsum("nd,df->nf", node_feat.astype(cfg.dtype), params["node_encoder"])
    # edge features: encoded from a constant when the dataset has none
    e = jnp.ones((src.shape[0], cfg.d_edge), cfg.dtype) @ params["edge_encoder"]

    def _constrain(h, e):
        if not (cfg.act_node_axes or cfg.act_edge_axes):
            return h, e
        from jax.sharding import PartitionSpec as P

        if cfg.act_node_axes:
            h = jax.lax.with_sharding_constraint(h, P(tuple(cfg.act_node_axes), None))
        if cfg.act_edge_axes:
            e = jax.lax.with_sharding_constraint(e, P(tuple(cfg.act_edge_axes), None))
        return h, e

    def body(carry, layer_p):
        h, e = carry
        h, e = _gated_layer(layer_p, h, e, src, dst)
        return _constrain(h, e), None

    if remat is not None:
        body = jax.checkpoint(body, policy=remat)
    (h, e), _ = jax.lax.scan(body, (h, e), params["layers"], unroll=unroll)
    return jnp.einsum("nf,fc->nc", h, params["readout"]).astype(jnp.float32)


def forward_batched(
    params: dict,
    cfg: GNNConfig,
    node_feat: jax.Array,  # [B, n, d]
    edge_index: jax.Array,  # [B, 2, e]
) -> jax.Array:
    """Batched small graphs (molecule cell): vmap over the batch, then mean-
    pool nodes for a graph-level prediction."""

    def single(nf, ei):
        logits = forward(params, cfg, nf, ei)
        return logits.mean(axis=0)

    return jax.vmap(single)(node_feat, edge_index)


def loss_fn(
    params: dict,
    cfg: GNNConfig,
    node_feat: jax.Array,
    edge_index: jax.Array,
    labels: jax.Array,
    mask: jax.Array | None = None,
    remat=None,
) -> jax.Array:
    logits = forward(params, cfg, node_feat, edge_index, remat=remat)
    ce = -jnp.take_along_axis(
        jax.nn.log_softmax(logits, axis=-1), labels[:, None], axis=-1
    )[:, 0]
    if mask is not None:
        return (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return ce.mean()
