"""Model zoo: transformers (dense/MoE/MLA/local-global), GatedGCN, recsys."""
from . import attention, gnn, layers, moe, recsys, transformer  # noqa: F401
