"""Common layers + the param-spec system (init / logical axes / abstract).

Params are nested dicts of jnp arrays.  Every model declares a *spec tree* of
``PSpec`` (shape, logical axes, init); from one spec tree we derive:

  init_params     — materialized params (smoke tests, real training)
  abstract_params — ShapeDtypeStructs with shardings (dry-run: no allocation)
  logical_axes    — the axes tree consumed by launch.sharding.resolve
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# param specs
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class PSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None  # default: 1/sqrt(fan_in) for normal

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x: Any) -> bool:
    return isinstance(x, PSpec)


def init_params(key: jax.Array, specs: Any, dtype: Any) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dtype))
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
            scale = s.scale if s.scale is not None else 1.0 / math.sqrt(fan_in)
            out.append((jax.random.normal(k, s.shape) * scale).astype(dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(specs: Any, dtype: Any, shardings: Any = None) -> Any:
    """ShapeDtypeStructs (optionally with shardings) — dry-run stand-ins."""
    if shardings is None:
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs, is_leaf=_is_spec
        )
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, dtype, sharding=sh),
        specs,
        shardings,
        is_leaf=_is_spec,
    )


def logical_axes(specs: Any) -> Any:
    return jax.tree_util.tree_map(lambda s: s.axes, specs, is_leaf=_is_spec)


def param_count(specs: Any) -> int:
    return sum(
        math.prod(s.shape)
        for s in jax.tree_util.tree_leaves(specs, is_leaf=_is_spec)
    )


# --------------------------------------------------------------------------
# functional layers
# --------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def batch_stat_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5):
    """BatchNorm with on-the-fly batch statistics (training mode, no EMA).

    GatedGCN's reference uses BN; a pure-functional train step computes batch
    stats per step.  Stats reduce over all leading dims.
    """
    xf = x.astype(jnp.float32)
    red = tuple(range(x.ndim - 1))
    mu = jnp.mean(xf, axis=red, keepdims=True)
    var = jnp.var(xf, axis=red, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


# -- rotary ------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0):
    """x: [..., S, H, dh]; positions: [..., S]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [dh/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, dh/2]
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- embedding / segment ops ---------------------------------------------------
def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    return jnp.take(table, ids, axis=0)


def embedding_bag(
    table: jax.Array,
    ids: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    combiner: str = "sum",
    weights: jax.Array | None = None,
) -> jax.Array:
    """EmbeddingBag via gather + segment_sum (JAX has no native one).

    ids: [nnz] row indices; segment_ids: [nnz] output bag per id (sorted not
    required); returns [num_segments, dim].
    """
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(segment_ids, dtype=rows.dtype),
            segment_ids,
            num_segments=num_segments,
        )
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def mlp(x: jax.Array, layers: list[dict[str, jax.Array]], act=jax.nn.relu):
    for i, lyr in enumerate(layers):
        x = jnp.einsum("...d,df->...f", x, lyr["w"]) + lyr["b"]
        if i < len(layers) - 1:
            x = act(x)
    return x


def mlp_specs(d_in: int, widths: tuple[int, ...], axes_in="embed", prefix="mlp"):
    layers = []
    d = d_in
    for i, w in enumerate(widths):
        layers.append(
            {
                "w": PSpec((d, w), (axes_in if i == 0 else "mlp_hidden", "mlp_hidden")),
                "b": PSpec((w,), ("mlp_hidden",), init="zeros"),
            }
        )
        d = w
    return layers
