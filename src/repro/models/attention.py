"""Attention variants (XLA path): GQA full/causal/local, MLA, cached decode.

These pure-jnp implementations are the default lowering path (and the oracle
for the Pallas kernels in ``repro.kernels``).  Models switch to the Pallas
flash kernels on TPU via ``attention_impl="pallas"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import apply_rope

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [B,S,Hkv,G,dh]  k: [B,T,Hkv,dh] -> scores [B,Hkv,G,S,T]."""
    return jnp.einsum("bskgd,btkd->bkgst", q, k)


def gqa_attention(
    q: jax.Array,  # [B,S,Hq,dh]
    k: jax.Array,  # [B,T,Hkv,dh]
    v: jax.Array,  # [B,T,Hkv,dh]
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    global_flag: jax.Array | None = None,
) -> jax.Array:
    """``window`` restricts attention to a sliding window; a traced
    ``global_flag`` (0.0/1.0 per layer, e.g. gemma3's 5:1 pattern) disables
    the window when 1 so local and global layers share one scan body."""
    B, S, Hq, dh = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, dh)
    scores = _gqa_scores(qg, k).astype(jnp.float32) / jnp.sqrt(dh).astype(jnp.float32)

    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        in_window = kpos[None, :] > qpos[:, None] - window
        if global_flag is not None:
            in_window = in_window | (global_flag > 0.5)
        mask &= in_window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, Hq, v.shape[-1])  # v_dim may differ from q (MLA)


def decode_attention(
    q: jax.Array,  # [B,S,Hq,dh]  (S=1 decode; S=chunk for chunked prefill)
    k_cache: jax.Array,  # [B,T,Hkv,dh]  (new kv already inserted)
    v_cache: jax.Array,
    q_start: jax.Array,  # [B] position of the FIRST query token
    *,
    window: int | None = None,
    global_flag: jax.Array | None = None,
) -> jax.Array:
    """Cached attention: query token s attends kpos <= q_start+s."""
    B, S, Hq, dh = q.shape
    _, T, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k_cache).astype(jnp.float32)
    scores = scores / jnp.sqrt(dh).astype(jnp.float32)
    kpos = jnp.arange(T)
    qpos = q_start[:, None] + jnp.arange(S)[None, :]  # [B,S]
    mask = kpos[None, None, :] <= qpos[:, :, None]  # [B,S,T]
    if window is not None:
        in_window = kpos[None, None, :] > qpos[:, :, None] - window
        if global_flag is not None:
            in_window = in_window | (global_flag > 0.5)
        mask &= in_window
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v_cache)
    return out.reshape(B, S, Hq, v_cache.shape[-1])


def chunked_gqa_attention(
    q: jax.Array,  # [B,S,Hq,dh]
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    global_flag: jax.Array | None = None,
    block_q: int = 256,
) -> jax.Array:
    """Q-chunked attention with per-chunk rematerialization: the S x T score
    matrix never materializes beyond one (block_q x T) tile, and backward
    recomputes per chunk — the XLA-level analogue of the Pallas flash kernel
    (which replaces this on real TPU).  Peak score memory drops S/block_q x.
    """
    B, S, Hq, dh = q.shape
    if S <= block_q:
        return gqa_attention(
            q, k, v, causal=causal, window=window, global_flag=global_flag
        )
    pad = (-S) % block_q
    qp = jnp.pad(q, [(0, 0), (0, pad), (0, 0), (0, 0)]) if pad else q
    nb = qp.shape[1] // block_q
    qb = jnp.moveaxis(qp.reshape(B, nb, block_q, Hq, dh), 1, 0)  # [nb,B,blk,H,dh]
    offsets = jnp.arange(nb) * block_q

    def body(_, xs):
        q_chunk, off = xs
        out = gqa_attention(
            q_chunk,
            k,
            v,
            causal=causal,
            window=window,
            q_offset=off,
            global_flag=global_flag,
        )
        return None, out

    _, outs = jax.lax.scan(jax.checkpoint(body), None, (qb, offsets))
    v_dim = outs.shape[-1]  # may differ from q's head dim (MLA)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nb * block_q, Hq, v_dim)
    return out[:, :S]


def insert_chunk(cache: jax.Array, new: jax.Array, cache_len: jax.Array) -> jax.Array:
    """Scatter new [B,c,...] into cache [B,T,...] at positions cache_len+j."""
    B, T = cache.shape[:2]
    c = new.shape[1]
    kpos = jnp.arange(T)
    oh = (
        kpos[None, :, None] == (cache_len[:, None, None] + jnp.arange(c)[None, None, :])
    ).astype(cache.dtype)
    return cache + jnp.einsum("btc,bc...->bt...", oh, new)


# --------------------------------------------------------------------------
# MLA (DeepSeek-V2, arXiv:2405.04434) — with the absorbed-matmul decode path
# --------------------------------------------------------------------------
def mla_prefill(
    x: jax.Array,  # [B,S,d]
    p: dict,
    *,
    n_heads: int,
    nope: int,
    rope: int,
    v_dim: int,
    positions: jax.Array,
    theta: float,
    causal: bool = True,
    attn_impl: str = "einsum",
    block_q: int = 512,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (attn_out [B,S,H*v_dim], c_kv [B,S,r], k_rope [B,S,rope])."""
    B, S, _ = x.shape
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
    q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])  # e = nope+rope
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, theta)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])  # r = kv_lora + rope
    c_kv, k_rope = ckv_full[..., :-rope], ckv_full[..., -rope:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, theta)[:, :, 0, :]

    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"])  # [B,S,H,nope]
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"])  # [B,S,H,v_dim]

    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, n_heads, rope))],
        axis=-1,
    )
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    if attn_impl == "chunked":
        out = chunked_gqa_attention(qf, k, v, causal=causal, block_q=block_q)
    else:
        out = gqa_attention(qf, k, v, causal=causal)
    return out.reshape(B, S, n_heads * v_dim), c_kv, k_rope


def mla_decode(
    x: jax.Array,  # [B,S,d]  (S=1 decode; S=chunk for chunked prefill)
    p: dict,
    c_kv_cache: jax.Array,  # [B,T,r]   (new latents already inserted)
    k_rope_cache: jax.Array,  # [B,T,rope]
    q_start: jax.Array,  # [B] position of the FIRST query token
    *,
    n_heads: int,
    nope: int,
    rope: int,
    v_dim: int,
    positions: jax.Array,  # [B,S]
    theta: float,
) -> jax.Array:
    """Absorbed-matmul cached attention: scores live in the latent space, the
    cache is only the rank-r latent + shared rope key — the MLA memory win.
    Query token s (global position q_start+s) attends kpos <= q_start+s."""
    B, S, _ = x.shape
    T = c_kv_cache.shape[1]
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"])
    q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, theta)

    # absorb w_uk into the query: q_lat [B,S,H,r]
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"])
    scores = jnp.einsum("bshr,btr->bhst", q_lat, c_kv_cache)
    scores = scores + jnp.einsum("bshe,bte->bhst", q_rope, k_rope_cache)
    scores = scores.astype(jnp.float32) / jnp.sqrt(nope + rope).astype(jnp.float32)

    kpos = jnp.arange(T)
    qpos = q_start[:, None] + jnp.arange(S)[None, :]  # [B,S]
    mask = kpos[None, None, :] <= qpos[:, :, None]  # [B,S,T]
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)

    ctx_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv_cache)  # [B,S,H,r]
    v = jnp.einsum("bshr,rhe->bshe", ctx_lat, p["w_uv"])  # [B,S,H,v_dim]
    return v.reshape(B, S, n_heads * v_dim)


def insert_kv(cache: jax.Array, new: jax.Array, cache_len: jax.Array) -> jax.Array:
    """Scatter new [B,1,...] into cache [B,T,...] at position cache_len[B]."""
    T = cache.shape[1]
    onehot = (jnp.arange(T)[None] == cache_len[:, None]).astype(cache.dtype)
    shape = (cache.shape[0], T) + (1,) * (cache.ndim - 2)
    return cache + onehot.reshape(shape) * new
