"""RecSys models: FM, DCN-v2, BST, SASRec on a shared embedding substrate.

The hot path is the sparse embedding lookup: JAX has no native EmbeddingBag,
so lookups are ``jnp.take`` + ``jax.ops.segment_sum`` (layers.embedding_bag)
— this IS part of the system.  Tables use one logical "table_vocab" axis so
the sharding rules row-shard them across the model axis.

Retrieval scoring (``retrieval_cand``): one query against 10^6 candidates as
a batched dot against the candidate-embedding matrix — FM factorizes exactly
(score = <sum_user v, v_cand> + w_cand + const); sequence models use their
standard final-hidden-state-dot-item-embedding scoring; DCN-v2 uses a
two-tower projection of its cross output (the production retrieval pattern —
the full cross network per candidate is a ranking, not retrieval, workload).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import RecsysConfig
from .layers import PSpec, layer_norm, mlp


# --------------------------------------------------------------------------
# shared helpers
# --------------------------------------------------------------------------
def _mlp_specs(d_in: int, widths: tuple[int, ...], out_dim: int = 1) -> list:
    layers = []
    d = d_in
    for w in list(widths) + [out_dim]:
        layers.append(
            {
                "w": PSpec((d, w), ("mlp_in", "mlp_hidden")),
                "b": PSpec((w,), ("mlp_hidden",), init="zeros"),
            }
        )
        d = w
    return layers


def _field_table_specs(cfg: RecsysConfig) -> PSpec:
    """One stacked table for all sparse fields (offset-indexed rows)."""
    total_rows = sum(cfg.vocab_sizes)
    return PSpec((total_rows, cfg.embed_dim), ("table_vocab", "embed_dim"), scale=0.02)


def field_offsets(cfg: RecsysConfig) -> jnp.ndarray:
    off = [0]
    for v in cfg.vocab_sizes[:-1]:
        off.append(off[-1] + v)
    return jnp.asarray(off, jnp.int32)


def lookup_fields(table: jax.Array, cfg: RecsysConfig, sparse_ids: jax.Array):
    """sparse_ids [B, n_fields] (per-field local ids) -> [B, n_fields, dim]."""
    ids = sparse_ids + field_offsets(cfg)[None, :]
    return jnp.take(table, ids, axis=0)


# --------------------------------------------------------------------------
# FM (Rendle ICDM'10): O(nk) sum-square trick
# --------------------------------------------------------------------------
def fm_specs(cfg: RecsysConfig) -> dict:
    total_rows = sum(cfg.vocab_sizes)
    return {
        "table": _field_table_specs(cfg),
        "w_linear": PSpec((total_rows,), ("table_vocab",), scale=0.01),
        "bias": PSpec((1,), (None,), init="zeros"),
    }


def fm_forward(params: dict, cfg: RecsysConfig, sparse_ids: jax.Array) -> jax.Array:
    ids = sparse_ids + field_offsets(cfg)[None, :]
    v = jnp.take(params["table"], ids, axis=0)  # [B,F,k]
    lin = jnp.take(params["w_linear"], ids, axis=0).sum(-1)  # [B]
    s = v.sum(axis=1)  # [B,k]
    pair = 0.5 * (jnp.square(s).sum(-1) - jnp.square(v).sum(axis=(1, 2)))
    return params["bias"][0] + lin + pair


def fm_retrieval(params: dict, cfg: RecsysConfig, sparse_ids, candidate_ids):
    """Exact FM split: user-part constant + <sum_user v, v_c> + w_c."""
    ids = sparse_ids + field_offsets(cfg)[None, :]
    v_u = jnp.take(params["table"], ids, axis=0).sum(axis=1)  # [B,k]
    v_c = jnp.take(params["table"], candidate_ids, axis=0)  # [C,k]
    w_c = jnp.take(params["w_linear"], candidate_ids, axis=0)  # [C]
    return jnp.einsum("bk,ck->bc", v_u, v_c) + w_c[None, :]


# --------------------------------------------------------------------------
# DCN-v2 (arXiv:2008.13535)
# --------------------------------------------------------------------------
def dcn_specs(cfg: RecsysConfig) -> dict:
    d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    Lc = cfg.n_cross_layers
    return {
        "table": _field_table_specs(cfg),
        "cross_w": PSpec((Lc, d0, d0), ("layers", "x0", "x0")),
        "cross_b": PSpec((Lc, d0), ("layers", "x0"), init="zeros"),
        "mlp": _mlp_specs(d0, cfg.mlp),
        "tower": PSpec((d0, cfg.embed_dim), ("x0", "embed_dim")),  # retrieval tower
    }


def dcn_embed(params: dict, cfg: RecsysConfig, dense, sparse_ids):
    emb = lookup_fields(params["table"], cfg, sparse_ids)  # [B,F,k]
    B = dense.shape[0]
    return jnp.concatenate([dense, emb.reshape(B, -1)], axis=-1)


def dcn_cross(params: dict, x0: jax.Array) -> jax.Array:
    x = x0
    n_layers = params["cross_w"].shape[0]
    for l in range(n_layers):
        x = x0 * (jnp.einsum("bd,de->be", x, params["cross_w"][l]) + params["cross_b"][l]) + x
    return x


def dcn_forward(params: dict, cfg: RecsysConfig, dense, sparse_ids) -> jax.Array:
    x0 = dcn_embed(params, cfg, dense, sparse_ids)
    x = dcn_cross(params, x0)
    return mlp(x, params["mlp"])[:, 0]


def dcn_retrieval(params: dict, cfg: RecsysConfig, dense, sparse_ids, candidate_ids):
    x0 = dcn_embed(params, cfg, dense, sparse_ids)
    u = jnp.einsum("bd,dk->bk", dcn_cross(params, x0), params["tower"])
    v_c = jnp.take(params["table"], candidate_ids, axis=0)
    return jnp.einsum("bk,ck->bc", u, v_c)


# --------------------------------------------------------------------------
# BST (arXiv:1905.06874): transformer over user behaviour sequence
# --------------------------------------------------------------------------
def _tf_block_specs(cfg: RecsysConfig, L: int, d: int) -> dict:
    h = cfg.n_heads
    dh = max(d // max(h, 1), 1)
    return {
        "wq": PSpec((L, d, h, dh), ("layers", "embed_dim", "heads", "head_dim")),
        "wk": PSpec((L, d, h, dh), ("layers", "embed_dim", "heads", "head_dim")),
        "wv": PSpec((L, d, h, dh), ("layers", "embed_dim", "heads", "head_dim")),
        "wo": PSpec((L, h, dh, d), ("layers", "heads", "head_dim", "embed_dim")),
        "ffn_w1": PSpec((L, d, 4 * d), ("layers", "embed_dim", "ff")),
        "ffn_b1": PSpec((L, 4 * d), ("layers", "ff"), init="zeros"),
        "ffn_w2": PSpec((L, 4 * d, d), ("layers", "ff", "embed_dim")),
        "ffn_b2": PSpec((L, d), ("layers", "embed_dim"), init="zeros"),
        "ln1_s": PSpec((L, d), ("layers", "embed_dim"), init="ones"),
        "ln1_b": PSpec((L, d), ("layers", "embed_dim"), init="zeros"),
        "ln2_s": PSpec((L, d), ("layers", "embed_dim"), init="ones"),
        "ln2_b": PSpec((L, d), ("layers", "embed_dim"), init="zeros"),
    }


def _tf_encode(p: dict, x: jax.Array, causal: bool) -> jax.Array:
    """x [B,S,d]; stacked blocks via scan."""
    from .attention import gqa_attention

    def body(carry, lp):
        h = layer_norm(carry, lp["ln1_s"], lp["ln1_b"])
        q = jnp.einsum("bsd,dhe->bshe", h, lp["wq"])
        k = jnp.einsum("bsd,dhe->bshe", h, lp["wk"])
        v = jnp.einsum("bsd,dhe->bshe", h, lp["wv"])
        a = gqa_attention(q, k, v, causal=causal)
        carry = carry + jnp.einsum("bshe,hed->bsd", a, lp["wo"])
        h = layer_norm(carry, lp["ln2_s"], lp["ln2_b"])
        f = jax.nn.relu(jnp.einsum("bsd,df->bsf", h, lp["ffn_w1"]) + lp["ffn_b1"])
        carry = carry + jnp.einsum("bsf,fd->bsd", f, lp["ffn_w2"]) + lp["ffn_b2"]
        return carry, None

    x, _ = jax.lax.scan(body, x, p)
    return x


def bst_specs(cfg: RecsysConfig) -> dict:
    d = cfg.embed_dim
    # +1 position: the target item is appended to the behaviour sequence
    return {
        "item_table": PSpec((cfg.item_vocab, d), ("table_vocab", "embed_dim"), scale=0.02),
        "pos_table": PSpec((cfg.seq_len + 1, d), ("seq", "embed_dim"), scale=0.02),
        "blocks": _tf_block_specs(cfg, cfg.n_blocks, d),
        "mlp": _mlp_specs((cfg.seq_len + 1) * d, cfg.mlp),
    }


def bst_forward(params: dict, cfg: RecsysConfig, hist_ids, target_id) -> jax.Array:
    B = hist_ids.shape[0]
    seq = jnp.concatenate([hist_ids, target_id[:, None]], axis=1)  # [B,S+1]
    x = jnp.take(params["item_table"], seq, axis=0) + params["pos_table"][None]
    x = _tf_encode(params["blocks"], x, causal=False)
    return mlp(x.reshape(B, -1), params["mlp"])[:, 0]


def bst_retrieval(params: dict, cfg: RecsysConfig, hist_ids, candidate_ids):
    x = jnp.take(params["item_table"], hist_ids, axis=0)
    x = x + params["pos_table"][None, : cfg.seq_len]
    x = _tf_encode(params["blocks"], x, causal=False)
    u = x.mean(axis=1)  # [B,d]
    v_c = jnp.take(params["item_table"], candidate_ids, axis=0)
    return jnp.einsum("bd,cd->bc", u, v_c)


# --------------------------------------------------------------------------
# SASRec (arXiv:1808.09781)
# --------------------------------------------------------------------------
def sasrec_specs(cfg: RecsysConfig) -> dict:
    d = cfg.embed_dim
    return {
        "item_table": PSpec((cfg.item_vocab, d), ("table_vocab", "embed_dim"), scale=0.02),
        "pos_table": PSpec((cfg.seq_len, d), ("seq", "embed_dim"), scale=0.02),
        "blocks": _tf_block_specs(cfg, cfg.n_blocks, d),
        "ln_f_s": PSpec((d,), ("embed_dim",), init="ones"),
        "ln_f_b": PSpec((d,), ("embed_dim",), init="zeros"),
    }


def sasrec_encode(params: dict, cfg: RecsysConfig, hist_ids) -> jax.Array:
    x = jnp.take(params["item_table"], hist_ids, axis=0) + params["pos_table"][None]
    x = _tf_encode(params["blocks"], x, causal=True)
    return layer_norm(x, params["ln_f_s"], params["ln_f_b"])  # [B,S,d]


def sasrec_forward(params: dict, cfg: RecsysConfig, hist_ids, pos_ids, neg_ids):
    """BPR-style: score positive & negative next items from the last state."""
    h = sasrec_encode(params, cfg, hist_ids)[:, -1]  # [B,d]
    v_pos = jnp.take(params["item_table"], pos_ids, axis=0)
    v_neg = jnp.take(params["item_table"], neg_ids, axis=0)
    return jnp.einsum("bd,bd->b", h, v_pos), jnp.einsum("bd,bd->b", h, v_neg)


def sasrec_retrieval(params: dict, cfg: RecsysConfig, hist_ids, candidate_ids):
    h = sasrec_encode(params, cfg, hist_ids)[:, -1]
    v_c = jnp.take(params["item_table"], candidate_ids, axis=0)
    return jnp.einsum("bd,cd->bc", h, v_c)
