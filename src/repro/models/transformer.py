"""Config-driven transformer LM: GQA / MLA / local:global, dense or MoE FFN.

Scan-over-layers with stacked per-layer params (compile-once layer body; the
production approach for deep models).  Heterogeneous local:global attention
(gemma3's 5:1 pattern) stays inside one scan body via a per-layer flag.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import LMConfig
from .attention import (
    NEG_INF,
    chunked_gqa_attention,
    decode_attention,
    gqa_attention,
    insert_chunk,
    insert_kv,
    mla_decode,
    mla_prefill,
)
from .layers import PSpec, apply_rope, rms_norm
from .moe import MoEDims, moe_ffn, moe_specs


# --------------------------------------------------------------------------
# param specs
# --------------------------------------------------------------------------
def lm_specs(cfg: LMConfig) -> dict:
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab
    hq, hkv, dh, f = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    layers: dict[str, Any] = {
        "ln1": PSpec((L, d), ("layers", "embed"), init="zeros"),
        "ln2": PSpec((L, d), ("layers", "embed"), init="zeros"),
    }
    if cfg.mla is not None:
        m = cfg.mla
        e_q = m.nope_head_dim + m.rope_head_dim
        layers["attn"] = {
            "w_dq": PSpec((L, d, m.q_lora_rank), ("layers", "embed", "q_lora")),
            "w_uq": PSpec(
                (L, m.q_lora_rank, hq, e_q), ("layers", "q_lora", "heads", "head_dim")
            ),
            "w_dkv": PSpec(
                (L, d, m.kv_lora_rank + m.rope_head_dim), ("layers", "embed", "kv_lora")
            ),
            "w_uk": PSpec(
                (L, m.kv_lora_rank, hq, m.nope_head_dim),
                ("layers", "kv_lora", "heads", "head_dim"),
            ),
            "w_uv": PSpec(
                (L, m.kv_lora_rank, hq, m.v_head_dim),
                ("layers", "kv_lora", "heads", "head_dim"),
            ),
            "w_o": PSpec(
                (L, hq * m.v_head_dim, d), ("layers", "qkv", "embed")
            ),
        }
    else:
        layers["attn"] = {
            # attn_in/attn_out default to replicated; archs whose head counts
            # don't divide the model axis (gemma3: 8 heads vs 16-way) override
            # them for weight/optimizer STORAGE sharding (weight-gathered)
            "wq": PSpec((L, d, hq, dh), ("layers", "attn_in", "heads", "head_dim")),
            "wk": PSpec((L, d, hkv, dh), ("layers", "attn_in", "kv_heads", "head_dim")),
            "wv": PSpec((L, d, hkv, dh), ("layers", "attn_in", "kv_heads", "head_dim")),
            "wo": PSpec((L, hq, dh, d), ("layers", "heads", "head_dim", "attn_out")),
        }
    if cfg.moe is not None:
        layers["moe"] = moe_specs(cfg.moe, d, L)
    else:
        layers["ffn"] = {
            "w_gate": PSpec((L, d, f), ("layers", "embed", "ff")),
            "w_up": PSpec((L, d, f), ("layers", "embed", "ff")),
            "w_down": PSpec((L, f, d), ("layers", "ff", "embed")),
        }
    specs = {
        "embed": PSpec((V, d), ("vocab", "embed"), scale=0.02),
        "layers": layers,
        "final_norm": PSpec((d,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        specs["head"] = PSpec((d, V), ("embed", "vocab"))
    return specs


def layer_flags(cfg: LMConfig) -> np.ndarray:
    """is_global per layer: gemma3 pattern 'n_local local then 1 global'."""
    if cfg.local_global is None:
        return np.ones(cfg.n_layers, dtype=np.float32)
    n_local, n_global = cfg.local_global
    cycle = n_local + n_global
    flags = [(i % cycle) >= n_local for i in range(cfg.n_layers)]
    return np.asarray(flags, dtype=np.float32)


def _moe_dims(cfg: LMConfig) -> MoEDims:
    assert cfg.moe is not None
    return MoEDims(
        n_experts=cfg.moe.n_experts,
        top_k=cfg.moe.top_k,
        n_shared=cfg.moe.n_shared,
        d_model=cfg.d_model,
        d_ff=cfg.moe.d_ff_expert,
        group_size=cfg.moe_group_size,
        capacity_factor=cfg.moe_capacity_factor,
        ep_axis=cfg.moe_ep_axis,
        token_axes=tuple(cfg.moe_token_axes),
    )


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def _attn_block(cfg: LMConfig, p: dict, x, positions, is_global, *, causal=True):
    """Full-sequence attention (train / prefill). Returns (out, kv-for-cache)."""
    B, S, _ = x.shape
    if cfg.mla is not None:
        m = cfg.mla
        out, c_kv, k_rope = mla_prefill(
            x,
            p,
            n_heads=cfg.n_heads,
            nope=m.nope_head_dim,
            rope=m.rope_head_dim,
            v_dim=m.v_head_dim,
            positions=positions,
            theta=cfg.rope_theta,
            causal=causal,
            attn_impl=cfg.attention_impl,
            block_q=cfg.attn_block_q,
        )
        out = jnp.einsum("bse,ed->bsd", out, p["w_o"])
        return out, (c_kv, k_rope)
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    window = None if cfg.local_global is None else cfg.local_window
    if cfg.attention_impl == "chunked":
        out = chunked_gqa_attention(
            q, k, v, causal=causal, window=window, global_flag=is_global,
            block_q=cfg.attn_block_q,
        )
    else:
        out = gqa_attention(
            q, k, v, causal=causal, window=window, global_flag=is_global
        )
    out = jnp.einsum("bshe,hed->bsd", out, p["wo"])
    return out, (k, v)


def _ffn_block(cfg: LMConfig, layer_p: dict, x):
    if cfg.moe is not None:
        return moe_ffn(x, layer_p["moe"], _moe_dims(cfg))
    f = layer_p["ffn"]
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, f["w_gate"]))
    u = jnp.einsum("bsd,df->bsf", x, f["w_up"])
    return jnp.einsum("bsf,fd->bsd", g * u, f["w_down"])


def _layer(cfg: LMConfig, layer_p: dict, x, positions, is_global, *, collect_kv=False):
    h, kv = _attn_block(
        cfg, layer_p["attn"], rms_norm(x, layer_p["ln1"]), positions, is_global
    )
    x = x + h
    x = x + _ffn_block(cfg, layer_p, rms_norm(x, layer_p["ln2"]))
    return x, (kv if collect_kv else None)


# --------------------------------------------------------------------------
# model entry points
# --------------------------------------------------------------------------
def forward(
    params: dict,
    cfg: LMConfig,
    tokens: jax.Array,
    *,
    remat: Any = None,
    unroll: int = 1,
    collect_kv: bool = False,
    last_only: bool = False,
    no_head: bool = False,
):
    """tokens [B,S] -> (logits [B,S,V] fp32, cache pytree | None).
    With no_head=True returns the final hidden states instead of logits."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    flags = jnp.asarray(layer_flags(cfg))

    def _carry_constraint(y):
        if not (cfg.act_batch_axes or cfg.act_seq_axes):
            return y
        from jax.sharding import PartitionSpec as P

        spec = P(
            tuple(cfg.act_batch_axes) or None,
            tuple(cfg.act_seq_axes) or None,
            None,
        )
        return jax.lax.with_sharding_constraint(y, spec)

    def body(carry, xs):
        layer_p, is_global = xs
        y, kv = _layer(cfg, layer_p, carry, positions, is_global, collect_kv=collect_kv)
        return _carry_constraint(y), kv

    if remat is not None:
        body = jax.checkpoint(body, policy=remat)
    x, caches = jax.lax.scan(body, x, (params["layers"], flags), unroll=unroll)
    x = rms_norm(x, params["final_norm"])
    if last_only:
        x = x[:, -1:]  # vLLM-style: prefill only needs the last position
    if no_head:
        return x, caches
    head = params.get("head")
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits.astype(jnp.float32), caches


def streaming_ce_loss(
    x: jax.Array,  # [B,S,d] final hidden (normed)
    head: jax.Array,  # [d,V] (or transposed embed for tied)
    targets: jax.Array,  # [B,S]
    n_chunks: int,
) -> jax.Array:
    """CE via running logsumexp over vocab chunks: the fp32 [B,S,V] logits
    tensor never materializes (peak extra memory = one [B,S,V/n] chunk)."""
    V = head.shape[-1]
    assert V % n_chunks == 0, (V, n_chunks)
    c = V // n_chunks

    def body(carry, i):
        m_prev, s_prev, tgt_prev = carry
        h = jax.lax.dynamic_slice_in_dim(head, i * c, c, axis=1)
        lg = jnp.einsum("bsd,dv->bsv", x, h).astype(jnp.float32)
        m_cur = jnp.maximum(m_prev, lg.max(-1))
        s_cur = s_prev * jnp.exp(m_prev - m_cur) + jnp.exp(
            lg - m_cur[..., None]
        ).sum(-1)
        mine = (targets >= i * c) & (targets < (i + 1) * c)
        idx = jnp.clip(targets - i * c, 0, c - 1)
        tgt_lg = jnp.take_along_axis(lg, idx[..., None], axis=-1)[..., 0]
        tgt_cur = jnp.where(mine, tgt_lg, tgt_prev)
        return (m_cur, s_cur, tgt_cur), None

    B, S, _ = x.shape
    init = (
        jnp.full((B, S), -jnp.inf, jnp.float32),
        jnp.zeros((B, S), jnp.float32),
        jnp.zeros((B, S), jnp.float32),
    )
    (m, s, tgt), _ = jax.lax.scan(
        jax.checkpoint(body), init, jnp.arange(n_chunks)
    )
    return (jnp.log(s) + m - tgt).mean()


def prefill(params: dict, cfg: LMConfig, tokens: jax.Array, *, unroll: int = 1):
    """Returns (last-position logits [B,V], cache dict, cache_len [B])."""
    B, S = tokens.shape
    logits, caches = forward(
        params, cfg, tokens, unroll=unroll, collect_kv=True,
        last_only=cfg.prefill_last_only,
    )
    if cfg.mla is not None:
        cache = {"c_kv": caches[0], "k_rope": caches[1]}
    else:
        cache = {"k": caches[0], "v": caches[1]}
    cache_len = jnp.full((B,), S, jnp.int32)
    return logits[:, -1], cache, cache_len


def decode_step(
    params: dict,
    cfg: LMConfig,
    tokens: jax.Array,  # [B,1]
    cache: dict,  # stacked over layers: [L,B,T,...]
    cache_len: jax.Array,  # [B] current valid length (new token goes here)
    *,
    unroll: int = 1,
):
    """One decode step. Returns (logits [B,V], new_cache, new_cache_len)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = cache_len[:, None]  # [B,1]
    flags = jnp.asarray(layer_flags(cfg))
    window = None if cfg.local_global is None else cfg.local_window

    if cfg.mla is not None:
        m = cfg.mla

        def body(carry, xs):
            layer_p, c_kv_c, k_rope_c, is_global = xs
            h = rms_norm(carry, layer_p["ln1"])
            ckv_full = jnp.einsum("bsd,dr->bsr", h, layer_p["attn"]["w_dkv"])
            new_ckv, new_krope = (
                ckv_full[..., : -m.rope_head_dim],
                ckv_full[..., -m.rope_head_dim :],
            )
            new_krope = apply_rope(new_krope[:, :, None, :], positions, cfg.rope_theta)[
                :, :, 0, :
            ]
            c_kv_c = insert_kv(c_kv_c, new_ckv, cache_len)
            k_rope_c = insert_kv(k_rope_c, new_krope, cache_len)
            out = mla_decode(
                h,
                layer_p["attn"],
                c_kv_c,
                k_rope_c,
                cache_len,
                n_heads=cfg.n_heads,
                nope=m.nope_head_dim,
                rope=m.rope_head_dim,
                v_dim=m.v_head_dim,
                positions=positions,
                theta=cfg.rope_theta,
            )
            out = jnp.einsum("bse,ed->bsd", out, layer_p["attn"]["w_o"])
            y = carry + out
            y = y + _ffn_block(cfg, layer_p, rms_norm(y, layer_p["ln2"]))
            return y, (c_kv_c, k_rope_c)

        x, (c_kv_new, k_rope_new) = jax.lax.scan(
            body,
            x,
            (params["layers"], cache["c_kv"], cache["k_rope"], flags),
            unroll=unroll,
        )
        new_cache = {"c_kv": c_kv_new, "k_rope": k_rope_new}
    else:

        def body(carry, xs):
            layer_p, k_c, v_c, is_global = xs
            ap = layer_p["attn"]
            h = rms_norm(carry, layer_p["ln1"])
            q = jnp.einsum("bsd,dhe->bshe", h, ap["wq"])
            k = jnp.einsum("bsd,dhe->bshe", h, ap["wk"])
            v = jnp.einsum("bsd,dhe->bshe", h, ap["wv"])
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            k_c = insert_kv(k_c, k, cache_len)
            v_c = insert_kv(v_c, v, cache_len)
            out = decode_attention(
                q, k_c, v_c, cache_len, window=window, global_flag=is_global
            )
            out = jnp.einsum("bshe,hed->bsd", out, ap["wo"])
            y = carry + out
            y = y + _ffn_block(cfg, layer_p, rms_norm(y, layer_p["ln2"]))
            return y, (k_c, v_c)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"], flags), unroll=unroll
        )
        new_cache = {"k": k_new, "v": v_new}

    x = rms_norm(x, params["final_norm"])
    head = params.get("head")
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits[:, 0].astype(jnp.float32), new_cache, cache_len + 1


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> dict:
    """Zeroed KV (or MLA latent) cache stacked over layers."""
    L = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((L, batch, max_len, m.kv_lora_rank), cfg.dtype),
            "k_rope": jnp.zeros((L, batch, max_len, m.rope_head_dim), cfg.dtype),
        }
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.dtype),
    }


def prefill_chunk(
    params: dict,
    cfg: LMConfig,
    tokens: jax.Array,  # [B,c]
    cache: dict,  # [L,B,T,...]
    cache_len: jax.Array,  # [B] valid length before this chunk
    *,
    unroll: int = 1,
):
    """Chunked prefill against an existing cache (serving engine / RISP
    prefix reuse): appends c tokens at positions cache_len..cache_len+c-1.
    Returns (last-position logits [B,V], new_cache, new_cache_len)."""
    B, c = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    positions = cache_len[:, None] + jnp.arange(c)[None, :]
    flags = jnp.asarray(layer_flags(cfg))
    window = None if cfg.local_global is None else cfg.local_window

    if cfg.mla is not None:
        m = cfg.mla

        def body(carry, xs):
            layer_p, c_kv_c, k_rope_c, is_global = xs
            h = rms_norm(carry, layer_p["ln1"])
            ckv_full = jnp.einsum("bsd,dr->bsr", h, layer_p["attn"]["w_dkv"])
            new_ckv = ckv_full[..., : -m.rope_head_dim]
            new_krope = apply_rope(
                ckv_full[..., -m.rope_head_dim :][:, :, None, :], positions,
                cfg.rope_theta,
            )[:, :, 0, :]
            c_kv_c = insert_chunk(c_kv_c, new_ckv, cache_len)
            k_rope_c = insert_chunk(k_rope_c, new_krope, cache_len)
            out = mla_decode(
                h, layer_p["attn"], c_kv_c, k_rope_c, cache_len,
                n_heads=cfg.n_heads, nope=m.nope_head_dim, rope=m.rope_head_dim,
                v_dim=m.v_head_dim, positions=positions, theta=cfg.rope_theta,
            )
            out = jnp.einsum("bse,ed->bsd", out, layer_p["attn"]["w_o"])
            y = carry + out
            y = y + _ffn_block(cfg, layer_p, rms_norm(y, layer_p["ln2"]))
            return y, (c_kv_c, k_rope_c)

        x, (ckv_new, krope_new) = jax.lax.scan(
            body, x, (params["layers"], cache["c_kv"], cache["k_rope"], flags),
            unroll=unroll,
        )
        new_cache = {"c_kv": ckv_new, "k_rope": krope_new}
    else:

        def body(carry, xs):
            layer_p, k_c, v_c, is_global = xs
            ap = layer_p["attn"]
            h = rms_norm(carry, layer_p["ln1"])
            q = jnp.einsum("bsd,dhe->bshe", h, ap["wq"])
            k = jnp.einsum("bsd,dhe->bshe", h, ap["wk"])
            v = jnp.einsum("bsd,dhe->bshe", h, ap["wv"])
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            k_c = insert_chunk(k_c, k, cache_len)
            v_c = insert_chunk(v_c, v, cache_len)
            out = decode_attention(
                q, k_c, v_c, cache_len, window=window, global_flag=is_global
            )
            out = jnp.einsum("bshe,hed->bsd", out, ap["wo"])
            y = carry + out
            y = y + _ffn_block(cfg, layer_p, rms_norm(y, layer_p["ln2"]))
            return y, (k_c, v_c)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"], flags), unroll=unroll
        )
        new_cache = {"k": k_new, "v": v_new}

    x = rms_norm(x, params["final_norm"])
    head = params.get("head")
    if head is None:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head)
    return logits[:, -1].astype(jnp.float32), new_cache, cache_len + c
