"""Mixture-of-Experts FFN: shared + routed top-k, GSPMD-friendly dispatch.

TPU adaptation: the grouped GShard/Switch einsum formulation — tokens are
reshaped into groups, a capacity-bounded one-hot dispatch tensor routes each
token to its top-k experts, and expert FFNs run as one stacked einsum over the
expert dimension.  Expert parallelism falls out of sharding the expert dim of
the weights ("experts" logical axis); the dispatch/combine einsums become the
all-to-alls.  Capacity factor bounds the dispatch tensor to O(k*T*g) — linear
in tokens (the ungrouped formulation is quadratic).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from ..configs.base import MoESpec
from .layers import PSpec


@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int
    top_k: int
    n_shared: int
    d_model: int
    d_ff: int
    group_size: int = 1024
    capacity_factor: float = 1.25
    # optional explicit EP annotations (mesh axis names) — forces GSPMD to
    # reshard tokens->experts as an all-to-all at the dispatch boundary
    ep_axis: str | None = None
    token_axes: tuple = ()

    def capacity(self, group_size: int) -> int:
        c = int(math.ceil(self.top_k * group_size / self.n_experts * self.capacity_factor))
        return max(c, 4)


def moe_specs(spec: MoESpec, d_model: int, n_layers: int) -> dict:
    E, f = spec.n_experts, spec.d_ff_expert
    d = d_model
    L = n_layers
    out = {
        "router": PSpec((L, d, E), ("layers", "embed", "experts_r")),
        "w_gate": PSpec((L, E, d, f), ("layers", "experts", "embed", "expert_ff")),
        "w_up": PSpec((L, E, d, f), ("layers", "experts", "embed", "expert_ff")),
        "w_down": PSpec((L, E, f, d), ("layers", "experts", "expert_ff", "embed")),
    }
    if spec.n_shared:
        fs = spec.d_ff_expert * spec.n_shared
        out["shared"] = {
            "w_gate": PSpec((L, d, fs), ("layers", "embed", "ff")),
            "w_up": PSpec((L, d, fs), ("layers", "embed", "ff")),
            "w_down": PSpec((L, fs, d), ("layers", "ff", "embed")),
        }
    return out


def moe_ffn(x: jax.Array, p: dict, dims: MoEDims) -> jax.Array:
    """x: [B,S,d] -> [B,S,d].  p holds one layer's slices (no leading L)."""
    B, S, d = x.shape
    E, K = dims.n_experts, dims.top_k
    g = min(dims.group_size, B * S)
    T = B * S
    assert T % g == 0, (T, g)
    G = T // g
    C = dims.capacity(g)

    xt = x.reshape(G, g, d)
    logits = jnp.einsum("Ggd,dE->GgE", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, K)  # [G,g,K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)  # renorm

    # capacity-bounded positions, priority by k (GShard top-k dispatch)
    dispatch = jnp.zeros((G, g, E, C), dtype=x.dtype)
    combine = jnp.zeros((G, g, E, C), dtype=x.dtype)
    prior_count = jnp.zeros((G, 1, E), dtype=jnp.int32)
    for k in range(K):
        mask_k = jax.nn.one_hot(idx[..., k], E, dtype=jnp.int32)  # [G,g,E]
        pos_k = jnp.cumsum(mask_k, axis=1) - 1 + prior_count  # [G,g,E]
        prior_count = prior_count + mask_k.sum(axis=1, keepdims=True)
        keep = (pos_k < C) & (mask_k > 0)
        oh = jax.nn.one_hot(jnp.where(keep, pos_k, C), C, dtype=x.dtype)
        d_k = oh * keep.astype(x.dtype)[..., None]  # [G,g,E,C]
        dispatch = dispatch + d_k
        combine = combine + d_k * gates[..., k, None, None].astype(x.dtype)

    def _ep_constraint(t):
        if dims.ep_axis is None:
            return t
        from jax.sharding import PartitionSpec as P

        spec = P(dims.ep_axis, dims.token_axes or None, *([None] * (t.ndim - 2)))
        return jax.lax.with_sharding_constraint(t, spec)

    expert_in = _ep_constraint(jnp.einsum("GgEC,Ggd->EGCd", dispatch, xt))
    h = jax.nn.silu(jnp.einsum("EGCd,Edf->EGCf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("EGCd,Edf->EGCf", expert_in, p["w_up"])
    expert_out = _ep_constraint(jnp.einsum("EGCf,Efd->EGCd", h, p["w_down"]))
    y = jnp.einsum("GgEC,EGCd->Ggd", combine, expert_out)

    out = y.reshape(B, S, d)
    if "shared" in p:
        sp = p["shared"]
        gsh = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sp["w_gate"]))
        ush = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
        out = out + jnp.einsum("bsf,fd->bsd", gsh * ush, sp["w_down"])
    return out


def aux_load_balance_loss(logits: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    density = jnp.mean(
        jax.nn.one_hot(idx[..., 0], n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    density_proxy = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return n_experts * jnp.sum(density * density_proxy)
