"""Pure-JAX AdamW with global-norm clipping and cosine/warmup schedule."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def apply_updates(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig
) -> tuple[Any, dict, dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** step.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    unf = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
    new_state = {"mu": unf(new_mu), "nu": unf(new_nu), "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return unf(new_p), new_state, metrics
