"""Compiled-artifact analysis: HLO collective parsing + roofline terms.

Hardware model (TPU v5e target):
  peak bf16 compute 197 TFLOP/s/chip, HBM 819 GB/s/chip, ICI ~50 GB/s/link.

Scan caveat: XLA cost analysis counts a ``while`` (scan) body ONCE.  Callers
lower each step twice (unroll=1 -> fixed+body, unroll=2 -> fixed+2*body) and
use ``scan_correct`` to report fixed + L*body.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link (per the brief's formula: chips x link_bw)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
    r"([a-z][\w\-]*)\((.*)\)", re.ASCII
)
COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    """Per-chip bytes moved over ICI, by collective kind."""

    bytes_by_kind: dict[str, float] = field(default_factory=dict)
    count_by_kind: dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def add(self, kind: str, nbytes: float) -> None:
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + nbytes
        self.count_by_kind[kind] = self.count_by_kind.get(kind, 0) + 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-chip ICI traffic from post-SPMD HLO.

    Cost model per op (ring algorithms, (N-1)/N ~ 1):
      all-reduce:          2 x result bytes
      all-gather:          result - operands (received shards)
      reduce-scatter:      operands - result
      all-to-all:          operand bytes
      collective-permute:  operand bytes
    """
    # first pass: result bytes of every named instruction
    sizes: dict[str, int] = {}
    instrs: list[tuple[str, str, str, str]] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, args = m.groups()
        sizes[name] = _type_bytes(type_str)
        if any(opcode.startswith(c) for c in COLLECTIVES):
            instrs.append((name, type_str, opcode, args))

    stats = CollectiveStats()
    for name, type_str, opcode, args in instrs:
        result_b = sizes[name]
        opnames = re.findall(r"%?([\w.\-]+)", args)
        operand_b = sum(sizes.get(o, 0) for o in opnames if o in sizes)
        kind = next(c for c in COLLECTIVES if opcode.startswith(c))
        if kind == "all-reduce":
            moved = 2.0 * result_b
        elif kind == "all-gather":
            moved = max(result_b - operand_b, result_b // 2)
        elif kind == "reduce-scatter":
            moved = max(operand_b - result_b, result_b)
        else:  # all-to-all, collective-permute
            moved = operand_b or result_b
        stats.add(kind, float(moved))
    return stats


def scan_correct(q1: float, q2: float, n_layers: int) -> float:
    """fixed+body, fixed+2*body -> fixed + L*body."""
    body = max(q2 - q1, 0.0)
    return q1 + (n_layers - 1) * body


@dataclass
class RooflineTerms:
    flops: float  # per-chip HLO flops for one step
    hbm_bytes: float  # per-chip bytes accessed
    coll_bytes: float  # per-chip ICI bytes
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self, model_flops_per_chip: float) -> float:
        """Useful-FLOPs throughput / peak — the MFU-at-roofline score."""
        if self.step_time_s == 0:
            return 0.0
        return (model_flops_per_chip / self.step_time_s) / PEAK_FLOPS

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
        }


# --------------------------------------------------------------------------
# analytic MODEL_FLOPS per family (the "useful compute" numerator)
# --------------------------------------------------------------------------
def model_flops(cfg, cell) -> float:
    """Global useful FLOPs for one step of (cfg, cell)."""
    from ..configs.base import GNNConfig, LMConfig, RecsysConfig

    if isinstance(cfg, LMConfig):
        n_active = cfg.n_active_params()
        p = cell.params
        B, S = p["global_batch"], p["seq_len"]
        if cell.kind == "train":
            # 6*N*D + causal attention 6*L*B*S^2*(Hq*dh) (12*.. * 0.5 causal)
            attn = 6 * cfg.n_layers * B * S * S * cfg.n_heads * cfg.head_dim
            if cfg.local_global is not None:
                n_loc, n_glob = cfg.local_global
                w = min(cfg.local_window, S)
                frac = (n_loc * (w / S) + n_glob) / (n_loc + n_glob)
                attn *= frac
            return 6.0 * n_active * B * S + attn
        if cell.kind == "prefill":
            attn = 3 * cfg.n_layers * B * S * S * cfg.n_heads * cfg.head_dim
            if cfg.local_global is not None:
                n_loc, n_glob = cfg.local_global
                w = min(cfg.local_window, S)
                frac = (n_loc * (w / S) + n_glob) / (n_loc + n_glob)
                attn *= frac
            return 2.0 * n_active * B * S + attn
        # decode: one token per sequence
        if cfg.mla is not None:
            r = cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim
            attn = 4.0 * cfg.n_layers * B * S * r * cfg.n_heads / cfg.n_heads
            attn = 4.0 * cfg.n_layers * B * S * (r + cfg.mla.kv_lora_rank)
        else:
            attn = 4.0 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.head_dim
        if cfg.local_global is not None:
            n_loc, n_glob = cfg.local_global
            w = min(cfg.local_window, S)
            frac = (n_loc * (w / S) + n_glob) / (n_loc + n_glob)
            attn *= frac
        return 2.0 * n_active * B + attn
    if isinstance(cfg, GNNConfig):
        p = cell.params
        d = cfg.d_hidden
        if cell.kind == "batched_graphs":
            n = p["batch"] * p["n_nodes"]
            e = p["batch"] * p["n_edges"]
        elif cell.kind == "minibatch":
            n = p["batch_nodes"] * (1 + p["fanout1"] + p["fanout1"] * p["fanout2"])
            e = p["batch_nodes"] * (p["fanout1"] + p["fanout1"] * p["fanout2"])
        else:
            n, e = p["n_nodes"], p["n_edges"]
        per_layer = 2 * n * d * d * 2 + 2 * e * d * d * 3 + 8 * e * d
        fwd = cfg.n_layers * per_layer + 2 * n * p.get("d_feat", 16) * d
        return 3.0 * fwd  # train: fwd + bwd
    if isinstance(cfg, RecsysConfig):
        p = cell.params
        B = p["batch"]
        d = cfg.embed_dim
        fwd = 0.0
        if cfg.interaction == "fm-2way":
            fwd = 2.0 * B * cfg.n_sparse * d
        elif cfg.interaction == "cross":
            d0 = cfg.n_dense + cfg.n_sparse * d
            fwd = cfg.n_cross_layers * 2 * B * d0 * d0
            dims = [d0] + list(cfg.mlp) + [1]
            fwd += sum(2 * B * a * b for a, b in zip(dims, dims[1:]))
        elif cfg.interaction in ("transformer-seq", "self-attn-seq"):
            S = cfg.seq_len + (1 if cfg.interaction == "transformer-seq" else 0)
            per_block = 8 * S * d * d + 4 * S * S * d + 16 * S * d * d
            fwd = B * max(cfg.n_blocks, 1) * per_block
            if cfg.mlp:
                d_in = S * d
                dims = [d_in] + list(cfg.mlp) + [1]
                fwd += sum(2 * B * a * b for a, b in zip(dims, dims[1:]))
        if cell.kind == "retrieval":
            fwd += 2.0 * p["n_candidates"] * d * B
        mult = 3.0 if cell.kind == "train" else 1.0
        return mult * fwd
    raise TypeError(type(cfg))
