"""Production training driver: --arch config + mesh + fault-tolerant loop.

On a real pod this runs per-host under jax.distributed; here it drives the
same code on the local device (use --smoke for CI-scale configs).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --smoke \
        --steps 20 --batch 4 --seq 64
"""
from __future__ import annotations

import argparse
import tempfile

import numpy as np

import jax

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.ckpt import CheckpointManager
from repro.models.layers import init_params, param_count
from repro.optim import AdamWConfig
from repro.runtime import TrainDriver
from repro.train import build_param_specs, build_train_step, make_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--fail-at", type=int, default=None, help="inject failure")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family != "lm":
        raise SystemExit("train.py drives LM archs; see examples/ for others")
    cell = ShapeCell("train", "train", {"seq_len": args.seq, "global_batch": args.batch})
    specs = build_param_specs(cfg, cell)
    print(f"arch={cfg.name} params={param_count(specs)/1e6:.1f}M")

    params = init_params(jax.random.PRNGKey(0), specs, cfg.dtype)
    state = make_train_state(params)
    step_fn = build_train_step(
        cfg,
        cell,
        AdamWConfig(warmup_steps=10, total_steps=args.steps),
        remat=args.remat,
        grad_accum=args.grad_accum,
    )

    def make_batch(step: int) -> dict:
        r = np.random.default_rng(step)
        toks = r.integers(0, cfg.vocab, size=(args.batch, args.seq + 1))
        return {
            "tokens": jax.numpy.asarray(toks[:, :-1], jax.numpy.int32),
            "targets": jax.numpy.asarray(toks[:, 1:], jax.numpy.int32),
        }

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp()
    driver = TrainDriver(
        train_step=step_fn,
        make_batch=make_batch,
        ckpt=CheckpointManager(ckpt_dir, keep=3, async_save=True),
        ckpt_every=args.ckpt_every,
        fail_at_steps=(args.fail_at,) if args.fail_at else (),
    )
    state, log = driver.run(state, args.steps)
    losses = [e["loss"] for e in log if "loss" in e]
    print(f"done: {args.steps} steps, loss {losses[0]:.3f} -> {losses[-1]:.3f}, "
          f"ckpts in {ckpt_dir}")


if __name__ == "__main__":
    main()
