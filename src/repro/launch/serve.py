"""Serving driver: RISP-prefix-cache engine over a request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --smoke \
        --requests 8
"""
from __future__ import annotations

import argparse

import numpy as np

import jax

from repro.configs import get_config
from repro.configs.base import ShapeCell
from repro.models.layers import init_params
from repro.serve import ServeEngine
from repro.train import build_param_specs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--system-len", type=int, default=48)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    cell = ShapeCell("s", "train", {"seq_len": 16, "global_batch": 1})
    params = init_params(
        jax.random.PRNGKey(0), build_param_specs(cfg, cell), cfg.dtype
    )
    engine = ServeEngine(cfg, params, max_len=args.max_len, chunk=args.chunk)

    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab, size=args.system_len).tolist()
    tot_prefill = tot_skipped = tot_chunks = 0
    for i in range(args.requests):
        user = rng.integers(0, cfg.vocab, size=12).tolist()
        _, st = engine.generate(system + user, max_new_tokens=args.max_new)
        tot_prefill += st.prefill_s
        tot_skipped += st.chunks_skipped
        tot_chunks += st.n_chunks
        print(f"req {i}: skipped {st.chunks_skipped}/{st.n_chunks} chunks, "
              f"prefill {st.prefill_s*1e3:.1f} ms, decode {st.decode_s*1e3:.1f} ms")
    print(f"total: prefill {tot_prefill:.2f}s, chunks skipped "
          f"{tot_skipped}/{tot_chunks}, snapshots {engine.n_snapshots}")


if __name__ == "__main__":
    main()
