import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
import sys  # noqa: E402

if "--smoke-mesh" in sys.argv:  # tiny mesh for CI-scale tests
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, get_shapes, list_archs  # noqa: E402
from repro.configs.base import GNNConfig, LMConfig, ShapeCell  # noqa: E402
from repro.launch import analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh, make_smoke_mesh  # noqa: E402
from repro.launch.sharding import rules_for, shard_input_specs, tree_shardings  # noqa: E402
from repro.models.layers import abstract_params, logical_axes, param_count  # noqa: E402
from repro.train import build_param_specs, build_serve_step, build_train_step  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _n_scan_layers(cfg) -> int:
    if isinstance(cfg, LMConfig):
        return cfg.n_layers
    if isinstance(cfg, GNNConfig):
        return cfg.n_layers
    return getattr(cfg, "n_blocks", 1) or 1


def _abstract_state(cfg, cell, mesh):
    specs = build_param_specs(cfg, cell)
    axes = logical_axes(specs)
    rules = rules_for(cfg)
    shardings = tree_shardings(axes, specs, mesh, rules)
    dtype = cfg.dtype
    params_sds = abstract_params(specs, dtype, shardings)
    n_params = param_count(specs)
    return specs, params_sds, shardings, n_params


def _opt_state_sds(params_sds):
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=s.sharding)
    return {
        "mu": jax.tree_util.tree_map(f32, params_sds),
        "nu": jax.tree_util.tree_map(f32, params_sds),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def apply_cfg_overrides(cfg, overrides: list[str]):
    """--cfg key=value (python literals) -> dataclasses.replace on the config."""
    import ast

    kw = {}
    for ov in overrides or ():
        key, _, val = ov.partition("=")
        try:
            kw[key] = ast.literal_eval(val)
        except (ValueError, SyntaxError):
            kw[key] = val
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis(): dict in jax >= 0.5, [dict] (per device) before."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def lower_cell(
    cfg,
    cell: ShapeCell,
    mesh,
    *,
    unroll: int = 1,
    remat: str = "none",
    grad_accum: int = 1,
):
    """Build + lower one (arch x shape) cell on a mesh. Returns lowered."""
    _, params_sds, _, _ = _abstract_state(cfg, cell, mesh)
    in_sds = shard_input_specs(cfg, cell, mesh)
    from .mesh import mesh_context

    with mesh_context(mesh):
        if cell.kind in ("train", "full_graph", "minibatch", "batched_graphs"):
            step = build_train_step(
                cfg, cell, remat=remat, unroll=unroll, grad_accum=grad_accum
            )
            state_sds = {"params": params_sds, "opt": _opt_state_sds(params_sds)}
            return jax.jit(step, donate_argnums=0).lower(state_sds, in_sds)
        step = build_serve_step(cfg, cell, unroll=unroll)
        if cell.kind == "decode":
            return jax.jit(step, donate_argnums=2).lower(
                params_sds, in_sds["tokens"], in_sds["cache"], in_sds["cache_len"]
            )
        return jax.jit(step).lower(params_sds, **in_sds)


def run_cell(
    arch: str,
    cell: ShapeCell,
    *,
    multi_pod: bool,
    smoke_mesh: bool = False,
    unroll: int = 1,
    remat: str = "none",
    grad_accum: int = 1,
    scan_corrected: bool = True,
    tag: str = "",
    cfg_overrides: list[str] | None = None,
) -> dict:
    cfg = apply_cfg_overrides(get_config(arch), cfg_overrides or [])
    mesh = (
        make_smoke_mesh(multi_pod=multi_pod)
        if smoke_mesh
        else make_production_mesh(multi_pod=multi_pod)
    )
    chips = mesh.devices.size
    mesh_name = ("multipod" if multi_pod else "pod") + ("-smoke" if smoke_mesh else "")
    rec: dict = {
        "arch": arch,
        "shape": cell.name,
        "kind": cell.kind,
        "mesh": mesh_name,
        "chips": int(chips),
        "remat": remat,
        "unroll": unroll,
        "grad_accum": grad_accum,
        "tag": tag,
        "cfg_overrides": list(cfg_overrides or []),
    }
    if (
        isinstance(cfg, LMConfig)
        and cell.name == "long_500k"
        and not cfg.sub_quadratic
    ):
        rec["status"] = "skipped"
        rec["reason"] = "pure full-attention arch; 500k dense KV excluded (DESIGN §4)"
        return rec

    t0 = time.time()
    try:
        lowered = lower_cell(
            cfg, cell, mesh, unroll=unroll, remat=remat, grad_accum=grad_accum
        )
        compiled = lowered.compile()
    except Exception as e:  # noqa: BLE001
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        return rec
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = _cost_dict(compiled)
    hlo = compiled.as_text()
    coll = analysis.parse_collectives(hlo)

    flops1 = float(ca.get("flops", 0.0))
    bytes1 = float(ca.get("bytes accessed", 0.0))
    coll1 = coll.total_bytes

    L = _n_scan_layers(cfg)
    corrected = False
    if scan_corrected and L >= 2 and L % 2 == 0 and unroll == 1:
        try:
            lowered2 = lower_cell(
                cfg, cell, mesh, unroll=2, remat=remat, grad_accum=grad_accum
            )
            compiled2 = lowered2.compile()
            ca2 = _cost_dict(compiled2)
            coll2 = analysis.parse_collectives(compiled2.as_text())
            flops = analysis.scan_correct(flops1, float(ca2.get("flops", 0.0)), L)
            hbm = analysis.scan_correct(bytes1, float(ca2.get("bytes accessed", 0.0)), L)
            cbytes = analysis.scan_correct(coll1, coll2.total_bytes, L)
            corrected = True
        except Exception:  # noqa: BLE001 - fall back to uncorrected
            flops, hbm, cbytes = flops1, bytes1, coll1
    else:
        flops, hbm, cbytes = flops1, bytes1, coll1

    terms = analysis.RooflineTerms(
        flops=flops, hbm_bytes=hbm, coll_bytes=cbytes, chips=chips
    )
    mflops = analysis.model_flops(cfg, cell)
    mflops_chip = mflops / chips

    rec.update(
        status="ok",
        compile_s=round(t_compile, 2),
        scan_corrected=corrected,
        n_layers=L,
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_hbm_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        roofline=terms.as_dict(),
        collectives={
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
        },
        model_flops_global=mflops,
        model_flops_per_chip=mflops_chip,
        useful_flops_ratio=(mflops_chip / flops) if flops else None,
        roofline_fraction=terms.roofline_fraction(mflops_chip),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile cells")
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape cell name (default: all)")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--smoke-mesh", action="store_true", help="8-device test mesh")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--unroll", type=int, default=1)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--no-scan-correct", action="store_true")
    ap.add_argument(
        "--cfg", action="append", default=[],
        help="config override key=value (python literal), repeatable",
    )
    ap.add_argument("--tag", default="", help="experiment tag for §Perf iterations")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    out_dir = Path(args.out) if args.out else RESULTS_DIR
    out_dir.mkdir(parents=True, exist_ok=True)

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for cell in get_shapes(arch):
            if args.shape and cell.name != args.shape:
                continue
            for mp in meshes:
                rec = run_cell(
                    arch,
                    cell,
                    multi_pod=mp,
                    smoke_mesh=args.smoke_mesh,
                    unroll=args.unroll,
                    remat=args.remat,
                    grad_accum=args.grad_accum,
                    scan_corrected=not args.no_scan_correct,
                    tag=args.tag,
                    cfg_overrides=args.cfg,
                )
                suffix = f"__{args.tag}" if args.tag else ""
                fname = f"{arch}__{cell.name}__{rec['mesh']}{suffix}.json"
                (out_dir / fname).write_text(json.dumps(rec, indent=1))
                status = rec["status"]
                n_ok += status == "ok"
                n_fail += status == "failed"
                n_skip += status == "skipped"
                if status == "ok":
                    r = rec["roofline"]
                    print(
                        f"[ok] {arch:18s} {cell.name:13s} {rec['mesh']:13s} "
                        f"compile={rec['compile_s']:7.1f}s peak_hbm="
                        f"{rec['memory']['peak_hbm_bytes']/2**30:7.2f}GiB "
                        f"dom={r['dominant']:10s} step={r['step_time_s']*1e3:9.3f}ms "
                        f"RF={rec['roofline_fraction']:.3f}",
                        flush=True,
                    )
                elif status == "skipped":
                    print(f"[skip] {arch:18s} {cell.name:13s} {rec['reason']}", flush=True)
                else:
                    print(
                        f"[FAIL] {arch:18s} {cell.name:13s} {rec['mesh']:13s} "
                        f"{rec['error']}",
                        flush=True,
                    )
    print(f"dry-run complete: ok={n_ok} failed={n_fail} skipped={n_skip}")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
