"""Logical-axis -> mesh-axis resolution with divisibility-aware fallback.

Every param/input dim carries a logical axis name (models/layers.PSpec); the
rules below map names to candidate mesh axes in priority order.  A candidate
is taken only if (a) its mesh axes are unused by this array and (b) its total
way-count divides the dim.  This realizes the DESIGN §5 policies mechanically:

  * gemma3-4b: 8 heads fail 16-way "model" -> the head_dim entry picks it up
  * qwen2-moe: 60 experts fail -> per-expert d_ff ("expert_ff") takes "model"
  * deepseek-v2: config overrides route "experts" to the data axis (EP) while
    "expert_ff" keeps "model"
"""
from __future__ import annotations

import math
from typing import Any, Mapping

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import (
    Config,
    GNNConfig,
    LMConfig,
    RecsysConfig,
    ShapeCell,
)

Cand = tuple  # tuple of mesh-axis names used jointly

# name -> candidates in priority order; each candidate is a tuple of mesh axes
DEFAULT_RULES: dict[str, tuple[Cand, ...]] = {
    # data-ish dims
    "batch": (("pod", "data"), ("data",)),
    "nodes": (("pod", "data"), ("data",)),
    "edges": (("pod", "data"), ("data",)),
    "candidates": (("pod", "data"), ("data",)),
    "kv_seq": (("data",), ("pod", "data")),
    # tensor-parallel dims
    "vocab": (("model",),),
    "ff": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    # NOTE: no head_dim fallback — sharding within a head mismatches the
    # q-side (heads) sharding and triggers involuntary SPMD remat copies;
    # replicating non-divisible (small) KV projections is strictly better.
    "head_dim": (),
    "qkv": (("model",),),
    "experts": (("model",),),
    "expert_ff": (("model",),),
    "table_vocab": (("model",),),
    "mlp_hidden": (("model",),),
    # attention weight storage dims: replicated by default ("heads" carries
    # the TP); overridden per-arch when heads don't divide the model axis
    "attn_in": (),
    "attn_out": (),
    # replicated dims
    "experts_r": (),
    "embed": (),
    "embed_dim": (),
    "seq": (),
    "layers": (),
    "q_lora": (),
    "kv_lora": (),
    "hidden": (),
    "classes": (),
    "node_feat": (),
    "edge_feat": (),
    "mlp_in": (),
    "x0": (),
}


def rules_for(cfg: Config) -> dict[str, tuple[Cand, ...]]:
    rules = dict(DEFAULT_RULES)
    for name, axes in getattr(cfg, "shard_overrides", ()) or ():
        # overrides REPLACE the rule: empty axes means force-replicate
        rules[name] = (tuple(axes),) if axes else ()
    return rules


def resolve_spec(
    axes: tuple[str | None, ...],
    shape: tuple[int, ...],
    mesh: jax.sharding.Mesh,
    rules: Mapping[str, tuple[Cand, ...]],
) -> P:
    used: set[str] = set()
    out: list[Any] = []
    for name, dim in zip(axes, shape):
        assigned = None
        if name is not None:
            for cand in rules.get(name, ()):
                cand = tuple(cand)
                if not cand:
                    continue
                if any(c in used or c not in mesh.shape for c in cand):
                    continue
                ways = math.prod(mesh.shape[c] for c in cand)
                if ways <= 1 or dim % ways != 0:
                    continue
                assigned = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        out.append(assigned)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(
    axes_tree: Any, shape_tree: Any, mesh: jax.sharding.Mesh, rules
) -> Any:
    """axes_tree: pytree of axis-tuples; shape_tree: matching pytree of
    shaped objects (PSpec / ShapeDtypeStruct / arrays)."""

    def one(axes, shaped):
        return NamedSharding(mesh, resolve_spec(axes, shaped.shape, mesh, rules))

    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x
    )
    return jax.tree_util.tree_map(one, axes_tree, shape_tree, is_leaf=is_axes)


# --------------------------------------------------------------------------
# input logical axes per family/cell (mirrors configs.base.input_specs)
# --------------------------------------------------------------------------
def input_axes(cfg: Config, cell: ShapeCell) -> dict[str, Any]:
    if isinstance(cfg, LMConfig):
        if cell.kind == "train":
            return {"tokens": ("batch", "seq"), "targets": ("batch", "seq")}
        if cell.kind == "prefill":
            return {"tokens": ("batch", "seq")}
        if cell.kind == "decode":
            if cfg.mla is not None:
                cache = {
                    "c_kv": ("layers", "batch", "kv_seq", "kv_lora"),
                    "k_rope": ("layers", "batch", "kv_seq", None),
                }
            else:
                cache = {
                    "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                    "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                }
            return {"tokens": ("batch", None), "cache": cache, "cache_len": ("batch",)}
    if isinstance(cfg, GNNConfig):
        if cell.kind == "full_graph":
            return {
                "node_feat": ("nodes", None),
                "edge_index": (None, "edges"),
                "labels": ("nodes",),
                "train_mask": ("nodes",),
            }
        if cell.kind == "minibatch":
            return {
                "node_feat": ("nodes", None),
                "edge_index": (None, "edges"),
                "labels": ("batch",),
                "seed_ids": ("batch",),
            }
        if cell.kind == "batched_graphs":
            return {
                "node_feat": ("batch", None, None),
                "edge_index": ("batch", None, None),
                "labels": ("batch",),
            }
    if isinstance(cfg, RecsysConfig):
        base = {
            "dense": ("batch", None),
            "sparse_ids": ("batch", None),
            "hist_ids": ("batch", None),
            "target_id": ("batch",),
            "pos_ids": ("batch",),
            "neg_ids": ("batch",),
            "labels": ("batch",),
            "candidate_ids": ("candidates",),
        }
        from ..configs.base import input_specs

        return {k: base[k] for k in input_specs(cfg, cell)}
    raise TypeError((type(cfg), cell.kind))


def shard_input_specs(
    cfg: Config, cell: ShapeCell, mesh: jax.sharding.Mesh
) -> dict[str, Any]:
    """input_specs with NamedShardings attached (ready for .lower())."""
    from ..configs.base import input_specs

    rules = rules_for(cfg)
    specs = input_specs(cfg, cell)
    axes = input_axes(cfg, cell)

    def attach(spec, ax):
        if isinstance(spec, dict):
            return {k: attach(spec[k], ax[k]) for k in spec}
        sh = NamedSharding(mesh, resolve_spec(ax, spec.shape, mesh, rules))
        return jax.ShapeDtypeStruct(spec.shape, spec.dtype, sharding=sh)

    return {k: attach(specs[k], axes[k]) for k in specs}
