"""Production mesh construction (as a function: no import-time device state).

Single pod: 16x16 = 256 chips -> ("data", "model")
Multi-pod:  2x16x16 = 512 chips -> ("pod", "data", "model")
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False, devices=None) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    if devices is None:
        devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py sets it)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_smoke_mesh(*, multi_pod: bool = False, devices=None) -> jax.sharding.Mesh:
    """Tiny mesh for CI-scale dry-run smoke tests (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    if devices is None:
        devices = jax.devices()
    return jax.make_mesh(shape, axes, devices=devices[:n])


def mesh_context(mesh: jax.sharding.Mesh):
    """``jax.set_mesh(mesh)`` where available (jax >= 0.5); otherwise enter the
    Mesh directly (the pre-0.5 ambient-mesh context manager)."""
    set_mesh = getattr(jax, "set_mesh", None) or getattr(
        jax.sharding, "use_mesh", None
    )
    return set_mesh(mesh) if set_mesh is not None else mesh
