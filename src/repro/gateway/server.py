"""``GatewayServer`` — the workflow fabric's HTTP front door.

A dependency-light threaded HTTP/JSON service (stdlib ``http.server``, the
same no-framework discipline as ``repro.net``) that turns the in-process
``repro.api.Client`` into a multi-tenant network surface:

  * ``POST /v1/workflows``        — submit a serialized ``WorkflowSpec``
    (they JSON-round-trip with canonical digests) plus its input data;
    202 + run id, or synchronous completion with ``"wait": true``.
  * ``GET  /v1/runs/{id}``        — run status + result summary.
  * ``GET  /v1/runs/{id}/events`` — chunked NDJSON progress stream
    (accepted → started → finished/failed).
  * ``GET  /v1/recommend``        — the Ch. 4 recommendation surface over
    the caller's visible namespaces.
  * ``GET  /v1/artifacts``        — provenance-catalog browse
    (``?module=&param.k=&dataset=&namespace=``), scoped to one visible
    namespace per query (private by default, ``shared`` on request).
  * ``GET  /v1/stats``            — fabric aggregate + the caller's ledger.
  * ``GET  /healthz``             — unauthenticated liveness/drain probe.

Every submission is authenticated (bearer token → tenant), resolved into
exactly one artifact namespace (private by default, opt-in ``shared`` —
see :mod:`repro.gateway.tenancy`), and admitted against two budgets
(per-tenant quotas here, the service-wide pending bound in
``WorkflowService``).  Saturation is an explicit structured ``429`` with
``Retry-After`` — accepted runs are never dropped, rejected runs are never
queued.  SIGTERM-style shutdown is two-phase: :meth:`begin_shutdown` makes
every new submission a ``503`` while in-flight runs drain, then
:meth:`close` waits them out and stops the listener.
"""
from __future__ import annotations

import json
import secrets
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Mapping
from urllib.parse import parse_qs, urlparse

from ..api.client import Client
from ..api.recommend import RecommendReport
from ..api.spec import SpecError, WorkflowSpec
from ..core.registry import ToolStateError, UnknownModuleError
from ..obs import tracing as _tracing
from ..obs.logging import get_logger
from ..obs.metrics import render_prometheus
from ..sched.scheduler import DagRunResult
from ..sched.service import AdmissionRejected, ServiceClosed
from ..sched.stats import TenantLedger
from .admission import AdmissionController, QuotaExceeded
from .auth import AuthError, TokenAuthenticator
from .tenancy import NamespaceDenied, TenancyPolicy

DEFAULT_PORT = 8707
DEFAULT_MAX_BODY_BYTES = 1 << 20  # 1 MiB of JSON is a very large workflow
_EVENT_STREAM_MAX_S = 300.0
_WAIT_MAX_S = 300.0
_MAX_RUNS_TRACKED = 10_000

_log = get_logger("gateway")


class _ApiError(Exception):
    """Internal: carries an HTTP status + structured body to the handler."""

    def __init__(
        self,
        status: int,
        error: str,
        message: str,
        headers: Mapping[str, str] | None = None,
    ) -> None:
        super().__init__(message)
        self.status = status
        self.error = error
        self.message = message
        self.headers = dict(headers or {})


class RunHandle:
    """Gateway-side state of one submitted run: status, events, result."""

    __slots__ = (
        "run_id", "tenant", "namespace", "digest", "created_at",
        "status", "events", "cond", "summary", "error", "trace_id",
    )

    def __init__(self, run_id: str, tenant: str, namespace: str, digest: str) -> None:
        self.run_id = run_id
        self.tenant = tenant
        self.namespace = namespace
        self.digest = digest
        self.created_at = time.time()
        self.status = "pending"  # pending | running | done | failed
        self.events: list[dict[str, Any]] = []
        self.cond = threading.Condition()
        self.summary: dict[str, Any] | None = None
        self.error: str | None = None
        self.trace_id: str | None = None

    def add_event(self, event: str, **fields: Any) -> None:
        doc = {"event": event, "run_id": self.run_id, "ts": time.time(), **fields}
        with self.cond:
            self.events.append(doc)
            self.cond.notify_all()

    @property
    def terminal(self) -> bool:
        return self.status in ("done", "failed")

    def describe(self) -> dict[str, Any]:
        doc: dict[str, Any] = {
            "run_id": self.run_id,
            "status": self.status,
            "namespace": self.namespace,
            "digest": self.digest,
        }
        if self.trace_id is not None:
            doc["trace_id"] = self.trace_id
        if self.summary is not None:
            doc["result"] = self.summary
        if self.error is not None:
            doc["error"] = self.error
        return doc


def _json_safe(value: Any) -> Any:
    """``value`` if it serializes as JSON, else a type placeholder — run
    outputs may be arrays/pytrees that have no JSON form."""
    try:
        json.dumps(value)
        return value
    except (TypeError, ValueError):
        return f"<unserializable: {type(value).__name__}>"


def _summarize(result: DagRunResult) -> dict[str, Any]:
    return {
        "n_nodes": len(result.module_seconds),
        "n_computed": result.n_computed,
        "n_skipped": result.n_skipped,
        "stored_keys": list(result.stored_keys),
        "total_seconds": result.total_seconds,
        "singleflight_waits": result.singleflight_waits,
        "reused_prefix_depth": (
            result.reused_prefix.depth if result.reused_prefix is not None else 0
        ),
        "output": _json_safe(result.output),
    }


def _report_doc(report: RecommendReport) -> dict[str, Any]:
    def sug(s: Any) -> dict[str, Any]:
        doc = {
            "kind": s.kind,
            "modules": [m.module_id for m in s.prefix.modules],
            "depth": s.depth,
            "support": s.support,
            "confidence": s.confidence,
            "stored": s.stored,
            "module_id": s.module_id,
        }
        if s.note:
            doc["note"] = s.note
        return doc

    return {
        "dataset_id": report.dataset_id,
        "depth": report.depth,
        "reusable_prefixes": [sug(s) for s in report.reusable_prefixes],
        "next_modules": [sug(s) for s in report.next_modules],
        "near_misses": [sug(s) for s in report.near_misses],
    }


def _artifact_doc(rec: Any) -> dict[str, Any]:
    """One catalog record as the wire shape of ``GET /v1/artifacts``."""
    return {
        "key": rec.key,
        "namespace": rec.namespace,
        "dataset": rec.dataset,
        "modules": list(rec.modules),
        "params": [rec.params(i) for i in range(rec.depth)],
        "depth": rec.depth,
        "nbytes": rec.nbytes,
        "compute_s": rec.compute_s,
        "created_at": rec.created_at,
        "last_used_at": rec.last_used_at,
        "n_loads": rec.n_loads,
    }


class GatewayServer:
    """Multi-tenant HTTP front door over one :class:`repro.api.Client`.

    The client (and therefore the store, policy, registry, and scheduler)
    is shared across every tenant — that is the design: one intermediate-data
    fabric, namespaced keys for isolation, shared-namespace keys for
    cross-tenant reuse.  The caller owns the client's lifecycle unless
    ``own_client=True`` (the CLI sets it).
    """

    def __init__(
        self,
        client: Client,
        auth: TokenAuthenticator,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        tenancy: TenancyPolicy | None = None,
        max_body_bytes: int = DEFAULT_MAX_BODY_BYTES,
        max_inflight_per_tenant: int | None = None,
        max_bytes_per_tenant: int | None = None,
        retry_after_s: float = 1.0,
        own_client: bool = False,
    ) -> None:
        if len(auth) == 0:
            raise ValueError(
                "refusing to start an unauthenticated gateway: register at "
                "least one token"
            )
        self.client = client
        self.auth = auth
        self.tenancy = tenancy if tenancy is not None else TenancyPolicy()
        self.host = host
        self.port = port
        self.max_body_bytes = max_body_bytes
        self.retry_after_s = retry_after_s
        self.ledger = TenantLedger()
        self.admission = AdmissionController(
            self.ledger,
            max_inflight_per_tenant=max_inflight_per_tenant,
            max_bytes_per_tenant=max_bytes_per_tenant,
            retry_after_s=retry_after_s,
        )
        self._own_client = own_client
        self._runs_lock = threading.Lock()
        self._runs: dict[str, RunHandle] = {}
        self._draining = False
        self._closed = False
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None
        # the gateway shares the client's registry — one metrics home for
        # the whole process; GET /metrics renders it merged with the
        # server-side registries of the mounted pool
        self.metrics = client.metrics
        self.ledger.bind_metrics(self.metrics)
        self._m_requests = self.metrics.counter(
            "repro_gateway_requests_total",
            "gateway admission/submission outcomes",
            ("op",),
        )
        self._m_http = self.metrics.counter(
            "repro_gateway_http_responses_total",
            "HTTP responses sent, by status code",
            ("status",),
        )
        # live quota: evictions (local budget or fleet-wide events) credit
        # the billed tenant's bytes back
        client.store.add_evict_listener(self.ledger.credit_evicted)

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        handler = type("_Handler", (_GatewayHandler,), {"gateway": self})
        self._httpd = ThreadingHTTPServer((self.host, self.port), handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="gateway-http", daemon=True
        )
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    @property
    def draining(self) -> bool:
        return self._draining

    def begin_shutdown(self) -> None:
        """Phase one of graceful shutdown: new submissions get 503 (here and
        at the service), in-flight runs keep executing, status/event reads
        keep working so clients can observe their runs finishing."""
        self._draining = True
        self.client.service.begin_shutdown()

    def close(self, drain_timeout: float | None = None) -> None:
        """Phase two: drain in-flight runs, stop the listener.  Idempotent."""
        self.begin_shutdown()
        if self._closed:
            return
        self._closed = True
        self.client.service.drain(drain_timeout)
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        if self._own_client:
            self.client.close()

    def __enter__(self) -> "GatewayServer":
        if self._httpd is None:
            self.start()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- bookkeeping -----------------------------------------------------------
    def _count(self, what: str) -> None:
        if what.startswith("http_"):
            self._m_http.labels(status=what[len("http_"):]).inc()
        else:
            self._m_requests.labels(op=what).inc()

    def counts(self) -> dict[str, int]:
        """Deprecated alias surface: the legacy flat dict, reconstructed from
        ``repro_gateway_requests_total{op}`` and
        ``repro_gateway_http_responses_total{status}``
        (see ``repro/obs/naming.py``)."""
        out: dict[str, int] = {}
        for s in self._m_requests.series():
            out[s["labels"]["op"]] = int(s["value"] or 0)
        for s in self._m_http.series():
            out[f"http_{s['labels']['status']}"] = int(s["value"] or 0)
        return out

    def metrics_text(self) -> str:
        """The fabric-wide Prometheus exposition behind ``GET /metrics``:
        this process's registry (gateway + client + scheduler + store +
        cache) merged with every reachable store server's registry."""
        return render_prometheus(self.client.metrics_doc())

    def _track(self, handle: RunHandle) -> None:
        with self._runs_lock:
            self._runs[handle.run_id] = handle
            if len(self._runs) > _MAX_RUNS_TRACKED:
                # retire oldest *terminal* runs only: an accepted run's
                # status must stay queryable until it completes
                for rid in [
                    r.run_id
                    for r in sorted(self._runs.values(), key=lambda r: r.created_at)
                    if r.terminal
                ][: len(self._runs) - _MAX_RUNS_TRACKED]:
                    self._runs.pop(rid, None)

    def get_run(self, run_id: str, tenant: str) -> RunHandle:
        with self._runs_lock:
            handle = self._runs.get(run_id)
        # a foreign tenant's run id is indistinguishable from an unknown one
        if handle is None or handle.tenant != tenant:
            raise _ApiError(404, "not_found", f"unknown run {run_id!r}")
        return handle

    # -- submission ------------------------------------------------------------
    def submit(
        self,
        tenant: str,
        spec: WorkflowSpec,
        data: Any,
        requested_namespace: str | None,
        trace: "_tracing.TraceContext | None" = None,
    ) -> RunHandle:
        """Admit + submit one run.  ``trace`` is the inbound trace context
        (parsed from the HTTP ``traceparent`` header); when tracing is
        enabled the gateway opens a ``gateway.submit`` span under it and
        parents the run's span there, so one trace covers
        gateway → scheduler → store → shards across processes."""
        gsp = _tracing.span("gateway.submit", kind="server", parent=trace, tenant=tenant)
        with gsp:
            return self._submit(tenant, spec, data, requested_namespace, trace, gsp)

    def _submit(
        self,
        tenant: str,
        spec: WorkflowSpec,
        data: Any,
        requested_namespace: str | None,
        trace: "_tracing.TraceContext | None",
        gsp: Any,
    ) -> RunHandle:
        if self._draining:
            raise _ApiError(
                503,
                "draining",
                "gateway is shutting down; resubmit elsewhere or later",
                {"Retry-After": "1"},
            )
        try:
            namespace = self.tenancy.resolve(tenant, requested_namespace)
        except NamespaceDenied as e:
            self._count("denied_namespace")
            raise _ApiError(403, "namespace_denied", str(e)) from None
        spec = spec.with_namespace(namespace)
        try:
            spec.validate(self.client.registry)
        except (SpecError, ToolStateError, UnknownModuleError) as e:
            self._count("invalid_spec")
            raise _ApiError(422, "invalid_spec", str(e)) from None

        try:
            self.admission.reserve(tenant)
        except QuotaExceeded as e:
            self._count("rejected_quota")
            raise _ApiError(
                429, "quota_exceeded", str(e),
                {"Retry-After": f"{max(1, round(e.retry_after_s))}"},
            ) from None

        run_id = f"r-{secrets.token_hex(8)}"
        handle = RunHandle(run_id, tenant, namespace, spec.digest)
        # parent the run's span under the gateway span when one is live,
        # else pass the raw inbound context straight through
        if getattr(gsp, "trace_id", None):
            gsp.set(run_id=run_id, namespace=namespace)
            child = _tracing.TraceContext(gsp.trace_id, gsp.span_id)
        else:
            child = trace
        handle.trace_id = child.trace_id if child is not None else None
        self._track(handle)
        handle.add_event(
            "accepted", namespace=namespace, digest=spec.digest, tenant=tenant
        )

        def _on_state(state: str) -> None:
            if state == "started":
                handle.status = "running"
                handle.add_event("started")

        try:
            fut = self.client.submit(spec, data, on_state=_on_state, trace=child)
        except AdmissionRejected as e:
            self.admission.cancel(tenant)
            handle.status = "failed"
            handle.error = str(e)
            handle.add_event("rejected", message=str(e))
            self._count("rejected_pending")
            raise _ApiError(
                429, "saturated", str(e),
                {"Retry-After": f"{max(1, round(self.retry_after_s))}"},
            ) from None
        except ServiceClosed as e:
            self.admission.cancel(tenant)
            handle.status = "failed"
            handle.error = str(e)
            handle.add_event("rejected", message=str(e))
            raise _ApiError(503, "draining", str(e), {"Retry-After": "1"}) from None

        self._count("accepted")
        _log.info(
            "run %s accepted (tenant=%s namespace=%s trace=%s)",
            run_id, tenant, namespace, handle.trace_id or "-",
        )

        def _done(f: Any) -> None:
            try:
                result: DagRunResult = f.result()
            except Exception as e:  # noqa: BLE001 - surfaced via run status
                handle.error = f"{type(e).__name__}: {e}"
                handle.status = "failed"
                self.admission.release(handle.tenant, failed=True)
                handle.add_event("failed", message=handle.error)
                _log.warning(
                    "run %s failed (tenant=%s): %s",
                    handle.run_id, handle.tenant, handle.error,
                )
            else:
                handle.summary = _summarize(result)
                for key in result.stored_keys:
                    rec = self.client.store.records.get(key)
                    if rec is not None:
                        self.ledger.charge_stored(
                            handle.tenant, key, int(rec.nbytes_disk)
                        )
                handle.status = "done"
                self.admission.release(
                    handle.tenant,
                    units_total=len(result.module_seconds),
                    units_skipped=result.n_skipped,
                )
                handle.add_event(
                    "finished",
                    n_skipped=result.n_skipped,
                    n_computed=result.n_computed,
                    stored=len(result.stored_keys),
                    total_seconds=result.total_seconds,
                )

        fut.add_done_callback(_done)
        return handle

    # -- read surfaces -----------------------------------------------------------
    def recommend_doc(
        self,
        tenant: str,
        dataset: str,
        modules: list[str],
        requested_namespace: str | None,
        top_k: int,
    ) -> dict[str, Any]:
        try:
            namespace = self.tenancy.resolve(tenant, requested_namespace)
        except NamespaceDenied as e:
            raise _ApiError(403, "namespace_denied", str(e)) from None
        partial = WorkflowSpec(dataset, namespace=namespace)
        if modules:
            partial.chain([m for m in modules])
        report = self.client.recommend(partial, top_k=top_k)
        return _report_doc(report)

    def artifacts_doc(
        self,
        tenant: str,
        module: str | None,
        params: dict[str, Any],
        dataset: str | None,
        requested_namespace: str | None,
        any_position: bool,
        limit: int,
    ) -> dict[str, Any]:
        """Tenant-scoped catalog browse: every query resolves to exactly ONE
        visible namespace through the same :class:`TenancyPolicy` gate as
        submissions — the private namespace by default, ``shared`` on
        request, a foreign tenant's namespace never (403)."""
        try:
            namespace = self.tenancy.resolve(tenant, requested_namespace)
        except NamespaceDenied as e:
            self._count("denied_namespace")
            raise _ApiError(403, "namespace_denied", str(e)) from None
        try:
            hits = self.client.find(
                module=module,
                params=params or None,
                dataset=dataset,
                namespace=namespace,
                any_position=any_position,
                limit=max(1, min(limit, 500)),
            )
        except ValueError as e:  # e.g. param filters without ?module=
            raise _ApiError(400, "bad_request", str(e)) from None
        return {
            "namespace": namespace,
            "count": len(hits),
            "artifacts": [_artifact_doc(r) for r in hits],
        }

    def stats_doc(self, tenant: str) -> dict[str, Any]:
        agg = self.client.stats()
        service = self.client.service
        return {
            "fabric": {
                "runs": agg.runs,
                "failures": agg.failures,
                "throughput_rps": agg.throughput_rps,
                "reuse_rate": agg.reuse_rate,
                "stored": agg.stored,
                "singleflight_waits": agg.singleflight_waits,
                "pending_runs": service.pending_runs,
                "rejected_runs": service.rejected_runs,
                "max_pending": service.max_pending,
            },
            "gateway": self.counts(),
            "tenant": {tenant: self.ledger.snapshot(tenant)},
            "draining": self._draining,
        }


class _GatewayHandler(BaseHTTPRequestHandler):
    """One HTTP connection; routes into the class-level ``gateway``."""

    gateway: GatewayServer  # bound by GatewayServer.start()
    protocol_version = "HTTP/1.1"
    server_version = "repro-gateway"

    def log_message(self, fmt: str, *args: Any) -> None:  # noqa: D102
        # http.server's per-request stderr chatter becomes debug-level
        # structured logging (visible with --log-level debug)
        _log.debug("%s %s", self.address_string(), fmt % args)

    # -- plumbing ------------------------------------------------------------
    def _send_json(
        self,
        status: int,
        doc: Mapping[str, Any],
        headers: Mapping[str, str] | None = None,
    ) -> None:
        body = json.dumps(doc).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)
        self.gateway._count(f"http_{status}")

    def _authenticate(self) -> str:
        try:
            return self.gateway.auth.authenticate(self.headers.get("Authorization"))
        except AuthError as e:
            raise _ApiError(
                401, "unauthorized", str(e),
                {"WWW-Authenticate": 'Bearer realm="repro-gateway"'},
            ) from None

    def _read_body(self) -> bytes:
        length = self.headers.get("Content-Length")
        if length is None:
            raise _ApiError(411, "length_required", "Content-Length is required")
        try:
            n = int(length)
        except ValueError:
            raise _ApiError(400, "bad_request", "malformed Content-Length") from None
        if n < 0:
            raise _ApiError(400, "bad_request", "malformed Content-Length")
        if n > self.gateway.max_body_bytes:
            # refuse before reading: a huge body never gets buffered
            self.close_connection = True
            raise _ApiError(
                413,
                "too_large",
                f"request body {n} bytes exceeds the "
                f"{self.gateway.max_body_bytes}-byte limit",
            )
        return self.rfile.read(n)

    def _parse_json(self, raw: bytes) -> Any:
        try:
            return json.loads(raw.decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as e:
            raise _ApiError(400, "bad_json", f"invalid JSON body: {e}") from None

    # -- routes --------------------------------------------------------------
    def do_GET(self) -> None:  # noqa: N802 - http.server API
        try:
            url = urlparse(self.path)
            parts = [p for p in url.path.split("/") if p]
            if url.path == "/healthz":
                self._send_json(
                    200, {"ok": True, "draining": self.gateway.draining}
                )
                return
            if url.path == "/metrics":
                # unauthenticated like /healthz: an operational scrape
                # surface, not a tenant data surface
                body = self.gateway.metrics_text().encode()
                self.send_response(200)
                self.send_header(
                    "Content-Type", "text/plain; version=0.0.4; charset=utf-8"
                )
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
                self.gateway._count("http_200")
                return
            tenant = self._authenticate()
            if parts[:1] == ["v1"] and parts[1:2] == ["runs"] and len(parts) == 3:
                handle = self.gateway.get_run(parts[2], tenant)
                self._send_json(200, handle.describe())
            elif (
                parts[:1] == ["v1"]
                and parts[1:2] == ["runs"]
                and len(parts) == 4
                and parts[3] == "events"
            ):
                handle = self.gateway.get_run(parts[2], tenant)
                self._stream_events(handle)
            elif parts == ["v1", "recommend"]:
                q = parse_qs(url.query)
                dataset = (q.get("dataset") or [""])[0]
                if not dataset:
                    raise _ApiError(400, "bad_request", "missing ?dataset=")
                modules = [
                    m for m in (q.get("modules") or [""])[0].split(",") if m
                ]
                namespace = (q.get("namespace") or [None])[0]
                try:
                    top_k = int((q.get("top_k") or ["5"])[0])
                except ValueError:
                    raise _ApiError(400, "bad_request", "top_k must be an int")
                doc = self.gateway.recommend_doc(
                    tenant, dataset, modules, namespace, top_k
                )
                self._send_json(200, doc)
            elif parts == ["v1", "artifacts"]:
                q = parse_qs(url.query)
                module = (q.get("module") or [None])[0]
                dataset = (q.get("dataset") or [None])[0]
                namespace = (q.get("namespace") or [None])[0]
                any_position = (q.get("any") or ["0"])[0] in ("1", "true", "yes")
                try:
                    limit = int((q.get("limit") or ["20"])[0])
                except ValueError:
                    raise _ApiError(400, "bad_request", "limit must be an int")
                # ?param.k=v filters on decoded tool-state params; values are
                # parsed as JSON when they look like it ("3", "true",
                # '"text"'), else taken as plain strings
                params: dict[str, Any] = {}
                for raw_key, values in q.items():
                    if not raw_key.startswith("param.") or not values:
                        continue
                    name = raw_key[len("param."):]
                    if not name:
                        raise _ApiError(400, "bad_request", "empty param name")
                    try:
                        params[name] = json.loads(values[0])
                    except ValueError:
                        params[name] = values[0]
                doc = self.gateway.artifacts_doc(
                    tenant, module, params, dataset, namespace,
                    any_position, limit,
                )
                self._send_json(200, doc)
            elif parts == ["v1", "stats"]:
                self._send_json(200, self.gateway.stats_doc(tenant))
            else:
                raise _ApiError(404, "not_found", f"no route for {url.path}")
        except _ApiError as e:
            self._send_json(
                e.status, {"error": e.error, "message": e.message}, e.headers
            )
        except Exception as e:  # noqa: BLE001 - the server thread must survive
            self._send_json(500, {"error": "internal", "message": str(e)})

    def do_POST(self) -> None:  # noqa: N802 - http.server API
        try:
            url = urlparse(self.path)
            if url.path != "/v1/workflows":
                raise _ApiError(404, "not_found", f"no route for {url.path}")
            tenant = self._authenticate()
            body = self._parse_json(self._read_body())
            if not isinstance(body, Mapping):
                raise _ApiError(400, "bad_request", "body must be a JSON object")
            # either {"spec": {...}, "data": ..., "namespace": ..., "wait": ...}
            # or a bare workflow-spec document
            if "spec" in body:
                raw_spec = body["spec"]
                data = body.get("data")
                namespace = body.get("namespace")
                wait = bool(body.get("wait", False))
            else:
                raw_spec, data, namespace, wait = body, None, None, False
            if not isinstance(raw_spec, Mapping):
                raise _ApiError(400, "bad_request", "'spec' must be a JSON object")
            try:
                spec = WorkflowSpec.from_dict(raw_spec)
            except SpecError as e:
                raise _ApiError(422, "invalid_spec", str(e)) from None
            if namespace is None and spec.namespace:
                namespace = spec.namespace
            trace = _tracing.TraceContext.from_traceparent(
                self.headers.get("traceparent")
            )
            handle = self.gateway.submit(tenant, spec, data, namespace, trace=trace)
            if wait:
                self._wait_terminal(handle)
                self._send_json(200, handle.describe())
            else:
                self._send_json(202, handle.describe())
        except _ApiError as e:
            self._send_json(
                e.status, {"error": e.error, "message": e.message}, e.headers
            )
        except Exception as e:  # noqa: BLE001 - the server thread must survive
            self._send_json(500, {"error": "internal", "message": str(e)})

    # -- streaming -------------------------------------------------------------
    def _wait_terminal(self, handle: RunHandle, timeout: float = _WAIT_MAX_S) -> None:
        deadline = time.monotonic() + timeout
        with handle.cond:
            while not handle.terminal:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise _ApiError(
                        504,
                        "timeout",
                        f"run {handle.run_id} still {handle.status!r} after "
                        f"{timeout:.0f}s; poll GET /v1/runs/{handle.run_id}",
                    )
                handle.cond.wait(min(remaining, 1.0))

    def _stream_events(self, handle: RunHandle) -> None:
        """Chunked NDJSON: every event so far, then live events until the
        run reaches a terminal state."""
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Transfer-Encoding", "chunked")
        self.send_header("Cache-Control", "no-store")
        self.end_headers()
        self.gateway._count("http_200")

        def _chunk(doc: dict[str, Any]) -> None:
            data = (json.dumps(doc) + "\n").encode()
            self.wfile.write(b"%x\r\n" % len(data) + data + b"\r\n")
            self.wfile.flush()

        sent = 0
        deadline = time.monotonic() + _EVENT_STREAM_MAX_S
        try:
            while True:
                with handle.cond:
                    while (
                        sent >= len(handle.events)
                        and not handle.terminal
                        and time.monotonic() < deadline
                    ):
                        handle.cond.wait(1.0)
                    fresh = handle.events[sent:]
                for doc in fresh:
                    _chunk(doc)
                sent += len(fresh)
                if (handle.terminal and sent >= len(handle.events)) or (
                    time.monotonic() >= deadline
                ):
                    break
            self.wfile.write(b"0\r\n\r\n")
        except (BrokenPipeError, ConnectionResetError):
            pass  # client went away mid-stream; nothing to clean up
        self.close_connection = True
