"""Tenant → namespace mapping: the isolation *and* sharing rules.

Every authenticated submission runs inside exactly one artifact namespace,
and the namespace is what every ``PrefixKey`` is derived from (see
``repro.api.spec.namespaced_dataset``), so these rules are the whole
cross-tenant story:

  * ``tenant:<name>`` — the tenant's **private** namespace.  Artifacts
    stored there are keyed under ``tenant:<name>/<dataset>::…`` and can
    never be produced or probed by another tenant's submission, because no
    other tenant's submissions are ever resolved into that namespace.
  * ``shared`` (and any extra names the operator allows) — the **opt-in
    public** namespace.  Any tenant may submit into it; identical public
    prefixes then collide *by construction*, which is the point: tenant B's
    run of a pipeline tenant A already ran skips A's stored intermediates.
    The thesis' reuse economics, across users.

A submission may name a namespace explicitly (request field or spec field).
Naming nothing means private.  Naming another tenant's private namespace is
refused (gateway → 403) — isolation is enforced here, at admission, not by
hoping clients behave.
"""
from __future__ import annotations

from ..api.spec import SpecError, check_namespace

SHARED_NAMESPACE = "shared"
TENANT_PREFIX = "tenant:"


class NamespaceDenied(Exception):
    """The tenant asked for a namespace it may not use (gateway → 403)."""


def check_tenant_name(tenant: str) -> str:
    """Tenant names must be non-empty, namespace-safe, and must not embed
    the reserved ``tenant:`` prefix or namespace separators."""
    if not tenant:
        raise ValueError("empty tenant name")
    try:
        check_namespace(tenant)
    except SpecError as e:
        raise ValueError(f"invalid tenant name {tenant!r}: {e}") from None
    if ":" in tenant:
        raise ValueError(f"invalid tenant name {tenant!r}: ':' is reserved")
    return tenant


def private_namespace(tenant: str) -> str:
    return f"{TENANT_PREFIX}{tenant}"


class TenancyPolicy:
    """Resolves a (tenant, requested namespace) pair to the namespace a
    submission actually runs in."""

    def __init__(self, shared_namespaces: tuple[str, ...] = (SHARED_NAMESPACE,)) -> None:
        for ns in shared_namespaces:
            check_namespace(ns)
            if ns.startswith(TENANT_PREFIX):
                raise ValueError(
                    f"shared namespace {ns!r} collides with the tenant: prefix"
                )
        self.shared_namespaces = tuple(shared_namespaces)

    def resolve(self, tenant: str, requested: str | None) -> str:
        """The namespace this tenant's submission runs in.

        ``None``/``""``/the tenant's own private namespace → private;
        an allowed shared namespace → that namespace; anything else →
        :class:`NamespaceDenied`.
        """
        mine = private_namespace(tenant)
        if not requested or requested == mine:
            return mine
        try:
            check_namespace(requested)
        except SpecError as e:
            raise NamespaceDenied(str(e)) from None
        if requested in self.shared_namespaces:
            return requested
        if requested.startswith(TENANT_PREFIX):
            raise NamespaceDenied(
                f"namespace {requested!r} is another tenant's private space"
            )
        raise NamespaceDenied(
            f"namespace {requested!r} is not an allowed shared namespace "
            f"(allowed: {', '.join(self.shared_namespaces)})"
        )
