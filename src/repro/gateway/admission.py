"""Admission control: bounded load, per-tenant quotas, explicit 429s.

Two layers refuse work *before* it costs anything:

  * the **service-wide pending budget** lives in ``WorkflowService``
    (``max_pending``) — its :class:`~repro.sched.service.AdmissionRejected`
    is the global backpressure signal;
  * the **per-tenant quotas** live here: runs in flight per tenant (one
    noisy tenant cannot occupy the whole pending budget) and live stored
    bytes per tenant (billed/credited through the shared
    :class:`~repro.sched.stats.TenantLedger`, which the gateway wires to the
    store's eviction events — quota is *live* usage against the eviction
    budget, not a monotone counter).

Both rejections surface to HTTP as structured ``429`` with ``Retry-After``;
accepted work is never silently dropped, rejected work is never silently
queued.
"""
from __future__ import annotations

from ..sched.stats import TenantLedger


class QuotaExceeded(Exception):
    """A per-tenant quota refused the submission (gateway → 429)."""

    def __init__(self, message: str, *, retry_after_s: float) -> None:
        super().__init__(message)
        self.retry_after_s = retry_after_s


class AdmissionController:
    """Per-tenant admission gates over a shared :class:`TenantLedger`.

    ``reserve`` is called before the service submit (and charges the
    in-flight slot so concurrent requests cannot over-admit); ``release`` is
    called when the run finishes — or immediately, when the service-wide
    budget rejected the submission after the reservation.
    """

    def __init__(
        self,
        ledger: TenantLedger,
        *,
        max_inflight_per_tenant: int | None = None,
        max_bytes_per_tenant: int | None = None,
        retry_after_s: float = 1.0,
    ) -> None:
        if max_inflight_per_tenant is not None and max_inflight_per_tenant < 1:
            raise ValueError("max_inflight_per_tenant must be >= 1 (or None)")
        if max_bytes_per_tenant is not None and max_bytes_per_tenant < 1:
            raise ValueError("max_bytes_per_tenant must be >= 1 (or None)")
        self.ledger = ledger
        self.max_inflight_per_tenant = max_inflight_per_tenant
        self.max_bytes_per_tenant = max_bytes_per_tenant
        self.retry_after_s = retry_after_s

    def reserve(self, tenant: str) -> None:
        """Admit one run for ``tenant`` or raise :class:`QuotaExceeded`.
        On success the tenant's in-flight count is already incremented."""
        if self.max_inflight_per_tenant is not None:
            if self.ledger.in_flight(tenant) >= self.max_inflight_per_tenant:
                self.ledger.rejected(tenant)
                raise QuotaExceeded(
                    f"tenant {tenant!r} already has "
                    f"{self.max_inflight_per_tenant} runs in flight",
                    retry_after_s=self.retry_after_s,
                )
        if self.max_bytes_per_tenant is not None:
            used = self.ledger.bytes_stored(tenant)
            if used >= self.max_bytes_per_tenant:
                self.ledger.rejected(tenant)
                raise QuotaExceeded(
                    f"tenant {tenant!r} stores {used} bytes, at or over its "
                    f"{self.max_bytes_per_tenant}-byte quota; reuse existing "
                    "artifacts or wait for eviction to reclaim space",
                    retry_after_s=self.retry_after_s,
                )
        self.ledger.run_started(tenant)

    def release(
        self,
        tenant: str,
        *,
        failed: bool = False,
        units_total: int = 0,
        units_skipped: int = 0,
    ) -> None:
        self.ledger.run_finished(
            tenant,
            failed=failed,
            units_total=units_total,
            units_skipped=units_skipped,
        )

    def cancel(self, tenant: str) -> None:
        """The service-wide pending budget rejected a submission *after* a
        successful reservation: undo the reservation and record the 429."""
        self.ledger.run_cancelled(tenant)
        self.ledger.rejected(tenant)
