"""``python -m repro.gateway.serve`` — run the workflow gateway.

Example::

    python -m repro.gateway.serve --root /var/lib/repro-artifacts \\
        --port 8707 --token s3cret-a=alice --token s3cret-b=bob \\
        --modules mypkg.pipelines:register

    curl -s -X POST http://127.0.0.1:8707/v1/workflows \\
        -H 'Authorization: Bearer s3cret-a' \\
        -d '{"spec": {...workflow spec json...}, "data": [1,2,3],
             "namespace": "shared", "wait": true}'

``--modules`` imports ``pkg.mod`` and calls its ``register(registry)`` (or a
named function after ``:``) so the gateway knows the module universe tenants
may reference.  ``--demo-modules`` registers a tiny arithmetic pipeline set —
enough to smoke-test the gateway end to end without writing code.

Binds loopback by default: tokens ride in plaintext HTTP headers, so expose
the gateway beyond ``127.0.0.1`` only behind TLS termination or on a trusted
network.  SIGTERM/SIGINT trigger the two-phase graceful shutdown (new
submissions 503, in-flight runs drain, then the listener stops).
"""
from __future__ import annotations

import argparse
import importlib
import os
import signal
import sys
import threading

from ..api.client import Client
from ..core.registry import ModuleRegistry
from ..obs.logging import configure_logging, get_logger
from ..obs.tracing import configure_tracing
from .auth import TokenAuthenticator
from .server import DEFAULT_PORT, GatewayServer
from .tenancy import SHARED_NAMESPACE, TenancyPolicy


def register_demo_modules(registry: ModuleRegistry) -> None:
    """A tiny numeric pipeline universe for smoke tests and demos."""

    @registry.module("normalize")
    def normalize(xs):
        total = sum(xs) or 1.0
        return [x / total for x in xs]

    @registry.module("scale", factor=2.0)
    def scale(xs, factor=2.0):
        return [x * factor for x in xs]

    @registry.module("stats")
    def stats(xs):
        return {"n": len(xs), "mean": sum(xs) / len(xs) if xs else 0.0}


def _load_modules(spec: str, registry: ModuleRegistry) -> None:
    mod_name, _, fn_name = spec.partition(":")
    mod = importlib.import_module(mod_name)
    fn = getattr(mod, fn_name or "register")
    fn(registry)


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.gateway.serve",
        description="HTTP front door: multi-tenant workflow submission over "
        "one shared intermediate-data fabric.",
    )
    parser.add_argument("--root", help="artifact directory (default: temp dir)")
    parser.add_argument(
        "--store-url",
        help="mount a repro.net store/cluster instead of a local root "
        '(e.g. "h:7077" or "h:7077,h:7078,h:7079")',
    )
    parser.add_argument(
        "--host",
        default="127.0.0.1",
        help="bind address; tokens travel as plaintext HTTP headers, so go "
        "beyond loopback only behind TLS or on a trusted network",
    )
    parser.add_argument("--port", type=int, default=DEFAULT_PORT)
    parser.add_argument(
        "--token",
        action="append",
        default=[],
        metavar="TOKEN=TENANT",
        help="register one bearer token (repeatable); required",
    )
    parser.add_argument(
        "--modules",
        action="append",
        default=[],
        metavar="PKG.MOD[:FN]",
        help="import and call FN(registry) (default FN: register) to "
        "populate the module universe (repeatable)",
    )
    parser.add_argument(
        "--demo-modules",
        action="store_true",
        help="register the built-in demo pipeline modules",
    )
    parser.add_argument("--policy", default="PT")
    parser.add_argument("--max-workers", type=int, default=4)
    parser.add_argument(
        "--max-pending",
        type=int,
        default=64,
        help="service-wide pending-run budget; saturation answers 429",
    )
    parser.add_argument(
        "--max-inflight-per-tenant",
        type=int,
        default=16,
        help="per-tenant in-flight run quota (0 disables)",
    )
    parser.add_argument(
        "--max-mb-per-tenant",
        type=int,
        default=0,
        help="per-tenant live stored-bytes quota in MiB (0 disables)",
    )
    parser.add_argument(
        "--capacity-mb",
        type=int,
        default=0,
        help="store eviction budget in MiB (0: unbounded)",
    )
    parser.add_argument(
        "--shared-namespace",
        action="append",
        default=[],
        help=f"extra opt-in shared namespaces (default: {SHARED_NAMESPACE!r})",
    )
    parser.add_argument(
        "--log-level",
        default="info",
        choices=["debug", "info", "warning", "error", "critical"],
        help="logging verbosity for the repro logger tree",
    )
    parser.add_argument(
        "--log-json",
        action="store_true",
        help="emit JSON-lines logs instead of the human-readable format",
    )
    parser.add_argument(
        "--trace-dir",
        default=None,
        help="record spans as NDJSON under this directory (enables tracing; "
        "also reachable via REPRO_TRACE_DIR)",
    )
    parser.add_argument(
        "--service",
        default=os.environ.get("REPRO_SERVICE", "gateway"),
        help="service name stamped on this process's spans "
        "(default: $REPRO_SERVICE or 'gateway')",
    )
    args = parser.parse_args(argv)

    configure_logging(args.log_level, json_lines=args.log_json)
    log = get_logger("gateway.serve")
    if args.trace_dir:
        configure_tracing(args.trace_dir, args.service)

    if not args.token:
        parser.error("at least one --token TOKEN=TENANT is required")
    auth = TokenAuthenticator.from_pairs(args.token)

    client = Client(
        root=args.root if not args.store_url else None,
        store_url=args.store_url,
        policy=args.policy,
        max_workers=args.max_workers,
        capacity_bytes=(args.capacity_mb << 20) or None,
        max_pending=args.max_pending,
    )
    if args.demo_modules:
        register_demo_modules(client.registry)
    for spec in args.modules:
        _load_modules(spec, client.registry)

    shared = tuple([SHARED_NAMESPACE, *args.shared_namespace])
    gateway = GatewayServer(
        client,
        auth,
        host=args.host,
        port=args.port,
        tenancy=TenancyPolicy(shared),
        max_inflight_per_tenant=args.max_inflight_per_tenant or None,
        max_bytes_per_tenant=(args.max_mb_per_tenant << 20) or None,
        own_client=True,
    )
    gateway.start()
    log.info(
        "gateway listening on %s (tenants=%d, modules=%d)",
        gateway.url, len(auth), len(client.registry),
    )

    done = threading.Event()

    def _graceful(*_: object) -> None:
        # phase one inline (reject new work immediately); the drain happens
        # on the main thread below
        gateway.begin_shutdown()
        done.set()

    signal.signal(signal.SIGTERM, _graceful)
    signal.signal(signal.SIGINT, _graceful)
    try:
        done.wait()
    except KeyboardInterrupt:
        gateway.begin_shutdown()
    log.info("gateway draining in-flight runs...")
    gateway.close()
    log.info("gateway stopped")
    return 0


if __name__ == "__main__":
    sys.exit(main())
