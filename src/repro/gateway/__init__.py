"""``repro.gateway`` — the HTTP front door of the workflow fabric.

Everything below this package is an in-process library; everything above it
is "millions of users".  The gateway closes that gap with four layers, each
its own module:

  * :mod:`~repro.gateway.auth`      — bearer tokens → tenants
    (constant-time lookup, no identity provider pretensions);
  * :mod:`~repro.gateway.tenancy`   — tenant → artifact namespace: private
    ``tenant:<name>`` isolation by construction, opt-in ``shared`` namespace
    where identical public prefixes collide on purpose so tenants reuse each
    other's intermediates — the thesis' reuse economics across users;
  * :mod:`~repro.gateway.admission` — per-tenant quotas (runs in flight,
    live stored bytes billed against the eviction budget) over the
    service-wide ``max_pending`` bound: saturation is a structured 429 with
    Retry-After, never an unbounded queue;
  * :mod:`~repro.gateway.server`    — the threaded stdlib HTTP/JSON service:
    ``POST /v1/workflows``, ``GET /v1/runs/{id}`` (+ chunked ``/events``
    stream), ``GET /v1/recommend``, ``GET /v1/stats``, ``GET /healthz``,
    and two-phase SIGTERM drain.

Run one with ``python -m repro.gateway.serve``; see ``docs/gateway.md``.
"""
from .admission import AdmissionController, QuotaExceeded
from .auth import AuthError, TokenAuthenticator
from .server import DEFAULT_PORT, GatewayServer, RunHandle
from .tenancy import (
    SHARED_NAMESPACE,
    NamespaceDenied,
    TenancyPolicy,
    private_namespace,
)

__all__ = [
    "AdmissionController",
    "AuthError",
    "DEFAULT_PORT",
    "GatewayServer",
    "NamespaceDenied",
    "QuotaExceeded",
    "RunHandle",
    "SHARED_NAMESPACE",
    "TenancyPolicy",
    "TokenAuthenticator",
    "private_namespace",
]
