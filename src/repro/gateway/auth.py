"""Bearer-token authentication for the workflow gateway.

The gateway's trust model is deliberately boring: a static map from opaque
bearer tokens to tenant names, supplied at startup (CLI flags, a tokens
file, or programmatically).  There is no user database, no token issuance,
no expiry — the gateway is the *front door of a workflow fabric*, not an
identity provider; production deployments put it behind whatever issues
their tokens and feed the map in.

What the module does guarantee:

  * token comparison is constant-time (``hmac.compare_digest``) — a
    timing side channel must not let one tenant brute-force another's token;
  * tenant names are validated against the namespace charset at
    registration, so a tenant name can never smuggle a ``/`` into the
    ``tenant:<name>`` private namespace and collide with another tenant.
"""
from __future__ import annotations

import hmac
from typing import Iterable, Mapping

from .tenancy import check_tenant_name


class AuthError(Exception):
    """Missing, malformed, or unknown credentials (gateway → 401)."""


class TokenAuthenticator:
    """Static ``token -> tenant`` map with constant-time lookup."""

    def __init__(self, tokens: Mapping[str, str] | None = None) -> None:
        self._tokens: dict[str, str] = {}
        for token, tenant in (tokens or {}).items():
            self.add_token(token, tenant)

    def add_token(self, token: str, tenant: str) -> None:
        if not token:
            raise ValueError("empty token")
        self._tokens[token] = check_tenant_name(tenant)

    @classmethod
    def from_pairs(cls, pairs: Iterable[str]) -> "TokenAuthenticator":
        """Build from CLI-style ``"<token>=<tenant>"`` strings."""
        auth = cls()
        for pair in pairs:
            token, sep, tenant = pair.partition("=")
            if not sep or not token or not tenant:
                raise ValueError(
                    f"malformed token spec {pair!r}; expected '<token>=<tenant>'"
                )
            auth.add_token(token, tenant)
        return auth

    def __len__(self) -> int:
        return len(self._tokens)

    def authenticate(self, authorization: str | None) -> str:
        """Map an ``Authorization`` header to a tenant name.

        Raises :class:`AuthError` on a missing header, a non-Bearer scheme,
        or an unknown token.  Every registered token is compared (constant
        work per request) so response timing does not reveal whether a
        guessed token shares a prefix with a real one.
        """
        if not authorization:
            raise AuthError("missing Authorization header")
        scheme, _, token = authorization.partition(" ")
        if scheme.lower() != "bearer" or not token.strip():
            raise AuthError("expected 'Authorization: Bearer <token>'")
        token = token.strip()
        tenant: str | None = None
        for known, name in self._tokens.items():
            if hmac.compare_digest(known.encode(), token.encode()):
                tenant = name
        if tenant is None:
            raise AuthError("unknown token")
        return tenant
