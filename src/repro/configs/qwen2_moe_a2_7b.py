"""Qwen1.5-MoE-A2.7B (hf:Qwen/Qwen1.5-MoE-A2.7B): 4 shared + 60 routed top-4.

Expert count 60 is not divisible by the 16-way model axis; the sharding rules
fall back to tensor-parallel per-expert d_ff (1408/16 = 88) — see DESIGN §5.
"""
from .base import LMConfig, LM_SHAPES, MoESpec, reduced

CONFIG = LMConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1408,
    vocab=151936,
    moe=MoESpec(n_experts=60, top_k=4, n_shared=4, d_ff_expert=1408),
    sub_quadratic=False,  # pure full attention -> long_500k skipped
)

SMOKE = reduced(
    CONFIG, name="qwen2-moe-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_head=16, d_ff=64, vocab=256,
    moe=MoESpec(n_experts=8, top_k=2, n_shared=1, d_ff_expert=32),
)

SHAPES = LM_SHAPES
