"""GatedGCN (arXiv:2003.00982 benchmarking-GNNs): 16L, d_hidden=70, gated agg."""
from .base import GNNConfig, GNN_SHAPES, reduced

CONFIG = GNNConfig(
    name="gatedgcn",
    n_layers=16,
    d_hidden=70,
    aggregator="gated",
    d_edge=8,
    n_classes=47,
)

SMOKE = reduced(CONFIG, name="gatedgcn-smoke", n_layers=3, d_hidden=16, n_classes=7)

SHAPES = GNN_SHAPES
