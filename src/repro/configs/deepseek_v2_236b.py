"""DeepSeek-V2 236B (arXiv:2405.04434): MLA kv_lora=512, 2 shared + 160
routed top-6 experts.

Experts shard over the data axis (160/16=10) with per-expert d_ff over the
model axis (1536/16=96): pure model-axis EP would leave 28 GB of expert
weights per chip (> v5e HBM). MLA's latent cache makes long_500k deployable.
"""
from .base import LMConfig, LM_SHAPES, MLASpec, MoESpec, reduced

CONFIG = LMConfig(
    name="deepseek-v2-236b",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=192,  # nope 128 + rope 64
    d_ff=12288,
    vocab=102400,
    moe=MoESpec(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536),
    mla=MLASpec(
        kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
        nope_head_dim=128, v_head_dim=128,
    ),
    rope_theta=10_000.0,
    sub_quadratic=True,  # MLA latent cache -> long_500k runs
    shard_overrides=(("experts", ("data",)),),
)

SMOKE = reduced(
    CONFIG, name="deepseek-v2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_head=24, d_ff=128, vocab=256,
    moe=MoESpec(n_experts=8, top_k=2, n_shared=1, d_ff_expert=32),
    mla=MLASpec(kv_lora_rank=16, q_lora_rank=24, rope_head_dim=8,
                nope_head_dim=16, v_head_dim=16),
)

SHAPES = LM_SHAPES
