"""SASRec (arXiv:1808.09781): 2 blocks, 1 head, seq 50, embed 50."""
from .base import RecsysConfig, RECSYS_SHAPES, reduced

CONFIG = RecsysConfig(
    name="sasrec",
    interaction="self-attn-seq",
    embed_dim=50,
    seq_len=50,
    n_blocks=2,
    n_heads=1,
    item_vocab=2_000_000,  # industrial catalogue; >= retrieval_cand pool
)

SMOKE = reduced(
    CONFIG, name="sasrec-smoke", embed_dim=8, seq_len=10, n_blocks=1,
    item_vocab=500,
)

SHAPES = RECSYS_SHAPES
