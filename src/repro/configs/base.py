"""Architecture configs, shape cells, and input specs for the dry-run.

Every assigned architecture gets a module in this package exposing:
  CONFIG  — the exact published configuration
  SMOKE   — a reduced same-family configuration for CPU smoke tests
  SHAPES  — the arch's shape cells (each lowers train_step or serve_step)

``input_specs(config, cell)`` returns ShapeDtypeStruct stand-ins for every
model input: weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# shape cells
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode | full_graph | minibatch | batch | retrieval
    params: dict[str, int] = field(default_factory=dict)
    note: str = ""


LM_SHAPES = (
    ShapeCell("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeCell("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeCell("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeCell("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)

GNN_SHAPES = (
    ShapeCell(
        "full_graph_sm",
        "full_graph",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433},
    ),
    ShapeCell(
        "minibatch_lg",
        "minibatch",
        {
            "n_nodes": 232_965,
            "n_edges": 114_615_892,
            "batch_nodes": 1024,
            "fanout1": 15,
            "fanout2": 10,
        },
    ),
    ShapeCell(
        "ogb_products",
        "full_graph",
        {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100},
    ),
    ShapeCell(
        "molecule",
        "batched_graphs",
        {"n_nodes": 30, "n_edges": 64, "batch": 128},
    ),
)

RECSYS_SHAPES = (
    ShapeCell("train_batch", "train", {"batch": 65536}),
    ShapeCell("serve_p99", "serve", {"batch": 512}),
    ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    ShapeCell("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)


# --------------------------------------------------------------------------
# configs
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0  # per-expert hidden size


@dataclass(frozen=True)
class MLASpec:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    moe: MoESpec | None = None
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 1024
    moe_ep_axis: str | None = None  # mesh axis for explicit EP annotations
    moe_token_axes: tuple = ()
    mla: MLASpec | None = None
    # local:global attention pattern (gemma3): (n_local, n_global) per cycle
    local_global: tuple[int, int] | None = None
    local_window: int = 1024
    rope_theta: float = 10_000.0
    dtype: Any = jnp.bfloat16
    tie_embeddings: bool = False
    # attention lowering: "einsum" (baseline) | "chunked" (flash-style q-chunk
    # remat; the XLA analogue of kernels/flash_attention for the dry-run)
    attention_impl: str = "einsum"
    attn_block_q: int = 512
    # serve prefill: compute logits only for the last position (vLLM-style)
    prefill_last_only: bool = False
    # Megatron-SP-style residual-stream sharding: constrain the scan carry to
    # (batch over act_batch_axes, seq over act_seq_axes) so saved activations
    # shard over the model axis too (GSPMD inserts the AG/RS pairs)
    act_batch_axes: tuple = ()
    act_seq_axes: tuple = ()
    # streaming CE: scan over vocab chunks (running logsumexp) so the fp32
    # [B,S,V] logits never materialize; 0 = off
    loss_vocab_chunks: int = 0
    # True iff attention is full (quadratic) in every layer -> long_500k skipped
    sub_quadratic: bool = False
    # per-arch sharding-rule overrides: (logical_axis, (mesh axes...)) pairs
    shard_overrides: tuple = ()
    family: str = "lm"

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS = 6*N*D bookkeeping)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hq, hkv, dh = self.n_heads, self.n_kv_heads, self.head_dim
        if self.mla is not None:
            m = self.mla
            attn = (
                d * m.q_lora_rank
                + m.q_lora_rank * hq * (m.nope_head_dim + m.rope_head_dim)
                + d * (m.kv_lora_rank + m.rope_head_dim)
                + m.kv_lora_rank * hq * (m.nope_head_dim + m.v_head_dim)
                + hq * m.v_head_dim * d
            )
        else:
            attn = d * hq * dh + 2 * d * hkv * dh + hq * dh * d
        if self.moe is not None:
            ffn_per_layer = (
                self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                + self.moe.n_shared * 3 * d * self.moe.d_ff_expert
                + d * self.moe.n_experts  # router
            )
        else:
            ffn_per_layer = 3 * d * f
        per_layer = attn + ffn_per_layer + 2 * d  # 2 rmsnorm scales
        n = L * per_layer + V * d + d  # embed + final norm
        if not self.tie_embeddings:
            n += V * d
        return int(n)

    def n_active_params(self) -> int:
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        dense_like = self.n_params()
        all_experts = L * self.moe.n_experts * 3 * d * self.moe.d_ff_expert
        active = L * (self.moe.top_k + self.moe.n_shared) * 3 * d * self.moe.d_ff_expert
        # shared experts always active; replace routed total by top_k
        shared = L * self.moe.n_shared * 3 * d * self.moe.d_ff_expert
        return int(dense_like - all_experts - shared + active)


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    aggregator: str = "gated"
    d_edge: int = 8
    n_classes: int = 47
    dtype: Any = jnp.float32
    # scan-carry sharding constraints (mesh axis names) for node/edge states
    act_node_axes: tuple = ()
    act_edge_axes: tuple = ()
    shard_overrides: tuple = ()
    family: str = "gnn"


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    interaction: str  # transformer-seq | cross | fm-2way | self-attn-seq
    embed_dim: int
    n_dense: int = 0
    n_sparse: int = 0
    seq_len: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    n_cross_layers: int = 0
    mlp: tuple[int, ...] = ()
    vocab_sizes: tuple[int, ...] = ()  # per sparse field
    item_vocab: int = 0  # for sequence models
    dtype: Any = jnp.float32
    family: str = "recsys"


Config = LMConfig | GNNConfig | RecsysConfig


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(config: Config, cell: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
    """Model-input stand-ins for one (arch x shape) cell."""
    if isinstance(config, LMConfig):
        return _lm_input_specs(config, cell)
    if isinstance(config, GNNConfig):
        return _gnn_input_specs(config, cell)
    if isinstance(config, RecsysConfig):
        return _recsys_input_specs(config, cell)
    raise TypeError(type(config))


def _lm_input_specs(cfg: LMConfig, cell: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
    p = cell.params
    B, S = p["global_batch"], p["seq_len"]
    if cell.kind == "train":
        return {
            "tokens": _sds((B, S), jnp.int32),
            "targets": _sds((B, S), jnp.int32),
        }
    if cell.kind == "prefill":
        return {"tokens": _sds((B, S), jnp.int32)}
    if cell.kind == "decode":
        # one new token against a KV cache of length S
        if cfg.mla is not None:
            m = cfg.mla
            cache = {
                "c_kv": _sds((cfg.n_layers, B, S, m.kv_lora_rank), cfg.dtype),
                "k_rope": _sds((cfg.n_layers, B, S, m.rope_head_dim), cfg.dtype),
            }
        else:
            hkv, dh = cfg.n_kv_heads, cfg.head_dim
            cache = {
                "k": _sds((cfg.n_layers, B, S, hkv, dh), cfg.dtype),
                "v": _sds((cfg.n_layers, B, S, hkv, dh), cfg.dtype),
            }
        return {
            "tokens": _sds((B, 1), jnp.int32),
            "cache": cache,
            "cache_len": _sds((B,), jnp.int32),
        }
    raise ValueError(cell.kind)


def _pad512(n: int) -> int:
    """Graph/candidate dims padded to a 512 multiple so every mesh divides
    them (GSPMD rejects uneven shardings).  Padding rows are isolated
    self-loop nodes masked out of the loss — <0.03% overhead at these sizes."""
    return ((n + 511) // 512) * 512


def _gnn_input_specs(cfg: GNNConfig, cell: ShapeCell) -> dict[str, jax.ShapeDtypeStruct]:
    p = cell.params
    if cell.kind == "full_graph":
        n, e, df = _pad512(p["n_nodes"]), _pad512(p["n_edges"]), p["d_feat"]
        return {
            "node_feat": _sds((n, df), cfg.dtype),
            "edge_index": _sds((2, e), jnp.int32),
            "labels": _sds((n,), jnp.int32),
            "train_mask": _sds((n,), jnp.bool_),
        }
    if cell.kind == "minibatch":
        b, f1, f2 = p["batch_nodes"], p["fanout1"], p["fanout2"]
        n_sub = b * (1 + f1 + f1 * f2)  # padded sampled subgraph
        e_sub = b * (f1 + f1 * f2)
        return {
            "node_feat": _sds((n_sub, 602), cfg.dtype),  # reddit d_feat
            "edge_index": _sds((2, e_sub), jnp.int32),
            "labels": _sds((b,), jnp.int32),
            "seed_ids": _sds((b,), jnp.int32),
        }
    if cell.kind == "batched_graphs":
        b, n, e = p["batch"], p["n_nodes"], p["n_edges"]
        return {
            "node_feat": _sds((b, n, 16), cfg.dtype),
            "edge_index": _sds((b, 2, e), jnp.int32),
            "labels": _sds((b,), jnp.int32),
        }
    raise ValueError(cell.kind)


def _recsys_input_specs(
    cfg: RecsysConfig, cell: ShapeCell
) -> dict[str, jax.ShapeDtypeStruct]:
    p = cell.params
    if cell.kind == "retrieval":
        specs = _recsys_batch_specs(cfg, p["batch"])
        specs.pop("labels", None)
        specs["candidate_ids"] = _sds((p["n_candidates"],), jnp.int32)
        return specs
    specs = _recsys_batch_specs(cfg, p["batch"])
    if cell.kind != "train":
        specs.pop("labels", None)
    return specs


def _recsys_batch_specs(cfg: RecsysConfig, B: int) -> dict[str, jax.ShapeDtypeStruct]:
    if cfg.interaction == "cross":  # dcn-v2
        return {
            "dense": _sds((B, cfg.n_dense), cfg.dtype),
            "sparse_ids": _sds((B, cfg.n_sparse), jnp.int32),
            "labels": _sds((B,), cfg.dtype),
        }
    if cfg.interaction == "fm-2way":  # fm
        return {
            "sparse_ids": _sds((B, cfg.n_sparse), jnp.int32),
            "labels": _sds((B,), cfg.dtype),
        }
    if cfg.interaction == "transformer-seq":  # bst
        return {
            "hist_ids": _sds((B, cfg.seq_len), jnp.int32),
            "target_id": _sds((B,), jnp.int32),
            "labels": _sds((B,), cfg.dtype),
        }
    if cfg.interaction == "self-attn-seq":  # sasrec
        return {
            "hist_ids": _sds((B, cfg.seq_len), jnp.int32),
            "pos_ids": _sds((B,), jnp.int32),
            "neg_ids": _sds((B,), jnp.int32),
            "labels": _sds((B,), cfg.dtype),
        }
    raise ValueError(cfg.interaction)


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
_REGISTRY: dict[str, Any] = {}


def register(arch_id: str, module_name: str) -> None:
    _REGISTRY[arch_id] = module_name


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def get_arch(arch_id: str):
    """Returns the config module for an arch id (lazy import)."""
    import importlib

    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    return importlib.import_module(f"repro.configs.{_REGISTRY[arch_id]}")


def get_config(arch_id: str, smoke: bool = False) -> Config:
    mod = get_arch(arch_id)
    return mod.SMOKE if smoke else mod.CONFIG


def get_shapes(arch_id: str) -> tuple[ShapeCell, ...]:
    return get_arch(arch_id).SHAPES


def reduced(config: Config, **overrides) -> Config:
    return dataclasses.replace(config, **overrides)
