"""TinyLlama 1.1B (arXiv:2401.02385): llama2-arch small, GQA kv=4."""
from .base import LMConfig, LM_SHAPES, reduced

CONFIG = LMConfig(
    name="tinyllama-1.1b",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=64,
    d_ff=5632,
    vocab=32000,
    sub_quadratic=False,  # pure full attention -> long_500k skipped
)

SMOKE = reduced(
    CONFIG, name="tinyllama-smoke", n_layers=2, d_model=64, n_heads=8,
    n_kv_heads=2, d_head=8, d_ff=128, vocab=256,
)

SHAPES = LM_SHAPES
