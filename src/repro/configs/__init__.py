"""Architecture registry: --arch <id> resolves here."""
from .base import (
    Config,
    GNNConfig,
    LMConfig,
    MLASpec,
    MoESpec,
    RecsysConfig,
    ShapeCell,
    get_arch,
    get_config,
    get_shapes,
    input_specs,
    list_archs,
    register,
)

register("deepseek-7b", "deepseek_7b")
register("gemma3-4b", "gemma3_4b")
register("tinyllama-1.1b", "tinyllama_1_1b")
register("qwen2-moe-a2.7b", "qwen2_moe_a2_7b")
register("deepseek-v2-236b", "deepseek_v2_236b")
register("gatedgcn", "gatedgcn")
register("bst", "bst")
register("dcn-v2", "dcn_v2")
register("fm", "fm")
register("sasrec", "sasrec")

__all__ = [
    "Config",
    "GNNConfig",
    "LMConfig",
    "MLASpec",
    "MoESpec",
    "RecsysConfig",
    "ShapeCell",
    "get_arch",
    "get_config",
    "get_shapes",
    "input_specs",
    "list_archs",
    "register",
]
