"""BST (arXiv:1905.06874): Behaviour Sequence Transformer (Alibaba)."""
from .base import RecsysConfig, RECSYS_SHAPES, reduced

CONFIG = RecsysConfig(
    name="bst",
    interaction="transformer-seq",
    embed_dim=32,
    seq_len=20,
    n_blocks=1,
    n_heads=8,
    mlp=(1024, 512, 256),
    item_vocab=4_000_000,  # Taobao-scale item catalogue
)

SMOKE = reduced(
    CONFIG, name="bst-smoke", embed_dim=8, seq_len=6, n_heads=2,
    mlp=(32, 16), item_vocab=1000,
)

SHAPES = RECSYS_SHAPES
