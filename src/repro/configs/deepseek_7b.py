"""DeepSeek-LLM 7B (arXiv:2401.02954): llama-arch dense, MHA (GQA kv=32)."""
from .base import LMConfig, LM_SHAPES, reduced

CONFIG = LMConfig(
    name="deepseek-7b",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=11008,
    vocab=102400,
    sub_quadratic=False,  # pure full attention -> long_500k skipped (DESIGN §4)
)

SMOKE = reduced(
    CONFIG, name="deepseek-7b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_head=16, d_ff=128, vocab=256,
)

SHAPES = LM_SHAPES
