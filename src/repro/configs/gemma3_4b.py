"""Gemma-3 4B (hf:google/gemma-3-*): 5:1 local:global attention, 128k ctx."""
from .base import LMConfig, LM_SHAPES, reduced

CONFIG = LMConfig(
    name="gemma3-4b",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10240,
    vocab=262144,
    local_global=(5, 1),
    local_window=1024,
    rope_theta=1_000_000.0,
    sub_quadratic=True,  # hybrid local:global -> long_500k runs
)

SMOKE = reduced(
    CONFIG, name="gemma3-4b-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=2, d_head=16, d_ff=128, vocab=256, local_global=(2, 1),
    local_window=8,
)

SHAPES = LM_SHAPES
