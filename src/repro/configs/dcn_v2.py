"""DCN-v2 (arXiv:2008.13535): 13 dense + 26 sparse (Criteo), 3 cross layers."""
from .base import RecsysConfig, RECSYS_SHAPES, reduced

# Criteo-Kaggle-like per-field cardinalities (sum ~33.8M)
_CRITEO_VOCABS = (
    1461, 584, 10_131_227, 2_202_608, 306, 24, 12_518, 634, 4, 93_146,
    5684, 8_351_593, 3195, 28, 14_993, 5_461_306, 11, 5653, 2173, 4,
    7_046_547, 18, 16, 286_181, 105, 142_572,
)

CONFIG = RecsysConfig(
    name="dcn-v2",
    interaction="cross",
    embed_dim=16,
    n_dense=13,
    n_sparse=26,
    n_cross_layers=3,
    mlp=(1024, 1024, 512),
    vocab_sizes=_CRITEO_VOCABS,
)

SMOKE = reduced(
    CONFIG, name="dcn-v2-smoke", embed_dim=4, n_dense=4, n_sparse=5,
    n_cross_layers=2, mlp=(16, 8), vocab_sizes=(50, 100, 20, 80, 10),
)

SHAPES = RECSYS_SHAPES
