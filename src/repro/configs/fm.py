"""FM (Rendle ICDM'10): pairwise interactions via the O(nk) sum-square trick."""
from .base import RecsysConfig, RECSYS_SHAPES, reduced

# 39 sparse fields (Criteo-TB style: 13 bucketized dense + 26 categorical)
_FM_VOCABS = tuple([100] * 13 + list((
    1461, 584, 8_000_000, 2_202_608, 306, 24, 12_518, 634, 4, 93_146,
    5684, 6_500_000, 3195, 28, 14_993, 5_461_306, 11, 5653, 2173, 4,
    7_046_547, 18, 16, 286_181, 105, 142_572,
)))

CONFIG = RecsysConfig(
    name="fm",
    interaction="fm-2way",
    embed_dim=10,
    n_sparse=39,
    vocab_sizes=_FM_VOCABS,
)

SMOKE = reduced(
    CONFIG, name="fm-smoke", embed_dim=4, n_sparse=6,
    vocab_sizes=(50, 100, 20, 80, 10, 30),
)

SHAPES = RECSYS_SHAPES
