from ..sched.service import WorkflowService
from ..sched.stats import AggregateStats
from .engine import GenStats, ServeEngine

__all__ = ["AggregateStats", "GenStats", "ServeEngine", "WorkflowService"]
