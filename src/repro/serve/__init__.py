from .engine import GenStats, ServeEngine

__all__ = ["GenStats", "ServeEngine"]
