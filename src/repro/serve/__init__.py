from ..sched.service import WorkflowService
from ..sched.stats import AggregateStats
from .engine import GenStats, ServeEngine, ServeMetrics
from .snapshots import (
    FabricSnapshotStore,
    LoadedSnapshot,
    MemorySnapshotStore,
    SnapshotStore,
)

__all__ = [
    "AggregateStats",
    "FabricSnapshotStore",
    "GenStats",
    "LoadedSnapshot",
    "MemorySnapshotStore",
    "ServeEngine",
    "ServeMetrics",
    "SnapshotStore",
    "WorkflowService",
]
