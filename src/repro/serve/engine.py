"""Serving engine with a RISP-guided KV-prefix cache (beyond-paper feature).

The thesis' Ch. 2.5 contrasts its persistent intermediate-data store with
web-service caching; this module closes the loop for LLM serving: a request's
prompt is a *workflow* — the token stream chunked into fixed-size modules —
and RISP's association mining over the request history decides which prefix
KV states to retain.  High-confidence shared prefixes (system prompts,
few-shot preambles) get their KV snapshot stored; later requests skip
prefilling them.  This is RadixAttention-style prefix caching with a
*mined admission policy* instead of cache-everything + LRU.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import LMConfig
from ..core.eviction import EvictionContext, EvictionManager
from ..core.registry import ModuleRegistry
from ..core.risp import RISP, StoragePolicy
from ..core.store import ArtifactRecord
from ..core.workflow import ModuleRef, Workflow
from ..models import transformer
from ..sched.stats import AggregateStats


def _chunk_id(tokens: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(tokens).tobytes()).hexdigest()[:12]


@dataclass
class GenStats:
    prompt_len: int
    n_chunks: int
    chunks_skipped: int
    prefill_s: float
    decode_s: float
    stored_prefixes: int
    n_new_tokens: int


@dataclass
class ServeEngine:
    cfg: LMConfig
    params: Any
    max_len: int = 512
    chunk: int = 32
    policy: StoragePolicy = field(default_factory=RISP)
    greedy: bool = True
    # KV-snapshot memory budget: same gain-loss retention as the disk store
    snapshot_budget_bytes: int | None = None
    eviction: str = "gain_loss"
    # optional shared ModuleRegistry: observed prompt chunks are recorded as
    # (non-executable) modules with prefill-cost hints, so the serving
    # workload's module universe is introspectable through the same registry
    # the workflow engines consume (repro.api.Client wires one across all
    # front doors)
    registry: ModuleRegistry | None = None

    def __post_init__(self) -> None:
        self._snapshots: dict[str, tuple[Any, int]] = {}  # key -> (host cache, len)
        self._snap_records: dict[str, ArtifactRecord] = {}
        self._evictor = EvictionManager(self.snapshot_budget_bytes, self.eviction)
        self._chunk_prefill_s = 0.0  # EMA seconds to prefill one chunk
        # O(1) running aggregates (a serving process outlives any per-request
        # history it could afford to keep)
        self._agg = AggregateStats()
        self._t_first: float | None = None
        self._t_last = 0.0
        self._prefill = jax.jit(
            lambda p, t, c, l: transformer.prefill_chunk(p, self.cfg, t, c, l)
        )
        self._decode = jax.jit(
            lambda p, t, c, l: transformer.decode_step(p, self.cfg, t, c, l)
        )

    # -- RISP bookkeeping over request chunks ------------------------------
    def _workflow(self, chunks: list[np.ndarray]) -> Workflow:
        mods = tuple(ModuleRef(_chunk_id(c)) for c in chunks)
        if self.registry is not None:
            for m in mods:
                self.registry.ensure(
                    m.module_id, cost_hint=self._chunk_prefill_s or None
                )
        return Workflow("prompts", mods, workflow_id=f"req{self.policy.n_pipelines}")

    def _snapshot(self, key: str, cache: Any, length: int, depth: int) -> bool:
        """Store a KV snapshot; returns False if the budget rejects it."""
        host = jax.tree_util.tree_map(lambda a: np.asarray(a), cache)
        nbytes = sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(host))
        if not self._evictor.admits(nbytes):
            return False
        self._snapshots[key] = (host, length)
        # recompute cost of this snapshot = re-prefilling ``depth`` chunks
        self._snap_records[key] = ArtifactRecord(
            key, nbytes, nbytes, save_s=0.0, compute_s=self._chunk_prefill_s * depth
        )
        victims = self._evictor.select_victims(
            self._snap_records, self.snapshot_bytes(),
            ctx=EvictionContext(load_bps=4e9), incoming=key,
        )
        for victim in victims:
            self._drop_snapshot(victim)
        return key not in victims

    def _drop_snapshot(self, key: str) -> None:
        self._snapshots.pop(key, None)
        self._snap_records.pop(key, None)
        self.policy.stored.pop(key, None)

    def _restore(self, key: str) -> tuple[Any, int]:
        host, length = self._snapshots[key]
        rec = self._snap_records.get(key)
        if rec is not None:
            rec.n_loads += 1
            rec.last_used_at = time.time()
        return jax.tree_util.tree_map(jnp.asarray, host), length

    # -- generation ---------------------------------------------------------
    def generate(
        self, prompt: list[int] | np.ndarray, max_new_tokens: int = 16
    ) -> tuple[list[int], GenStats]:
        prompt = np.asarray(prompt, np.int32)
        pad = (-len(prompt)) % self.chunk
        padded = np.concatenate([np.zeros(pad, np.int32), prompt])  # left-pad
        chunks = [padded[i : i + self.chunk] for i in range(0, len(padded), self.chunk)]
        wf = self._workflow(chunks)
        rec = self.policy.step(wf)

        # longest stored prefix with a live snapshot
        start, cache, cache_len_i = 0, None, 0
        cand = rec.reuse
        while cand is not None:
            key = cand.key(self.policy.with_state)
            if key in self._snapshots:
                cache, cache_len_i = self._restore(key)
                start = cand.depth
                break
            self.policy.stored.pop(key, None)
            cand = cand.parent()
        if cache is None:
            cache = transformer.init_cache(self.cfg, 1, self.max_len)

        t0 = time.perf_counter()
        cache_len = jnp.asarray([cache_len_i], jnp.int32)
        logits = None
        boundary_caches: dict[int, tuple[Any, int]] = {}
        for i in range(start, len(chunks)):
            tok = jnp.asarray(chunks[i][None], jnp.int32)
            tc = time.perf_counter()
            logits, cache, cache_len = self._prefill(self.params, tok, cache, cache_len)
            jax.block_until_ready(logits)
            dt = time.perf_counter() - tc
            self._chunk_prefill_s = (
                dt if not self._chunk_prefill_s
                else 0.3 * dt + 0.7 * self._chunk_prefill_s
            )
            boundary_caches[i + 1] = (cache, int(cache_len[0]))
        prefill_s = time.perf_counter() - t0

        # store admitted prefixes (only those whose boundary we computed)
        stored = 0
        for prefix in rec.store:
            key = prefix.key(self.policy.with_state)
            if prefix.depth in boundary_caches:
                c, ln = boundary_caches[prefix.depth]
                if self._snapshot(key, c, ln, prefix.depth):
                    stored += 1
                else:  # snapshot alone exceeds the whole budget
                    self.policy.stored.pop(key, None)
            else:
                self.policy.stored.pop(key, None)

        # decode
        t1 = time.perf_counter()
        out: list[int] = []
        if logits is None:  # full-prompt cache hit: re-run last chunk's logits
            tok = jnp.asarray(chunks[-1][None], jnp.int32)
            trimmed_cache, trimmed_len = self._trim_last_chunk(cache, cache_len)
            logits, cache, cache_len = self._prefill(
                self.params, tok, trimmed_cache, trimmed_len
            )
        for _ in range(max_new_tokens):
            nxt = int(jnp.argmax(logits[0]))
            out.append(nxt)
            tok = jnp.asarray([[nxt]], jnp.int32)
            logits, cache, cache_len = self._decode(self.params, tok, cache, cache_len)
        decode_s = time.perf_counter() - t1

        stats = GenStats(
            prompt_len=len(prompt),
            n_chunks=len(chunks),
            chunks_skipped=start,
            prefill_s=prefill_s,
            decode_s=decode_s,
            stored_prefixes=stored,
            n_new_tokens=len(out),
        )
        if self._t_first is None:
            self._t_first = t0
        self._t_last = time.perf_counter()
        self._agg.runs += 1
        self._agg.busy_seconds += stats.prefill_s + stats.decode_s
        self._agg.units_total += stats.n_chunks
        self._agg.units_skipped += stats.chunks_skipped
        self._agg.stored += stats.stored_prefixes
        return out, stats

    def _trim_last_chunk(self, cache, cache_len):
        """Full-prefix hit: zero out the last chunk's slots and re-prefill it
        to recover last-position logits (snapshots store caches, not logits)."""
        ln = int(cache_len[0]) - self.chunk
        T = jax.tree_util.tree_leaves(cache)[0].shape[2]
        keep = (jnp.arange(T) < ln).astype(jax.tree_util.tree_leaves(cache)[0].dtype)

        def zero_tail(a):
            shape = (1, 1, T) + (1,) * (a.ndim - 3)
            return a * keep.reshape(shape)

        return (
            jax.tree_util.tree_map(zero_tail, cache),
            jnp.asarray([ln], jnp.int32),
        )

    # -- accounting -----------------------------------------------------------
    @property
    def n_snapshots(self) -> int:
        return len(self._snapshots)

    @property
    def n_snapshot_evictions(self) -> int:
        return self._evictor.n_evictions

    def snapshot_bytes(self) -> int:
        total = 0
        for host, _ in self._snapshots.values():
            for leaf in jax.tree_util.tree_leaves(host):
                total += leaf.nbytes
        return total

    def aggregate_stats(self) -> AggregateStats:
        """Fleet-level view in the scheduler service's shape: one request =
        one run, one prompt chunk = one work unit (skipped = prefill reuse)."""
        wall = (
            (self._t_last - self._t_first)
            if self._t_first is not None and self._t_last
            else 0.0
        )
        return AggregateStats(
            runs=self._agg.runs,
            wall_seconds=max(wall, 0.0),
            busy_seconds=self._agg.busy_seconds,
            units_total=self._agg.units_total,
            units_skipped=self._agg.units_skipped,
            stored=self._agg.stored,
        )
