"""Serving engine with a RISP-guided KV-prefix cache (beyond-paper feature).

The thesis' Ch. 2.5 contrasts its persistent intermediate-data store with
web-service caching; this module closes the loop for LLM serving: a request's
prompt is a *workflow* — the token stream chunked into fixed-size modules —
and RISP's association mining over the request history decides which prefix
KV states to retain.  High-confidence shared prefixes (system prompts,
few-shot preambles) get their KV snapshot stored; later requests skip
prefilling them.  This is RadixAttention-style prefix caching with a
*mined admission policy* instead of cache-everything + LRU.

Where snapshots live is a seam (:mod:`repro.serve.snapshots`): the default
:class:`MemorySnapshotStore` keeps the legacy engine-private behavior, while
a :class:`FabricSnapshotStore` puts snapshots on the shared artifact fabric
so N serving processes reuse each other's prefills.  On the fabric, prefill
itself becomes a *coordinated compute*: when a ``flight``
(:class:`~repro.net.flight.DistributedSingleFlight`) is wired, exactly one
engine fleet-wide prefills a shared prefix (the leader stores the snapshot;
followers block on the lease, then load it) — the same exactly-once
discipline the workflow scheduler applies to module computes.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from ..configs.base import LMConfig
from ..core.registry import ModuleRegistry
from ..core.risp import RISP, StoragePolicy, StoredRecord
from ..core.workflow import ModuleRef, Workflow
from ..models import transformer
from ..obs import tracing as _tracing
from ..obs.metrics import MetricsRegistry
from ..sched.singleflight import SingleFlight
from ..sched.stats import AggregateStats
from .snapshots import MemorySnapshotStore, SnapshotStore


def _chunk_id(tokens: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(tokens).tobytes()).hexdigest()[:12]


@dataclass
class GenStats:
    prompt_len: int
    n_chunks: int
    chunks_skipped: int
    prefill_s: float
    decode_s: float
    stored_prefixes: int
    n_new_tokens: int


class ServeMetrics:
    """The canonical ``repro_serve_*`` instruments.

    One home for every serving counter; :meth:`ServeEngine.aggregate_stats`
    is reconstructed from these (the legacy ``AggregateStats`` shape survives
    as an alias — see ``obs/naming.py::ALIASES``).
    """

    def __init__(self, registry: MetricsRegistry) -> None:
        m = registry
        self.requests = m.counter(
            "repro_serve_requests_total", "generation requests served"
        )
        self.chunks = m.counter(
            "repro_serve_chunks_total", "prompt chunks across all requests"
        )
        self.chunks_skipped = m.counter(
            "repro_serve_chunks_skipped_total",
            "prompt chunks skipped by restoring a KV snapshot",
        )
        self.tokens = m.counter("repro_serve_tokens_total", "new tokens decoded")
        self.stored = m.counter(
            "repro_serve_snapshots_stored_total",
            "KV snapshot admissions by this engine",
        )
        self.busy = m.counter(
            "repro_serve_busy_seconds_total", "seconds spent prefilling + decoding"
        )
        self.saved = m.counter(
            "repro_serve_prefill_saved_seconds_total",
            "prefill seconds avoided by snapshot reuse (measured cost minus load)",
        )
        self.prefill_s = m.histogram(
            "repro_serve_prefill_seconds", "per-request prefill wall seconds"
        )
        self.decode_s = m.histogram(
            "repro_serve_decode_seconds", "per-request decode wall seconds"
        )


@dataclass
class _PrefixResult:
    """State after materializing a prompt prefix (the coordinated unit)."""

    cache: Any  # device cache pytree at ``depth`` chunks
    cache_len: Any  # jnp [1] int32
    depth: int  # chunks materialized in ``cache``
    logits: Any | None  # logits of the last prefilled chunk (None: none ran)
    skipped: int  # chunks restored from a snapshot instead of prefilled
    stored: int  # snapshot admissions performed
    prefill_s: float  # wall seconds of prefill done here


@dataclass
class ServeEngine:
    cfg: LMConfig
    params: Any
    max_len: int = 512
    chunk: int = 32
    policy: StoragePolicy = field(default_factory=RISP)
    greedy: bool = True
    # KV-snapshot budget for the default in-memory tier: same gain-loss
    # retention as the disk store (ignored when ``snapshots`` is passed)
    snapshot_budget_bytes: int | None = None
    eviction: str = "gain_loss"
    # optional shared ModuleRegistry: observed prompt chunks are recorded as
    # (non-executable) modules with prefill-cost hints, so the serving
    # workload's module universe is introspectable through the same registry
    # the workflow engines consume (repro.api.Client wires one across all
    # front doors)
    registry: ModuleRegistry | None = None
    # where snapshots live (None -> engine-private MemorySnapshotStore);
    # pass a FabricSnapshotStore to share prefills across processes
    snapshots: SnapshotStore | None = None
    # single-flight election over shared-prefix prefills; a
    # DistributedSingleFlight makes the election fleet-wide
    flight: SingleFlight | None = None
    metrics: MetricsRegistry | None = None
    # dataset identity of the prompt workflows (Client.serve_engine composes
    # its namespace in, so snapshot keys are tenant-scoped like any artifact)
    dataset_id: str = "prompts"

    def __post_init__(self) -> None:
        if self.metrics is None:
            self.metrics = (
                self.snapshots.metrics
                if self.snapshots is not None
                else MetricsRegistry()
            )
        if self.snapshots is None:
            self.snapshots = MemorySnapshotStore(
                self.snapshot_budget_bytes, self.eviction, registry=self.metrics
            )
        # every removal path (budget eviction, fleet event, phantom probe)
        # funnels through the store's listeners: the policy's claim of the
        # snapshot dies with the snapshot — never the other way around.
        # GIL-atomic pop without the policy lock (documented lock order).
        self.snapshots.add_evict_listener(
            lambda key: self.policy.stored.pop(key, None)
        )
        self._sm = ServeMetrics(self.metrics)
        self._chunk_prefill_s = 0.0  # EMA seconds to prefill one chunk
        self._t_first: float | None = None
        self._t_last = 0.0
        self._prefill = jax.jit(
            lambda p, t, c, l: transformer.prefill_chunk(p, self.cfg, t, c, l)
        )
        self._decode = jax.jit(
            lambda p, t, c, l: transformer.decode_step(p, self.cfg, t, c, l)
        )

    # -- RISP bookkeeping over request chunks ------------------------------
    def _workflow(self, chunks: list[np.ndarray]) -> Workflow:
        mods = tuple(ModuleRef(_chunk_id(c)) for c in chunks)
        if self.registry is not None:
            for m in mods:
                self.registry.ensure(
                    m.module_id, cost_hint=self._chunk_prefill_s or None
                )
        return Workflow(
            self.dataset_id, mods, workflow_id=f"req{self.policy.n_pipelines}"
        )

    def _load_snapshot(self, key: str, depth: int) -> "Any | None":
        """Restore one snapshot, crediting the measured time it saved."""
        with _tracing.span("serve.snapshot.load", kind="serve", key=key) as sp:
            snap = self.snapshots.load(key)
            if snap is None:
                sp.set(status="miss")
                return None
            recompute = snap.prefill_s or depth * self._chunk_prefill_s
            saved = max(recompute - snap.load_s, 0.0)
            sp.set(source="snapshot", saved_s=round(saved, 6), depth=depth)
            self._sm.saved.inc(saved)
        return snap

    # -- prefill -------------------------------------------------------------
    def _prefill_prefix(
        self,
        chunks: list[np.ndarray],
        wf: Workflow,
        rec: Any,
        depth_keys: dict[int, str],
        upto: int,
        presence: "dict[str, bool | None] | None" = None,
    ) -> _PrefixResult:
        """Materialize the first ``upto`` chunks: restore the deepest live
        snapshot, prefill the rest, store what the policy admitted.

        This is the unit a single-flight leader runs.  A follower re-running
        it after the leader finishes re-probes and finds the leader's
        snapshot — so it prefills nothing (the exactly-once property)."""
        ws = self.policy.with_state
        if presence is None:
            presence = self.snapshots.presence_many(list(depth_keys.values()))
        # authoritative absences invalidate any claim the policy still holds
        # (same discipline as the executor's probe walk; ``None`` =
        # unreachable is deliberately NOT evidence of absence) — except the
        # claims ``policy.step`` just admitted for THIS request: those are
        # pending the save below, not stale
        pending = {p.key(ws) for p in rec.store}
        for key in depth_keys.values():
            if presence.get(key) is False and key not in pending:
                self.policy.stored.pop(key, None)

        start, cache, cache_len_i = 0, None, 0
        for d in range(upto, 0, -1):
            key = depth_keys[d]
            if not presence.get(key):
                continue
            snap = self._load_snapshot(key, d)
            if snap is None:
                continue  # phantom: the store already pruned + notified
            cache = jax.tree_util.tree_map(jnp.asarray, snap.cache)
            cache_len_i = snap.length
            start = d
            # cross-process adoption: mining in this process may never have
            # admitted this prefix — record that it is stored so the policy
            # recommends reusing it next time
            self.policy.stored.setdefault(
                key, StoredRecord(wf.prefix(d), self.policy.n_pipelines)
            )
            break
        if cache is None:
            cache = transformer.init_cache(self.cfg, 1, self.max_len)

        t0 = time.perf_counter()
        cache_len = jnp.asarray([cache_len_i], jnp.int32)
        logits = None
        boundary: dict[int, tuple[Any, int]] = {}
        # measured recompute cost of each boundary's prefix: seconds actually
        # spent this request, plus the EMA-priced skipped part — this is what
        # gain-loss eviction will charge to re-create the snapshot
        boundary_cost: dict[int, float] = {}
        base_cost = start * self._chunk_prefill_s
        cum = 0.0
        for i in range(start, upto):
            tok = jnp.asarray(chunks[i][None], jnp.int32)
            tc = time.perf_counter()
            logits, cache, cache_len = self._prefill(self.params, tok, cache, cache_len)
            jax.block_until_ready(logits)
            dt = time.perf_counter() - tc
            self._chunk_prefill_s = (
                dt if not self._chunk_prefill_s
                else 0.3 * dt + 0.7 * self._chunk_prefill_s
            )
            cum += dt
            boundary[i + 1] = (cache, int(cache_len[0]))
            boundary_cost[i + 1] = base_cost + cum
        prefill_s = time.perf_counter() - t0

        # store admitted prefixes (only those whose boundary we computed)
        stored = 0
        for prefix in rec.store:
            if prefix.depth > upto:
                continue  # a later (uncoordinated) stage never stores
            key = prefix.key(ws)
            if prefix.depth in boundary:
                c, ln = boundary[prefix.depth]
                if self.snapshots.save(
                    key, c, ln,
                    prefill_s=boundary_cost[prefix.depth],
                    prefix=prefix,
                ):
                    stored += 1
                else:  # budget (or fabric) rejected the snapshot
                    self.policy.stored.pop(key, None)
            elif presence.get(key):
                pass  # inside the restored region: already on the store
            else:
                self.policy.stored.pop(key, None)
        return _PrefixResult(
            cache=cache,
            cache_len=cache_len,
            depth=upto,
            logits=logits,
            skipped=start,
            stored=stored,
            prefill_s=prefill_s,
        )

    # -- generation ---------------------------------------------------------
    def generate(
        self, prompt: list[int] | np.ndarray, max_new_tokens: int = 16
    ) -> tuple[list[int], GenStats]:
        prompt = np.asarray(prompt, np.int32)
        pad = (-len(prompt)) % self.chunk
        padded = np.concatenate([np.zeros(pad, np.int32), prompt])  # left-pad
        chunks = [padded[i : i + self.chunk] for i in range(0, len(padded), self.chunk)]
        n = len(chunks)
        wf = self._workflow(chunks)
        rec = self.policy.step(wf)
        ws = self.policy.with_state
        depth_keys = {d: wf.prefix(d).key(ws) for d in range(1, n + 1)}

        # the coordination unit: the deepest prefix this request was asked to
        # store — fleet-wide, exactly one engine should prefill it
        coord_depth = max((p.depth for p in rec.store), default=0)

        with _tracing.span("serve.prefill", kind="serve") as sp:
            t_pf = time.perf_counter()
            if self.flight is not None and coord_depth > 0:
                value, leader = self.flight.run(
                    depth_keys[coord_depth],
                    lambda: self._prefill_prefix(
                        chunks, wf, rec, depth_keys, upto=coord_depth
                    ),
                )
                if not leader:
                    # coalesced in-process behind the leader: the shared
                    # prefix arrived computed — all of it counts as skipped
                    value = _PrefixResult(
                        cache=value.cache,
                        cache_len=value.cache_len,
                        depth=value.depth,
                        logits=value.logits,
                        skipped=value.depth,
                        stored=0,
                        prefill_s=0.0,
                    )
            else:
                value = self._prefill_prefix(chunks, wf, rec, depth_keys, upto=n)

            # uncoordinated remainder: this request's private suffix
            cache, cache_len = value.cache, value.cache_len
            logits = value.logits
            t_ext = time.perf_counter()
            for i in range(value.depth, n):
                tok = jnp.asarray(chunks[i][None], jnp.int32)
                tc = time.perf_counter()
                logits, cache, cache_len = self._prefill(
                    self.params, tok, cache, cache_len
                )
                jax.block_until_ready(logits)
                dt = time.perf_counter() - tc
                self._chunk_prefill_s = (
                    dt if not self._chunk_prefill_s
                    else 0.3 * dt + 0.7 * self._chunk_prefill_s
                )
            prefill_s = value.prefill_s + (time.perf_counter() - t_ext)
            sp.set(n=n, skipped=value.skipped, stored=value.stored)
        stored = value.stored

        # decode
        t1 = time.perf_counter()
        out: list[int] = []
        if logits is None:  # full-prompt cache hit: re-run last chunk's logits
            tok = jnp.asarray(chunks[-1][None], jnp.int32)
            trimmed_cache, trimmed_len = self._trim_last_chunk(cache, cache_len)
            logits, cache, cache_len = self._prefill(
                self.params, tok, trimmed_cache, trimmed_len
            )
        for _ in range(max_new_tokens):
            nxt = int(jnp.argmax(logits[0]))
            out.append(nxt)
            tok = jnp.asarray([[nxt]], jnp.int32)
            logits, cache, cache_len = self._decode(self.params, tok, cache, cache_len)
        decode_s = time.perf_counter() - t1

        stats = GenStats(
            prompt_len=len(prompt),
            n_chunks=n,
            chunks_skipped=value.skipped,
            prefill_s=prefill_s,
            decode_s=decode_s,
            stored_prefixes=stored,
            n_new_tokens=len(out),
        )
        if self._t_first is None:
            self._t_first = t_pf
        self._t_last = time.perf_counter()
        m = self._sm
        m.requests.inc()
        m.chunks.inc(stats.n_chunks)
        m.chunks_skipped.inc(stats.chunks_skipped)
        m.tokens.inc(stats.n_new_tokens)
        m.stored.inc(stats.stored_prefixes)
        m.busy.inc(stats.prefill_s + stats.decode_s)
        m.prefill_s.observe(stats.prefill_s)
        m.decode_s.observe(stats.decode_s)
        return out, stats

    def _trim_last_chunk(self, cache, cache_len):
        """Full-prefix hit: zero out the last chunk's slots and re-prefill it
        to recover last-position logits (snapshots store caches, not logits)."""
        ln = int(cache_len[0]) - self.chunk
        T = jax.tree_util.tree_leaves(cache)[0].shape[2]
        keep = (jnp.arange(T) < ln).astype(jax.tree_util.tree_leaves(cache)[0].dtype)

        def zero_tail(a):
            shape = (1, 1, T) + (1,) * (a.ndim - 3)
            return a * keep.reshape(shape)

        return (
            jax.tree_util.tree_map(zero_tail, cache),
            jnp.asarray([ln], jnp.int32),
        )

    # -- accounting -----------------------------------------------------------
    @property
    def n_snapshots(self) -> int:
        return self.snapshots.n_snapshots

    @property
    def n_snapshot_evictions(self) -> int:
        return self.snapshots.n_evictions

    def snapshot_bytes(self) -> int:
        return self.snapshots.snapshot_bytes()

    def aggregate_stats(self) -> AggregateStats:
        """Fleet-level view in the scheduler service's shape: one request =
        one run, one prompt chunk = one work unit (skipped = prefill reuse).
        Reconstructed from the canonical ``repro_serve_*`` registry series
        (the legacy shape is an alias — ``obs/naming.py::ALIASES``)."""
        wall = (
            (self._t_last - self._t_first)
            if self._t_first is not None and self._t_last
            else 0.0
        )
        m = self._sm
        return AggregateStats(
            runs=int(m.requests.value),
            wall_seconds=max(wall, 0.0),
            busy_seconds=m.busy.value,
            units_total=int(m.chunks.value),
            units_skipped=int(m.chunks_skipped.value),
            stored=int(m.stored.value),
        )
