"""The ``SnapshotStore`` seam: where a serving engine's KV snapshots live.

Before this seam, :class:`~repro.serve.engine.ServeEngine` kept its prefix
KV snapshots in a per-process dict (``_snapshots``/``_snap_records``/
``_evictor``) — intermediate data the thesis says belongs in a *shared*
store stayed engine-private, so N serving processes each re-prefilled the
same system prompts.  Two implementations now stand behind one interface:

* :class:`MemorySnapshotStore` — the extracted legacy tier: host-RAM
  snapshots, per-process, gain-loss bounded.  Zero new failure modes.
* :class:`FabricSnapshotStore` — snapshots as first-class artifacts on any
  :class:`~repro.core.backends.StorageBackend` (LocalFS for single-host
  persistence; ``RemoteBackend``/``ShardedBackend`` — usually behind a
  ``CachingBackend`` hot tier — for fleet-wide reuse), encoded by the
  deterministic KV codec (:mod:`repro.core.kvcodec`).

Consistency discipline (the PR 8 zero-phantom contract, applied to serving):
every way a snapshot can disappear — local gain-loss eviction, another
process's eviction arriving on the event stream, or an authoritative absence
discovered by a probe/load — funnels through one ``_forget`` path that drops
the record, fires the evict listeners (the engine wires ``policy.stored``
there), discards the catalog row, and credits the tenant ledger.  Catalog,
policy and ledger therefore converge no matter where the eviction happened.

Eviction is priced by **measured** prefill seconds: the engine passes the
wall-clock cost of computing each snapshot's prefix, the codec persists it
in the manifest, and an adopting process (which never ran that prefill)
prices the artifact identically — gain-loss scores are fleet-consistent.
"""
from __future__ import annotations

import threading
import time
from abc import ABC, abstractmethod
from dataclasses import dataclass
from typing import Any, Callable, Iterable

import numpy as np

import jax

from ..core.backends import BackendUnavailable, StorageBackend
from ..core.eviction import EvictionContext, EvictionManager
from ..core.kvcodec import load_kv, save_kv
from ..core.store import ArtifactRecord
from ..core.workflow import PrefixKey
from ..obs.metrics import MetricsRegistry

__all__ = [
    "FabricSnapshotStore",
    "LoadedSnapshot",
    "MemorySnapshotStore",
    "SnapshotStore",
]


@dataclass
class LoadedSnapshot:
    """One restored snapshot: host-side cache pytree + its provenance."""

    cache: Any  # host (numpy) pytree — caller moves it on-device
    length: int  # valid cache positions (prefix length in tokens)
    prefill_s: float  # measured seconds a fresh prefill of this prefix costs
    load_s: float  # measured seconds this load took


def _host_tree(cache: Any) -> tuple[Any, int]:
    host = jax.tree_util.tree_map(lambda a: np.asarray(a), cache)
    nbytes = sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(host))
    return host, nbytes


class SnapshotStore(ABC):
    """Where prefix KV snapshots live, and who learns when they die.

    ``save``/``load`` move whole snapshots; ``presence_many`` answers the
    deep-prefix probe in one batched round trip (tri-state: ``None`` =
    unreachable, never treated as absent).  Evict listeners fire for *every*
    removal path — the engine keeps ``policy.stored`` consistent through
    them, exactly like the workflow store's listener contract.
    """

    def __init__(self, registry: MetricsRegistry | None = None) -> None:
        self.metrics = registry if registry is not None else MetricsRegistry()
        m = self.metrics
        self._m_saves = m.counter(
            "repro_serve_snapshot_saves_total", "KV snapshots persisted"
        )
        self._m_loads = m.counter(
            "repro_serve_snapshot_loads_total", "KV snapshots restored"
        )
        self._m_drops = m.counter(
            "repro_serve_snapshot_evictions_total",
            "KV snapshots dropped, by cause",
            labels=("source",),
        )
        self._m_save_s = m.histogram(
            "repro_serve_snapshot_save_seconds", "seconds to persist one snapshot"
        )
        self._m_load_s = m.histogram(
            "repro_serve_snapshot_load_seconds", "seconds to restore one snapshot"
        )
        m.gauge(
            "repro_serve_snapshots", "live KV snapshots known here"
        ).unlabeled.set_function(lambda: float(self.n_snapshots))
        m.gauge(
            "repro_serve_snapshot_stored_bytes", "bytes of live KV snapshots"
        ).unlabeled.set_function(lambda: float(self.snapshot_bytes()))
        self._listeners: list[Callable[[str], None]] = []

    # -- listener plumbing (shared) -----------------------------------------
    def add_evict_listener(self, fn: Callable[[str], None]) -> None:
        """``fn(key)`` fires whenever ``key`` stops being loadable here —
        local eviction, fleet eviction event, or discovered phantom."""
        self._listeners.append(fn)

    def _fire(self, key: str) -> None:
        for fn in list(self._listeners):
            try:
                fn(key)
            except Exception:  # noqa: BLE001 - listeners must not kill serving
                pass

    # -- contract ------------------------------------------------------------
    @abstractmethod
    def save(
        self,
        key: str,
        cache: Any,
        length: int,
        *,
        prefill_s: float,
        prefix: PrefixKey | None = None,
    ) -> bool:
        """Persist one snapshot; False when the budget (or fabric) rejects it."""

    @abstractmethod
    def load(self, key: str) -> LoadedSnapshot | None:
        """Restore ``key``, or None when it is gone/unreachable (a discovered
        authoritative absence also fires the evict listeners)."""

    @abstractmethod
    def presence_many(self, keys: Iterable[str]) -> dict[str, bool | None]:
        """Batched presence (one round trip on remote fabrics); authoritative
        absences of locally-known snapshots fire the evict listeners."""

    @abstractmethod
    def drop(self, key: str) -> None:
        """Explicitly evict ``key`` (no-op when unknown)."""

    @abstractmethod
    def record(self, key: str) -> ArtifactRecord | None:
        """Bookkeeping record for ``key`` (None when unknown here)."""

    @property
    @abstractmethod
    def n_snapshots(self) -> int: ...

    @property
    @abstractmethod
    def n_evictions(self) -> int: ...

    @abstractmethod
    def snapshot_bytes(self) -> int: ...

    def contains(self, key: str) -> bool:
        """Presence of one key; unreachable counts as False (a redundant
        prefill is safe, a skipped one is not) — the single-flight
        ``stored_fn`` probe uses this."""
        return bool(self.presence_many([key]).get(key))

    def close(self) -> None:  # pragma: no cover - default teardown is empty
        pass


class MemorySnapshotStore(SnapshotStore):
    """The legacy engine-private tier, extracted verbatim behind the seam:
    host-RAM snapshot dict + gain-loss budget.  ``load`` hands back the same
    host arrays it stored (no codec round trip — this tier trades
    shareability for zero serialization cost)."""

    def __init__(
        self,
        capacity_bytes: int | None = None,
        eviction: str = "gain_loss",
        *,
        registry: MetricsRegistry | None = None,
        load_bps: float = 4e9,
    ) -> None:
        super().__init__(registry)
        self._snaps: dict[str, tuple[Any, int]] = {}  # key -> (host cache, len)
        self._records: dict[str, ArtifactRecord] = {}
        self._evictor = EvictionManager(capacity_bytes, eviction)
        self._ctx = EvictionContext(load_bps=load_bps)
        self._lock = threading.Lock()

    def save(
        self,
        key: str,
        cache: Any,
        length: int,
        *,
        prefill_s: float,
        prefix: PrefixKey | None = None,
    ) -> bool:
        host, nbytes = _host_tree(cache)
        if not self._evictor.admits(nbytes):
            return False
        with self._lock:
            self._snaps[key] = (host, length)
            self._records[key] = ArtifactRecord(
                key, nbytes, nbytes, save_s=0.0, compute_s=prefill_s
            )
            victims = self._evictor.select_victims(
                self._records,
                sum(r.nbytes_disk for r in self._records.values()),
                ctx=self._ctx,
                incoming=key,
            )
            for victim in victims:
                self._snaps.pop(victim, None)
                self._records.pop(victim, None)
        for victim in victims:
            self._m_drops.labels(source="evict").inc()
            self._fire(victim)
        if key in victims:
            return False
        self._m_saves.inc()
        self._m_save_s.observe(0.0)
        return True

    def load(self, key: str) -> LoadedSnapshot | None:
        t0 = time.perf_counter()
        with self._lock:
            entry = self._snaps.get(key)
            rec = self._records.get(key)
            if entry is None:
                return None
            if rec is not None:
                rec.n_loads += 1
                rec.last_used_at = time.time()
        self._m_loads.inc()
        load_s = time.perf_counter() - t0
        self._m_load_s.observe(load_s)
        return LoadedSnapshot(
            cache=entry[0],
            length=entry[1],
            prefill_s=float(rec.compute_s or 0.0) if rec is not None else 0.0,
            load_s=load_s,
        )

    def presence_many(self, keys: Iterable[str]) -> dict[str, bool | None]:
        with self._lock:
            return {k: k in self._snaps for k in keys}

    def drop(self, key: str) -> None:
        with self._lock:
            known = self._snaps.pop(key, None) is not None
            self._records.pop(key, None)
        if known:
            self._m_drops.labels(source="drop").inc()
            self._fire(key)

    def record(self, key: str) -> ArtifactRecord | None:
        return self._records.get(key)

    @property
    def n_snapshots(self) -> int:
        return len(self._snaps)

    @property
    def n_evictions(self) -> int:
        return self._evictor.n_evictions

    def snapshot_bytes(self) -> int:
        with self._lock:
            return sum(r.nbytes_disk for r in self._records.values())


class FabricSnapshotStore(SnapshotStore):
    """KV snapshots as shared artifacts on a :class:`StorageBackend`.

    Parameters
    ----------
    backend: where bytes live — ``LocalFSBackend``, ``MemoryBackend``, or a
        remote/sharded backend (wrap in ``CachingBackend`` for a local hot
        tier; ``Client.serve_engine`` does).
    capacity_bytes: gain-loss budget *this store enforces* on the fabric; an
        eviction here deletes the artifact fleet-wide (same semantics as the
        workflow store's capacity over a shared backend).  ``None`` = no
        local enforcement.
    codec: per-leaf payload codec name from the codec registry (default
        ``"none"`` — the zero-copy raw path).
    catalog: snapshots publish ``CatalogRecord``s at the admission seam and
        discard them on any removal, so ``find``/``--dedup`` see serving
        artifacts exactly like workflow artifacts.
    ledger / tenant: optional ``TenantLedger`` billing — ``charge_stored``
        on admission, ``credit_evicted`` on every removal path.
    events_from: a backend with ``add_event_listener`` (RemoteBackend /
        ShardedBackend): fleet-wide eviction events prune local records so
        no engine keeps planning around a snapshot another process evicted.
    """

    def __init__(
        self,
        backend: StorageBackend,
        *,
        capacity_bytes: int | None = None,
        eviction: str = "gain_loss",
        codec: str | None = "none",
        registry: MetricsRegistry | None = None,
        catalog: Any = None,
        ledger: Any = None,
        tenant: str = "",
        events_from: Any = None,
        load_bps: float = 4e9,
    ) -> None:
        super().__init__(registry)
        self.backend = backend
        self.codec = codec
        self.catalog = catalog
        self.ledger = ledger
        self.tenant = tenant
        self._records: dict[str, ArtifactRecord] = {}
        self._prefixes: dict[str, PrefixKey] = {}  # for catalog re-publish
        self._evictor = EvictionManager(capacity_bytes, eviction)
        self._ctx = EvictionContext(load_bps=load_bps)
        self._lock = threading.Lock()
        if events_from is not None:
            events_from.add_event_listener(self._on_fabric_event)

    # -- removal funnel ------------------------------------------------------
    def _forget(self, key: str, source: str) -> None:
        """The one path out: record + catalog + ledger + listeners converge."""
        with self._lock:
            known = self._records.pop(key, None) is not None
            self._prefixes.pop(key, None)
        if not known:
            return
        if self.catalog is not None:
            self.catalog.discard(key)
        if self.ledger is not None:
            self.ledger.credit_evicted(key)
        self._m_drops.labels(source=source).inc()
        self._fire(key)

    def _on_fabric_event(self, event: str, key: str) -> None:
        if event == "evicted":
            self._forget(key, source="event")

    def _evict(self, key: str) -> None:
        try:
            self.backend.delete(key)
        except BackendUnavailable:
            # can't reach the fabric: keep the record — the artifact still
            # exists, and pretending otherwise would leak the ledger bytes
            return
        invalidate = getattr(self.backend, "invalidate", None)
        if callable(invalidate):
            invalidate(key)
        self._forget(key, source="evict")

    # -- contract ------------------------------------------------------------
    def save(
        self,
        key: str,
        cache: Any,
        length: int,
        *,
        prefill_s: float,
        prefix: PrefixKey | None = None,
    ) -> bool:
        host, nbytes = _host_tree(cache)
        if not self._evictor.admits(nbytes):
            return False
        t0 = time.perf_counter()
        try:
            info = save_kv(
                self.backend,
                key,
                host,
                length,
                codec=self.codec,
                prefill_s=prefill_s,
            )
        except BackendUnavailable:
            return False
        save_s = time.perf_counter() - t0
        rec = ArtifactRecord(
            key, info.nbytes_raw, info.nbytes_disk, save_s=save_s, compute_s=prefill_s
        )
        with self._lock:
            self._records[key] = rec
            if prefix is not None:
                self._prefixes[key] = prefix
            total = sum(r.nbytes_disk for r in self._records.values())
            victims = self._evictor.select_victims(
                self._records, total, ctx=self._ctx, incoming=key
            )
        if self.catalog is not None and prefix is not None:
            self.catalog.publish(prefix, key, rec)
        if self.ledger is not None:
            self.ledger.charge_stored(self.tenant, key, info.nbytes_disk)
        for victim in victims:
            self._evict(victim)
        if key in victims:
            return False
        self._m_saves.inc()
        self._m_save_s.observe(save_s)
        return True

    def load(self, key: str) -> LoadedSnapshot | None:
        t0 = time.perf_counter()
        try:
            tree, length, info = load_kv(self.backend, key)
        except (KeyError, FileNotFoundError):
            # authoritative absence: evicted elsewhere before the event (or
            # any event at all) reached us — prune so nothing phantom-plans
            self._forget(key, source="phantom")
            return None
        except BackendUnavailable:
            return None  # unreachable is not absent: keep records intact
        load_s = time.perf_counter() - t0
        now = time.time()
        with self._lock:
            rec = self._records.get(key)
            if rec is None:
                # cross-process adoption: another engine stored it; the
                # manifest carries the measured prefill cost so gain-loss
                # prices it here exactly as it did there
                rec = ArtifactRecord(
                    key,
                    info.nbytes_raw,
                    info.nbytes_disk,
                    save_s=0.0,
                    compute_s=info.prefill_s,
                    created_at=info.created_at or now,
                )
                self._records[key] = rec
            rec.n_loads += 1
            rec.last_used_at = now
            rec.load_s = load_s
        if self.catalog is not None:
            self.catalog.touch(key, rec)
        self._m_loads.inc()
        self._m_load_s.observe(load_s)
        return LoadedSnapshot(
            cache=tree,
            length=length,
            prefill_s=float(info.prefill_s or rec.compute_s or 0.0),
            load_s=load_s,
        )

    def presence_many(self, keys: Iterable[str]) -> dict[str, bool | None]:
        keys = list(keys)
        try:
            result = self.backend.exists_many(keys)
        except BackendUnavailable:
            return {k: None for k in keys}
        for k, present in result.items():
            if present is False:
                self._forget(k, source="phantom")
        return result

    def drop(self, key: str) -> None:
        self._evict(key)

    def record(self, key: str) -> ArtifactRecord | None:
        return self._records.get(key)

    @property
    def n_snapshots(self) -> int:
        return len(self._records)

    @property
    def n_evictions(self) -> int:
        return self._evictor.n_evictions

    def snapshot_bytes(self) -> int:
        with self._lock:
            return sum(r.nbytes_disk for r in self._records.values())
