"""Synthetic batch generators matching input_specs for every family/cell."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..configs.base import (
    Config,
    GNNConfig,
    LMConfig,
    RecsysConfig,
    ShapeCell,
    input_specs,
)


def make_batch(cfg: Config, cell: ShapeCell, seed: int = 0) -> dict:
    """Materialize a concrete batch with the exact spec shapes/dtypes."""
    rng = np.random.default_rng(seed)
    specs = input_specs(cfg, cell)
    out = {}
    for name, spec in specs.items():
        out[name] = _fill(rng, name, spec, cfg, cell)
    return out


def _fill(rng, name, spec, cfg, cell):
    if isinstance(spec, dict):  # nested (decode cache)
        return {k: _fill(rng, k, v, cfg, cell) for k, v in spec.items()}
    shape, dtype = spec.shape, spec.dtype
    if name in ("tokens", "targets"):
        return jnp.asarray(rng.integers(0, cfg.vocab, size=shape), jnp.int32)
    if name == "cache_len":
        return jnp.full(shape, cell.params["seq_len"] // 2, jnp.int32)
    if name == "edge_index":
        n = cell.params.get("n_nodes", 16)
        return jnp.asarray(rng.integers(0, n, size=shape), jnp.int32)
    if name == "labels":
        if np.issubdtype(dtype, np.integer):
            n_cls = getattr(cfg, "n_classes", 2)
            return jnp.asarray(rng.integers(0, n_cls, size=shape), jnp.int32)
        return jnp.asarray(rng.integers(0, 2, size=shape).astype(np.float32))
    if name == "train_mask":
        return jnp.asarray(rng.random(shape) < 0.5)
    if name in ("sparse_ids",):
        vocabs = np.asarray(cfg.vocab_sizes, np.int64)
        ids = rng.integers(0, vocabs[None, :], size=shape)
        return jnp.asarray(ids, jnp.int32)
    if name in ("hist_ids", "target_id", "pos_ids", "neg_ids", "candidate_ids", "seed_ids"):
        hi = getattr(cfg, "item_vocab", 0) or cell.params.get("n_nodes", 1000)
        return jnp.asarray(rng.integers(0, hi, size=shape), jnp.int32)
    if np.issubdtype(np.dtype(dtype), np.floating) or str(dtype) == "bfloat16":
        return jnp.asarray(rng.normal(size=shape) * 0.1).astype(dtype)
    return jnp.zeros(shape, dtype)
