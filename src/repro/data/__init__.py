from .synthetic import make_batch
from .graph import NeighborSampler, random_graph

__all__ = ["make_batch", "NeighborSampler", "random_graph"]
