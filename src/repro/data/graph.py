"""Graph substrate: CSR adjacency + real fanout neighbor sampling.

``minibatch_lg`` (Reddit-scale: 233k nodes / 115M edges, fanout 15-10)
requires an actual neighbor sampler, not a stub: ``NeighborSampler`` builds a
CSR index once and draws per-seed fixed-fanout samples (with replacement for
high-degree nodes, padded with self-loops for low-degree nodes) producing the
static-shape padded subgraph the jitted train step consumes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def random_graph(
    n_nodes: int, n_edges: int, seed: int = 0, power_law: bool = True
) -> np.ndarray:
    """Edge index [2, E] with a skewed (power-law-ish) degree distribution."""
    rng = np.random.default_rng(seed)
    if power_law:
        w = rng.pareto(1.5, size=n_nodes) + 1.0
        p = w / w.sum()
        src = rng.choice(n_nodes, size=n_edges, p=p)
        dst = rng.choice(n_nodes, size=n_edges, p=p)
    else:
        src = rng.integers(0, n_nodes, size=n_edges)
        dst = rng.integers(0, n_nodes, size=n_edges)
    return np.stack([src, dst]).astype(np.int32)


@dataclass
class CSRGraph:
    indptr: np.ndarray  # [N+1]
    indices: np.ndarray  # [E] neighbour ids
    n_nodes: int

    @classmethod
    def from_edge_index(cls, edge_index: np.ndarray, n_nodes: int) -> "CSRGraph":
        src, dst = edge_index
        order = np.argsort(dst, kind="stable")
        src_sorted = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(counts, out=indptr[1:])
        return cls(indptr=indptr, indices=src_sorted.astype(np.int32), n_nodes=n_nodes)

    def degree(self, nodes: np.ndarray) -> np.ndarray:
        return (self.indptr[nodes + 1] - self.indptr[nodes]).astype(np.int64)


class NeighborSampler:
    """GraphSAGE-style fixed-fanout sampler producing padded subgraphs."""

    def __init__(self, graph: CSRGraph, fanouts: tuple[int, ...], seed: int = 0):
        self.g = graph
        self.fanouts = fanouts
        self.rng = np.random.default_rng(seed)

    def _sample_neighbors(self, nodes: np.ndarray, fanout: int) -> np.ndarray:
        """[B] -> [B, fanout] sampled neighbour ids (self-loop padded)."""
        deg = self.g.degree(nodes)
        # random offsets into each node's neighbour list
        offs = (self.rng.random((nodes.shape[0], fanout)) * np.maximum(deg, 1)[:, None]).astype(
            np.int64
        )
        idx = self.g.indptr[nodes][:, None] + offs
        nbrs = self.g.indices[np.minimum(idx, self.g.indices.shape[0] - 1)]
        # isolated nodes: self-loops
        nbrs = np.where(deg[:, None] > 0, nbrs, nodes[:, None])
        return nbrs.astype(np.int32)

    def sample(self, seeds: np.ndarray) -> dict[str, np.ndarray]:
        """Returns a padded 2-hop block: local node list + local edge index.

        Layout: [seeds | hop1 | hop2]; edges point child -> parent (message
        flow toward the seeds).  Static shapes: B*(1+f1+f1*f2) nodes,
        B*(f1+f1*f2) edges.
        """
        assert len(self.fanouts) == 2, "configured for 2-hop (fanout 15-10)"
        f1, f2 = self.fanouts
        B = seeds.shape[0]
        hop1 = self._sample_neighbors(seeds, f1)  # [B, f1]
        hop2 = self._sample_neighbors(hop1.reshape(-1), f2)  # [B*f1, f2]

        nodes = np.concatenate([seeds, hop1.reshape(-1), hop2.reshape(-1)])
        n1_off = B
        n2_off = B + B * f1
        # hop1 -> seeds
        src1 = n1_off + np.arange(B * f1)
        dst1 = np.repeat(np.arange(B), f1)
        # hop2 -> hop1
        src2 = n2_off + np.arange(B * f1 * f2)
        dst2 = n1_off + np.repeat(np.arange(B * f1), f2)
        edge_index = np.stack(
            [np.concatenate([src1, src2]), np.concatenate([dst1, dst2])]
        ).astype(np.int32)
        return {"nodes": nodes.astype(np.int32), "edge_index": edge_index}
