"""LM data-preparation pipeline as SWfMS modules (RISP-cacheable stages).

The thesis' technique applies to *data* workflows first and foremost: the
tokenize -> pack -> split stages below register with the WorkflowExecutor, so
repeated training runs over the same corpus reuse the packed token shards
instead of re-preprocessing (DESIGN §4 table, LM row).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..core import ModuleSpec, WorkflowExecutor


def byte_tokenize(text_blob: jnp.ndarray, vocab: int = 32000) -> jnp.ndarray:
    """Toy byte-pair-ish tokenizer: fold bytes into the model vocab."""
    b = jnp.asarray(text_blob, jnp.uint32)
    pairs = b[: (b.shape[0] // 2) * 2].reshape(-1, 2)
    ids = (pairs[:, 0] * 311 + pairs[:, 1] * 7) % vocab
    return ids.astype(jnp.int32)


def pack_sequences(ids: jnp.ndarray, seq_len: int = 128) -> jnp.ndarray:
    """Pack the token stream into [n, seq_len+1] rows (input+target)."""
    n = ids.shape[0] // (seq_len + 1)
    return ids[: n * (seq_len + 1)].reshape(n, seq_len + 1)


def train_split(packed: jnp.ndarray, holdout: int = 8) -> dict:
    return {"train": packed[:-holdout], "eval": packed[-holdout:]}


def register_data_modules(ex: WorkflowExecutor, vocab: int = 32000) -> None:
    ex.register(
        ModuleSpec(
            "tokenize",
            lambda blob, vocab=vocab: byte_tokenize(blob, vocab),
            {"vocab": vocab},
        )
    )
    ex.register(
        ModuleSpec(
            "pack", lambda ids, seq_len=128: pack_sequences(ids, seq_len),
            {"seq_len": 128},
        )
    )
    ex.register(
        ModuleSpec(
            "split", lambda p, holdout=8: train_split(p, holdout), {"holdout": 8}
        )
    )


def make_corpus_blob(n_bytes: int = 1 << 20, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 256, size=n_bytes, dtype=np.uint32))
