"""One front door for the whole system.

Before ``repro.api``, running a workflow meant choosing between three
disjoint entry points — ``WorkflowExecutor`` (sequential), ``WorkflowService``
(concurrent DAGs), ``ServeEngine`` (serving) — each with its own module
bookkeeping.  :class:`Client` wires store + policy + eviction + cost model +
registry + both execution engines in one constructor, and accepts the same
declarative :class:`~repro.api.spec.WorkflowSpec` everywhere.  Because both
engines share one :class:`~repro.core.registry.ModuleRegistry` and one
``StoragePolicy``, a prefix stored by a sequential ``run`` is reused by a
concurrent ``submit`` of an equivalent spec (and vice versa) — the store
keys are identical by construction.

``recommend`` exposes the thesis' Ch. 4 recommendation pipeline over the
same mined history: feed it a partial spec while composing and it returns
ranked reusable-prefix and next-module suggestions.
"""
from __future__ import annotations

import tempfile
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Iterable, Mapping, Sequence

from ..core.backends import BackendUnavailable
from ..core.cost import CostModel
from ..core.executor import RunResult, WorkflowExecutor
from ..core.provenance import ProvenanceLog
from ..core.registry import ModuleRegistry
from ..core.risp import StoragePolicy, make_policy
from ..core.store import IntermediateStore
from ..core.workflow import ModuleRef, ModuleSpec, Workflow
from ..obs import tracing as _tracing
from ..obs.metrics import MetricsRegistry, merge_docs
from ..sched.dag import DagWorkflow
from ..sched.dispatch import NodeDispatcher
from ..sched.scheduler import DagRunResult
from ..catalog import Catalog, CatalogRecord, rank_key
from ..sched.service import WorkflowService
from ..sched.singleflight import SingleFlight
from ..sched.stats import AggregateStats
from .recommend import RecommendReport, Recommender
from .spec import WorkflowSpec, check_namespace, namespaced_dataset


class Client:
    """Unified facade over the sequential executor and the DAG scheduler.

    Parameters
    ----------
    root: directory for the default ``IntermediateStore`` (a temp dir when
        neither ``root`` nor ``store`` nor ``store_url`` is given — handy
        for demos/tests).
    store_url: ``tcp://host:port`` of a ``repro.net`` store server.  The
        client then mounts the *shared* artifact pool through a read-through
        ``CachingBackend`` over a ``RemoteBackend``, subscribes to the
        server's eviction-event stream (keeping ``policy.stored`` and the
        cache consistent with fleet-wide evictions), and upgrades the
        scheduler's single-flight to the server's lease table so N client
        processes compute an uncomputed prefix exactly once.  A
        comma-separated list (``"h:7077,h:7078,h:7079"``) mounts the pool in
        **cluster mode** instead: a ``ShardedBackend`` routes every key over
        a consistent-hash ring of the listed servers with ``replication``
        copies, failover reads, read-repair, and ring-aware lease election
        (see ``docs/remote.md``, "Cluster mode").  Mutually exclusive with
        ``root``/``store``.
    replication: replica count per artifact in cluster mode (default 2,
        clamped to the shard count) — ``R>=2`` survives a shard death
        mid-run with no artifact loss.  Only valid with a multi-endpoint
        ``store_url``.
    store: pre-built store; mutually exclusive with ``root``/``capacity_bytes``
        /``eviction``/``codec``.
    policy: a ``StoragePolicy`` instance or a policy name
        (``"PT"``/``"TSAR"``/``"TSPAR"``/``"TSFR"``); names are instantiated
        with ``with_state``.
    registry: shared ``ModuleRegistry`` (or a plain dict, adopted by
        reference).  Pass the same registry to several clients/engines to
        share one module universe.
    max_workers: DAG scheduler worker-pool size.
    admission: ``"always"`` or the Eq. 4.9 cost gate ``"t1_gt_t2"``.
    cache_bytes: local read-through cache budget (``store_url`` mode only).
    dispatcher: optional ``repro.sched.ProcessPoolDispatcher`` — module
        computes escape onto worker processes (the caller owns its
        lifecycle).
    namespace: default artifact namespace.  Specs that don't carry their own
        namespace are rebound to this one before resolving ``PrefixKey``s, so
        everything this client stores lives under
        ``<namespace>/<dataset_id>`` — the isolation unit the gateway maps
        tenants onto.  Empty (the default) keeps the legacy un-namespaced
        keys.
    max_pending: bound on scheduler submissions in flight (queued + running);
        when saturated, ``submit`` raises
        :class:`~repro.sched.service.AdmissionRejected` instead of queueing
        unboundedly.  ``None`` (default) keeps the unbounded legacy behavior.
    """

    def __init__(
        self,
        root: str | None = None,
        *,
        store: IntermediateStore | None = None,
        store_url: str | None = None,
        policy: StoragePolicy | str = "PT",
        with_state: bool = True,
        registry: ModuleRegistry | Mapping[str, ModuleSpec] | None = None,
        admission: str = "always",
        capacity_bytes: int | None = None,
        eviction: str | None = None,
        codec: str | None = None,
        max_workers: int = 4,
        max_concurrent_runs: int = 32,
        provenance: ProvenanceLog | None = None,
        cache_bytes: int = 64 * 1024 * 1024,
        client_id: str | None = None,
        replication: int | None = None,
        dispatcher: "NodeDispatcher | None" = None,
        namespace: str = "",
        max_pending: int | None = None,
    ) -> None:
        self.namespace = check_namespace(namespace)
        self._remote: "RemoteBackend | ShardedBackend | None" = None
        singleflight: "SingleFlight | None" = None
        # one metrics registry for every layer this client wires together
        # (store, cache, shards, single-flight, service) — a pre-built store
        # brings its own, which we adopt so all series still co-reside
        metrics = store.metrics if store is not None else MetricsRegistry()
        self.metrics = metrics
        if store_url is None and replication is not None:
            raise ValueError("replication only applies to a store_url cluster mount")
        if store_url is not None:
            if store is not None or root is not None:
                raise ValueError(
                    "store_url mounts a remote pool; don't also pass store/root"
                )
            # local import: repro.api stays importable without repro.net only
            # in spirit — net has no extra deps, but the seam keeps layering
            # one-directional (api -> net, never net -> api)
            from ..net import (
                CachingBackend,
                DistributedSingleFlight,
                RemoteBackend,
                ShardedBackend,
            )

            if "," in store_url:
                self._remote = ShardedBackend(
                    store_url,
                    replication=replication if replication is not None else 2,
                    client_id=client_id,
                    registry=metrics,
                )
            else:
                if replication is not None:
                    raise ValueError(
                        "replication is a cluster-mode option; it needs a "
                        "multi-endpoint store_url (\"h:p1,h:p2,…\")"
                    )
                self._remote = RemoteBackend(
                    store_url, client_id=client_id, registry=metrics
                )
            cache = CachingBackend(
                self._remote, capacity_bytes=cache_bytes, registry=metrics
            )
            store = IntermediateStore(
                backend=cache,
                capacity_bytes=capacity_bytes,
                eviction=eviction if eviction is not None else "gain_loss",
                codec=codec,
                registry=metrics,
            )
            # fleet-wide evictions: purge the cache first, then drop local
            # records + policy bookkeeping via the store's listeners
            def _on_event(event: str, key: str, _cache=cache, _store=store) -> None:
                if event == "evicted":
                    _cache.invalidate(key)
                    _store.on_external_evict(key)

            self._remote.add_event_listener(_on_event)
            singleflight = DistributedSingleFlight(
                self._remote, stored_fn=store.has, registry=metrics
            )
        elif store is None:
            if root is None:
                root = tempfile.mkdtemp(prefix="repro-store-")
            store = IntermediateStore(
                root,
                capacity_bytes=capacity_bytes,
                eviction=eviction if eviction is not None else "gain_loss",
                codec=codec,
                registry=metrics,
            )
        elif any(v is not None for v in (root, capacity_bytes, eviction, codec)):
            raise ValueError(
                "a pre-built store already fixes root/capacity_bytes/eviction/"
                "codec; pass either the store or those options, not both"
            )
        if isinstance(policy, str):
            policy = make_policy(policy, with_state=with_state)
        self.store = store
        self.policy = policy
        self.registry = (
            registry
            if isinstance(registry, ModuleRegistry)
            else ModuleRegistry(registry)
        )
        cost_model = CostModel(store=store)
        # provenance catalog: local query index mirrored to the remote pool
        # (server- or cluster-side) when one is mounted, so the index
        # survives client churn.  Mirrors through the RAW remote backend, not
        # the read-through cache wrapper — catalog ops are not blob ops.
        self.catalog = Catalog(
            self._remote if self._remote is not None else store.backend
        )
        self.executor = WorkflowExecutor(
            store=store,
            policy=policy,
            registry=self.registry,
            admission=admission,
            provenance=provenance,
            cost_model=cost_model,
            catalog=self.catalog,
        )
        self.service = WorkflowService(
            store=store,
            policy=policy,
            registry=self.registry,
            max_workers=max_workers,
            admission=admission,
            provenance=provenance,
            cost_model=cost_model,
            max_concurrent_runs=max_concurrent_runs,
            singleflight=singleflight,
            dispatcher=dispatcher,
            max_pending=max_pending,
            catalog=self.catalog,
        )
        self.recommender = Recommender(policy, store, catalog=self.catalog)
        # client-level aggregate stats spanning BOTH engines (the service's
        # own tally covers only submit()-path runs)
        self._lock = threading.Lock()
        self._agg = AggregateStats()
        self._t_first: float | None = None
        self._t_last = 0.0
        self._closed = False

    def _bind_namespace(self, spec: WorkflowSpec) -> WorkflowSpec:
        """Apply the client's default namespace to specs that carry none
        (a spec's own namespace always wins)."""
        if self.namespace and not spec.namespace:
            return spec.with_namespace(self.namespace)
        return spec

    # -- registration ----------------------------------------------------------
    def module(
        self,
        module_id: str | None = None,
        *,
        cost_hint: float | None = None,
        **default_params: Any,
    ) -> Callable[[Callable[..., Any]], Callable[..., Any]]:
        """``@client.module("normalize")`` decorator (see
        :meth:`ModuleRegistry.module`)."""
        return self.registry.module(
            module_id, cost_hint=cost_hint, **default_params
        )

    def register(self, spec: ModuleSpec) -> None:
        self.registry.register(spec)

    def register_fn(self, module_id: str, fn, **default_params) -> None:
        self.registry.register_fn(module_id, fn, **default_params)

    # -- spec construction ------------------------------------------------------
    def spec(self, dataset_id: str, workflow_id: str = "") -> WorkflowSpec:
        """An empty :class:`WorkflowSpec` builder (validated against this
        client's registry at run time)."""
        return WorkflowSpec(dataset_id, workflow_id)

    # -- bookkeeping ------------------------------------------------------------
    def _mark_start(self) -> None:
        with self._lock:
            if self._t_first is None:
                self._t_first = time.perf_counter()

    def _record(self, result: RunResult | DagRunResult | None, failed: bool) -> None:
        with self._lock:
            self._t_last = time.perf_counter()
            if failed or result is None:
                self._agg.failures += 1
            else:
                self._agg.add_run(result)

    # -- execution ---------------------------------------------------------------
    def run(
        self,
        spec: WorkflowSpec | Workflow | DagWorkflow,
        data: Any,
    ) -> RunResult | DagRunResult:
        """Blocking run.  Linear specs (and ``Workflow``s) execute on the
        sequential executor; DAG-shaped specs go through the scheduler.
        Either way the artifacts land under the same ``PrefixKey``s."""
        self._mark_start()
        if isinstance(spec, WorkflowSpec):
            spec = self._bind_namespace(spec)
            if spec.is_linear:
                runnable: Workflow | DagWorkflow = spec.to_workflow(self.registry)
            else:
                runnable = spec.to_dag(self.registry)
        else:
            runnable = spec
        try:
            if isinstance(runnable, Workflow):
                result: RunResult | DagRunResult = self.executor.run_workflow(
                    runnable, data
                )
            else:
                result = self.service.scheduler.run(runnable, data)
        except Exception:
            self._record(None, failed=True)
            raise
        self._record(result, failed=False)
        return result

    def submit(
        self,
        spec: WorkflowSpec | Workflow | DagWorkflow,
        data: Any,
        on_state: Callable[[str], None] | None = None,
        trace: "_tracing.TraceContext | None" = None,
    ) -> "Future[DagRunResult]":
        """Non-blocking submission onto the shared scheduler (chains run as
        chain DAGs).  Returns the run's future.  ``on_state`` (if given) is
        forwarded to :meth:`WorkflowService.submit` — it fires with
        ``"started"`` when a coordinator picks the run up and
        ``"finished"``/``"failed"`` when it completes.  ``trace`` parents the
        run's span under an inbound trace context (e.g. a gateway request);
        without it the service mints a fresh trace when tracing is enabled.
        The returned future carries the run's ``trace_id`` attribute."""
        self._mark_start()
        if isinstance(spec, WorkflowSpec):
            dag = self._bind_namespace(spec).to_dag(self.registry)
        elif isinstance(spec, Workflow):
            dag = DagWorkflow.from_workflow(spec, registry=self.registry)
        else:
            dag = spec
        fut = self.service.submit(dag, data, on_state=on_state, trace=trace)

        def _done(f: "Future[DagRunResult]") -> None:
            try:
                self._record(f.result(), failed=False)
            except Exception:  # noqa: BLE001 - delivered via the future
                self._record(None, failed=True)

        fut.add_done_callback(_done)
        return fut

    def run_steps(
        self,
        dataset_id: str,
        data: Any,
        steps: Sequence[str | tuple[str, Mapping[str, Any] | None]],
        workflow_id: str = "",
    ) -> RunResult | DagRunResult:
        """Linear-pipeline shorthand."""
        return self.run(WorkflowSpec.from_steps(dataset_id, steps, workflow_id), data)

    # -- history / recommendation ------------------------------------------------
    def observe(self, wf: WorkflowSpec | Workflow) -> None:
        """Feed one workflow into the mined history *without executing it* —
        the thesis' replay protocol (Ch. 4.5.1), used to warm the
        recommendation surface from an existing corpus.

        The policy's miner and replay counters advance exactly as if the
        workflow had run, but store admissions it claims are pruned again
        when no artifact exists: replayed history must not make real runs
        believe (and skip storing) artifacts that were never persisted.
        """
        if isinstance(wf, WorkflowSpec):
            rec = self.policy.step_paths(
                self._bind_namespace(wf).to_dag(self.registry, strict=False).paths()
            )
        else:
            rec = self.policy.step(wf)
        for prefix in rec.store:
            key = prefix.key(self.policy.with_state)
            if self.store.has_state(key) == "absent":
                # GIL-atomic pop without the policy lock (same pattern as the
                # store's evict listeners; see the documented lock order).
                # Authoritative absence only: unreachable shards are not
                # evidence the replayed artifact never existed.
                self.policy.stored.pop(key, None)

    def replay(self, corpus: Iterable[WorkflowSpec | Workflow]) -> int:
        """Observe a whole corpus; returns the number of workflows replayed."""
        n = 0
        for wf in corpus:
            self.observe(wf)
            n += 1
        return n

    def recommend(
        self,
        partial: WorkflowSpec | Workflow | str,
        modules: Sequence[ModuleRef] = (),
        top_k: int = 5,
    ) -> RecommendReport:
        """Ranked suggestions while composing a workflow.

        ``partial`` is a linear (possibly empty) :class:`WorkflowSpec`, a
        :class:`Workflow`, or a bare dataset id (then ``modules`` supplies
        the chain built so far).  Returns reusable-prefix suggestions
        (deepest skip points, flagged when the artifact is live) and
        next-module suggestions mined from the observed corpus.
        """
        if isinstance(partial, str):
            # bare dataset ids are composed with the client's default
            # namespace (pass an already-composed id to escape)
            dataset_id = namespaced_dataset(self.namespace, partial)
            chain = tuple(modules)
        elif isinstance(partial, Workflow):
            dataset_id, chain = partial.dataset_id, partial.modules
        else:
            partial = self._bind_namespace(partial)
            dataset_id = partial.effective_dataset_id
            if len(partial) == 0:
                chain = ()
            else:
                chain = partial.to_workflow(self.registry, strict=False).modules
        return self.recommender.recommend(dataset_id, chain, top_k=top_k)

    def find(
        self,
        module: str | None = None,
        params: Mapping[str, Any] | None = None,
        dataset: str | None = None,
        namespace: str | None = None,
        *,
        any_position: bool = False,
        limit: int = 20,
        verify: bool = True,
    ) -> list[CatalogRecord]:
        """Query the provenance catalog: which stored artifacts were produced
        by ``module`` with these (decoded) ``params``, for this ``dataset``,
        in this ``namespace``?

        Matching is against the *terminal* module of each artifact's chain
        unless ``any_position=True``.  ``namespace=None`` scopes to this
        client's bound namespace (or any, when the client is un-namespaced);
        pass ``"*"`` to search across namespaces explicitly, or ``""`` for
        the un-namespaced pool only.  Results merge the local index with the
        remote pool's (server/cluster) index when one is mounted, ranked by
        reuse count, then chain depth, then recency.

        With ``verify=True`` (default) every candidate is checked against
        the store in one batched presence probe; only artifacts readable
        *right now* survive — the zero-phantom guarantee: ``find`` never
        reports an evicted artifact.  Authoritative absences additionally
        prune the catalog; candidates whose every replica is unreachable are
        dropped from the answer but kept indexed (the artifact may well
        exist; only its shards are down).
        """
        if namespace is None:
            ns = self.namespace if self.namespace else "*"
        else:
            ns = namespace
        hits = self.catalog.find(
            module=module,
            params=dict(params) if params else None,
            dataset=dataset,
            namespace=None if ns == "*" else ns,
            any_position=any_position,
            limit=limit,
        )
        if not verify or not hits:
            return hits
        presence = self.store.has_state_many([r.key for r in hits])
        kept = self.catalog.verify_present(hits, presence)
        # fold in the local store's live stats (loads observed by THIS
        # process since the record was published) so ranking reflects the
        # freshest counters we can see
        merged: list[CatalogRecord] = []
        for rec in kept:
            art = self.store.records.get(rec.key)
            if art is not None and (
                art.n_loads > rec.n_loads or art.last_used_at > rec.last_used_at
            ):
                rec = CatalogRecord(
                    key=rec.key,
                    namespace=rec.namespace,
                    dataset=rec.dataset,
                    modules=rec.modules,
                    states=rec.states,
                    nbytes=rec.nbytes,
                    compute_s=rec.compute_s,
                    created_at=rec.created_at,
                    last_used_at=max(rec.last_used_at, art.last_used_at),
                    n_loads=max(rec.n_loads, art.n_loads),
                )
            merged.append(rec)
        merged.sort(key=rank_key)
        return merged

    # -- serving -----------------------------------------------------------------
    def serve_engine(
        self,
        cfg: Any,
        params: Any,
        *,
        dataset_id: str = "prompts",
        policy: "StoragePolicy | None" = None,
        snapshot_budget_bytes: int | None = None,
        snapshot_codec: str | None = "none",
        ledger: Any = None,
        tenant: str | None = None,
        **engine_kw: Any,
    ) -> "Any":
        """Mount a :class:`~repro.serve.ServeEngine` on this client's fabric.

        The engine's KV-prefix snapshots become first-class artifacts on the
        client's backend (local store dir, remote pool, or shard cluster —
        read through the same hot cache workflow artifacts use), encoded by
        the deterministic KV codec and published to the provenance catalog.
        With a remote mount, prefill is a *coordinated compute*: the
        store-server lease table elects exactly one prefiller per shared
        prompt prefix fleet-wide (followers block, then load the leader's
        snapshot), and fleet eviction events keep every engine's
        ``policy.stored`` free of phantoms.

        ``dataset_id`` is composed with the client's namespace, so snapshot
        keys are tenant-scoped exactly like workflow artifacts.  ``ledger``
        (a :class:`~repro.sched.stats.TenantLedger`) bills stored snapshot
        bytes to ``tenant`` (default: the client's namespace) and is credited
        on every eviction path.  Remaining ``engine_kw`` (``max_len``,
        ``chunk``, ``greedy``, ...) pass through to ``ServeEngine``.
        """
        from ..core.risp import RISP
        from ..serve import FabricSnapshotStore, ServeEngine

        snapshots = FabricSnapshotStore(
            self.store.backend,
            capacity_bytes=snapshot_budget_bytes,
            codec=snapshot_codec,
            registry=self.metrics,
            catalog=self.catalog,
            ledger=ledger,
            tenant=tenant if tenant is not None else (self.namespace or ""),
            events_from=self._remote,
        )
        if self._remote is not None:
            from ..net import DistributedSingleFlight

            flight: SingleFlight = DistributedSingleFlight(
                self._remote, stored_fn=snapshots.contains, registry=self.metrics
            )
        else:
            # still coalesces concurrent identical prefixes in-process
            flight = SingleFlight(registry=self.metrics)
        return ServeEngine(
            cfg=cfg,
            params=params,
            policy=policy if policy is not None else RISP(),
            registry=self.registry,
            snapshots=snapshots,
            flight=flight,
            metrics=self.metrics,
            dataset_id=namespaced_dataset(self.namespace, dataset_id),
            **engine_kw,
        )

    # -- reporting / lifecycle -----------------------------------------------------
    def stats(self) -> AggregateStats:
        """Aggregate throughput/reuse across BOTH engines (sequential runs +
        scheduler submissions), in the same shape ``WorkflowService.stats``
        and ``ServeEngine.aggregate_stats`` report."""
        sf = self.service.scheduler.singleflight
        with self._lock:
            wall = (
                (self._t_last - self._t_first)
                if self._t_first is not None and self._t_last
                else 0.0
            )
            return self._agg.snapshot(wall, singleflight_waits=sf.waits)

    def metrics_doc(self) -> dict[str, Any]:
        """Fabric-wide metrics document: this process's registry (store,
        cache, shards-as-seen-from-here, scheduler, single-flight) merged
        with the server-side registries of every reachable store server when
        a remote pool is mounted.  Server series arrive stamped with a
        ``shard`` label so gauges from different processes never collapse
        into one meaningless sum.  Render with
        :func:`repro.obs.metrics.render_prometheus`."""
        docs: list[dict[str, Any]] = [self.metrics.to_doc()]
        extras: list[dict[str, str] | None] = [None]
        remote = self._remote
        if remote is not None:
            try:
                server_doc = remote.metrics_doc()
            except BackendUnavailable:
                server_doc = None
            if server_doc:
                docs.append(server_doc)
                # ShardedBackend stamps per-shard labels itself; a single
                # RemoteBackend's doc still needs its endpoint stamped here
                extras.append(
                    None
                    if hasattr(remote, "_shards")
                    else {"shard": f"{remote.host}:{remote.port}"}
                )
        return merge_docs(docs, extras)

    def drain(self, timeout: float | None = None) -> None:
        self.service.drain(timeout)

    def close(self) -> None:
        """Idempotent teardown: drain the service, flush the store, release
        any remote mount.  Safe to call repeatedly (and from ``__exit__``
        after an explicit close)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.service.close()
        self.store.flush()
        self.catalog.close()
        if self._remote is not None:
            self._remote.close()

    def __enter__(self) -> "Client":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
