"""``repro.api`` — the unified, declarative front door.

Three pillars (ISSUE 3 / thesis Ch. 4's recommendation technique made
public):

  * :class:`ModuleRegistry` — one module universe shared by every engine
    (sequential executor, DAG scheduler/service, serving engine), with a
    ``@registry.module(...)`` decorator, default params, and tool-state
    validation;
  * :class:`WorkflowSpec` — a declarative, JSON-round-trippable workflow
    document (chains and fan-in/fan-out DAGs, per-node tool states, Galaxy
    ``.ga`` import) whose resolved ``PrefixKey``s are identical across
    processes — the portable unit of workflow sharing;
  * :class:`Client` — store + policy + eviction + both engines in one
    constructor: ``run``/``submit``/``stats``/``recommend``/``replay``.

Quickstart::

    from repro.api import Client, WorkflowSpec

    client = Client("/tmp/artifacts", policy="PT", with_state=True)

    @client.module("normalize")
    def normalize(x): ...

    spec = WorkflowSpec.from_steps("sensor-A", ["normalize", ...])
    result = client.run(spec, data)
    print(client.recommend(spec).best_next)

Migration from the legacy front doors is documented in ``docs/api.md``;
``WorkflowExecutor`` and ``WorkflowService`` remain supported shims over the
same machinery.
"""
from ..core.registry import ModuleRegistry, ToolStateError, UnknownModuleError
from .client import Client
from .recommend import RecommendReport, Recommender, Suggestion
from .spec import NodeSpec, SpecError, WorkflowSpec

__all__ = [
    "Client",
    "ModuleRegistry",
    "NodeSpec",
    "RecommendReport",
    "Recommender",
    "SpecError",
    "Suggestion",
    "ToolStateError",
    "UnknownModuleError",
    "WorkflowSpec",
]
