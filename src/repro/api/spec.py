"""Declarative, JSON-round-trippable workflow documents.

A :class:`WorkflowSpec` is the portable description of a workflow — linear
chain or fan-in/fan-out DAG — that the ``repro.api.Client`` accepts on every
entry point.  It addresses the reusability blocker the Galaxy case study
(arXiv:2309.07291) identifies: workflows that exist only as in-memory object
graphs cannot be shared, versioned, or re-run elsewhere.  The design rules:

  * **Serializable** — ``to_json``/``from_json`` round-trip the document
    exactly, including per-node tool states (params go through the canonical
    invertible encoder from ``repro.core.workflow``, so tuples stay tuples
    and floats keep full precision).
  * **Store-key compatible** — resolving a spec against a
    :class:`~repro.core.registry.ModuleRegistry` yields the same
    ``PrefixKey`` identities in every process, so intermediate data stored
    by one process is reused by another that parsed the same document.
  * **Canonically digested** — :attr:`digest` hashes a normalized rendering
    (nodes sorted by id, presentational fields excluded) via ``_stable_hash``;
    serialization never changes it.

``from_galaxy`` imports Galaxy's native ``.ga`` workflow JSON (the corpus
format the source thesis mined).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Iterator, Mapping, Sequence

from ..core.registry import ModuleRegistry
from ..core.workflow import (
    ModuleRef,
    ToolState,
    Workflow,
    _stable_hash,
    decode_param,
)
from ..sched.dag import DagWorkflow, kahn_order

SCHEMA_KIND = "repro.workflow_spec"
SCHEMA_VERSION = 1

# characters a namespace may use; "/" is reserved as the namespace/dataset
# separator inside composed dataset ids, ":" only for the "tenant:<name>"
# convention the gateway uses
_NAMESPACE_OK = frozenset(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_.:-"
)


class SpecError(ValueError):
    """The workflow document is structurally invalid (cycle, duplicate node,
    unknown parent/module, empty graph)."""


def check_namespace(namespace: str) -> str:
    """Validate a namespace label (``""`` — the legacy un-namespaced world —
    is allowed and returned as-is)."""
    if namespace and not set(namespace) <= _NAMESPACE_OK:
        raise SpecError(
            f"invalid namespace {namespace!r}: allowed characters are "
            "letters, digits, '_', '.', ':', '-'"
        )
    return namespace


def namespaced_dataset(namespace: str, dataset_id: str) -> str:
    """The dataset identity every ``PrefixKey`` of a namespaced workflow is
    derived from: ``<namespace>/<dataset_id>`` (or plain ``dataset_id`` when
    un-namespaced).  Two tenants submitting the same document into the same
    namespace therefore share store keys — and into different namespaces,
    never do."""
    check_namespace(namespace)
    return f"{namespace}/{dataset_id}" if namespace else dataset_id


@dataclass(frozen=True)
class NodeSpec:
    """One module occurrence: id + module + tool-state params + parents.

    ``after`` order matters for fan-in nodes — the module function receives a
    tuple of parent values in this order.
    """

    node_id: str
    module_id: str
    params: ToolState = field(default_factory=ToolState)
    after: tuple[str, ...] = ()

    def config(self) -> dict[str, Any]:
        """The decoded parameter mapping (may be empty)."""
        return self.params.to_config()


def _as_state(params: Mapping[str, Any] | ToolState | None) -> ToolState:
    if isinstance(params, ToolState):
        # normalize: a ToolState carried over from a legacy (repr-encoded)
        # workflow re-canonicalizes here, so the document always serializes
        # canonical encodings and its digest survives JSON round trips
        if not params.params:
            return params
        return ToolState.from_config(params.to_config())
    return ToolState.from_config(params)


class WorkflowSpec:
    """Mutable builder + serializable document for one workflow.

    Build programmatically::

        spec = WorkflowSpec("survey2026", workflow_id="report")
        spec.add("norm", "normalize")
        spec.add("q10", "analyze", {"q": 10}, after="norm")
        spec.add("q90", "analyze", {"q": 90}, after="norm")
        spec.add("sum", "merge", after=("q10", "q90"))

    or declaratively: ``WorkflowSpec.from_json(text)``,
    ``WorkflowSpec.from_steps("ds", ["normalize", ("analyze", {"q": 10})])``,
    ``WorkflowSpec.from_galaxy(ga_doc)``.

    Unlike ``DagWorkflow.add``, ``add`` tolerates forward references to
    parents (documents may list nodes in any order); :meth:`validate` checks
    the full structure.
    """

    def __init__(
        self,
        dataset_id: str,
        workflow_id: str = "",
        nodes: Sequence[NodeSpec] = (),
        namespace: str = "",
    ) -> None:
        if not dataset_id:
            raise SpecError("a workflow spec needs a dataset_id")
        self.dataset_id = dataset_id
        self.workflow_id = workflow_id
        self.namespace = check_namespace(namespace)
        self._nodes: dict[str, NodeSpec] = {}
        for n in nodes:
            self._add_node(n)

    @property
    def effective_dataset_id(self) -> str:
        """Dataset identity after namespace composition — what every engine
        view (and therefore every ``PrefixKey``) is built from."""
        return namespaced_dataset(self.namespace, self.dataset_id)

    def with_namespace(self, namespace: str) -> "WorkflowSpec":
        """A copy of this spec rebound to ``namespace`` (nodes shared — they
        are immutable).  The gateway uses this to pin every submission to its
        tenant's private namespace or the opt-in shared one."""
        return WorkflowSpec(
            self.dataset_id, self.workflow_id, self.nodes, namespace=namespace
        )

    # -- construction --------------------------------------------------------
    def _add_node(self, node: NodeSpec) -> None:
        if node.node_id in self._nodes:
            raise SpecError(f"duplicate node id {node.node_id!r}")
        self._nodes[node.node_id] = node

    def add(
        self,
        node_id: str,
        module_id: str,
        params: Mapping[str, Any] | ToolState | None = None,
        after: str | Sequence[str] | None = None,
    ) -> str:
        if after is None:
            parents: tuple[str, ...] = ()
        elif isinstance(after, str):
            parents = (after,)
        else:
            parents = tuple(after)
        self._add_node(NodeSpec(node_id, module_id, _as_state(params), parents))
        return node_id

    def chain(
        self,
        steps: Sequence[str | tuple[str, Mapping[str, Any] | None]],
        after: str | None = None,
    ) -> str | None:
        """Append a linear chain of ``steps``; returns the last node id."""
        last = after
        for step in steps:
            mod, params = (step, None) if isinstance(step, str) else step
            nid = f"{mod}.{len(self._nodes)}"
            self.add(nid, mod, params, after=last)
            last = nid
        return last

    @classmethod
    def from_steps(
        cls,
        dataset_id: str,
        steps: Sequence[str | tuple[str, Mapping[str, Any] | None]],
        workflow_id: str = "",
    ) -> "WorkflowSpec":
        """Linear-pipeline shorthand (mirrors ``WorkflowExecutor.run`` steps)."""
        spec = cls(dataset_id, workflow_id)
        spec.chain(steps)
        return spec

    @classmethod
    def from_workflow(cls, wf: Workflow) -> "WorkflowSpec":
        """Lift an in-memory sequential :class:`Workflow` into a document."""
        spec = cls(wf.dataset_id, wf.workflow_id)
        last: str | None = None
        for i, ref in enumerate(wf.modules):
            nid = f"{ref.module_id}.{i}"
            spec.add(nid, ref.module_id, ref.state, after=last)
            last = nid
        return spec

    @classmethod
    def from_dag(cls, dag: DagWorkflow) -> "WorkflowSpec":
        """Lift an in-memory :class:`DagWorkflow` into a document."""
        spec = cls(dag.dataset_id, dag.workflow_id)
        for nid in dag.nodes:
            node = dag.node(nid)
            spec.add(nid, node.ref.module_id, node.ref.state, after=node.parents)
        return spec

    # -- structure -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._nodes)

    def __contains__(self, node_id: str) -> bool:
        return node_id in self._nodes

    def __iter__(self) -> Iterator[NodeSpec]:
        return iter(self._nodes.values())

    @property
    def nodes(self) -> tuple[NodeSpec, ...]:
        return tuple(self._nodes.values())

    def node(self, node_id: str) -> NodeSpec:
        return self._nodes[node_id]

    def roots(self) -> tuple[str, ...]:
        return tuple(n.node_id for n in self._nodes.values() if not n.after)

    def sinks(self) -> tuple[str, ...]:
        with_children = {p for n in self._nodes.values() for p in n.after}
        return tuple(nid for nid in self._nodes if nid not in with_children)

    def topo_order(self) -> tuple[str, ...]:
        """Deterministic topological order (Kahn; ties broken by declaration
        order).  Raises :class:`SpecError` on cycles or unknown parents."""
        for n in self._nodes.values():
            for p in n.after:
                if p not in self._nodes:
                    raise SpecError(
                        f"node {n.node_id!r}: unknown parent {p!r}"
                    )
        try:
            return kahn_order({nid: n.after for nid, n in self._nodes.items()})
        except ValueError as e:
            raise SpecError(str(e).replace("graph", "spec")) from None

    @property
    def is_linear(self) -> bool:
        """True when the spec is a single chain (one root, every node with at
        most one parent and one child) — the executor-compatible shape."""
        if not self._nodes:
            return False
        if len(self.roots()) != 1:
            return False
        child_count: dict[str, int] = {nid: 0 for nid in self._nodes}
        for n in self._nodes.values():
            if len(n.after) > 1:
                return False
            for p in n.after:
                child_count[p] += 1
        return all(c <= 1 for c in child_count.values())

    def validate(self, registry: ModuleRegistry | None = None) -> None:
        """Structural checks (non-empty, parents resolve, acyclic), plus —
        when a registry is given — unknown-module and tool-state validation."""
        if not self._nodes:
            raise SpecError("a workflow spec needs at least one node")
        self.topo_order()
        if registry is not None:
            for n in self._nodes.values():
                if n.module_id not in registry:
                    known = ", ".join(sorted(registry)[:8]) or "<none>"
                    raise SpecError(
                        f"node {n.node_id!r} references unknown module "
                        f"{n.module_id!r}; registered modules: {known}"
                    )
                registry.validate_state(n.module_id, n.config())

    # -- identity ------------------------------------------------------------
    def canonical(self) -> dict[str, Any]:
        """Normalized rendering for digesting: nodes sorted by id, parent
        *order* preserved (fan-in order is semantic), presentational fields
        (``workflow_id``, document key order) excluded.  The namespace is
        part of the identity when set (the same document in two namespaces
        names two disjoint artifact families); un-namespaced specs keep their
        pre-namespace digests."""
        doc: dict[str, Any] = {
            "version": SCHEMA_VERSION,
            "dataset_id": self.dataset_id,
            "nodes": [
                [n.node_id, n.module_id, list(map(list, n.params.params)), list(n.after)]
                for n in sorted(self._nodes.values(), key=lambda n: n.node_id)
            ],
        }
        if self.namespace:
            doc["namespace"] = self.namespace
        return doc

    @property
    def digest(self) -> str:
        """Canonical content digest, stable across processes and across
        serialize/deserialize round-trips (built on ``_stable_hash``)."""
        return _stable_hash(self.canonical())

    # -- engine views ---------------------------------------------------------
    def _resolve_ref(
        self, node: NodeSpec, registry: ModuleRegistry | None
    ) -> ModuleRef:
        # registry resolution merges registered defaults into the tool state,
        # matching what make_workflow/DagWorkflow.add produce — REQUIRED for
        # PrefixKey compatibility with runs built through the engines.  An
        # unregistered module resolves raw (lenient callers only; strict
        # validation has already rejected it otherwise), so known modules
        # still mine engine-identical keys.
        if registry is None or node.module_id not in registry:
            return ModuleRef(node.module_id, node.params)
        return registry[node.module_id].ref(node.config() or None)

    def to_workflow(
        self, registry: ModuleRegistry | None = None, *, strict: bool = True
    ) -> Workflow:
        """Sequential-engine view; requires :attr:`is_linear`.

        ``strict=False`` skips registry validation (structure is always
        checked) and resolves unregistered modules raw — for observe/
        recommend flows over historical corpora whose tools are not all
        registered locally."""
        self.validate(registry if strict else None)
        if not self.is_linear:
            raise SpecError(
                "spec is not a linear chain; use to_dag() / Client.submit()"
            )
        refs = tuple(
            self._resolve_ref(self._nodes[nid], registry)
            for nid in self.topo_order()
        )
        return Workflow(self.effective_dataset_id, refs, self.workflow_id)

    def to_dag(
        self, registry: ModuleRegistry | None = None, *, strict: bool = True
    ) -> DagWorkflow:
        """Scheduler view (works for chains and DAGs alike).  ``strict`` as
        in :meth:`to_workflow`."""
        self.validate(registry if strict else None)
        dag = DagWorkflow(self.effective_dataset_id, self.workflow_id, registry=None)
        for nid in self.topo_order():
            node = self._nodes[nid]
            dag.add(
                nid,
                self._resolve_ref(node, registry),
                after=node.after or None,
            )
        return dag

    def prefix_keys(
        self, registry: ModuleRegistry | None = None, with_state: bool = True
    ) -> list[str]:
        """Store keys of every linear-ancestry node — the intermediate-data
        identities a run of this spec can share with other processes."""
        dag = self.to_dag(registry)
        out = []
        for nid in dag.topo_order():
            prefix = dag.chain_prefix(nid)
            if prefix is not None:
                out.append(prefix.key(with_state))
        return out

    # -- serialization ---------------------------------------------------------
    def to_dict(self) -> dict[str, Any]:
        doc: dict[str, Any] = {
            "kind": SCHEMA_KIND,
            "version": SCHEMA_VERSION,
            "dataset_id": self.dataset_id,
            "workflow_id": self.workflow_id,
            "nodes": [
                {
                    "id": n.node_id,
                    "module": n.module_id,
                    # params are already canonically encoded strings — emit
                    # them verbatim so the document round-trips bit-exactly
                    "params": {k: v for k, v in n.params.params} or None,
                    "after": list(n.after),
                }
                for n in self._nodes.values()
            ],
        }
        if self.namespace:
            doc["namespace"] = self.namespace
        return doc

    @classmethod
    def from_dict(cls, doc: Mapping[str, Any]) -> "WorkflowSpec":
        kind = doc.get("kind", SCHEMA_KIND)
        if kind != SCHEMA_KIND:
            raise SpecError(f"not a workflow spec document (kind={kind!r})")
        version = int(doc.get("version", SCHEMA_VERSION))
        if version > SCHEMA_VERSION:
            raise SpecError(
                f"workflow spec version {version} is newer than supported "
                f"({SCHEMA_VERSION})"
            )
        if "dataset_id" not in doc:
            raise SpecError("workflow spec document missing 'dataset_id'")
        spec = cls(
            doc["dataset_id"],
            doc.get("workflow_id", ""),
            namespace=str(doc.get("namespace") or ""),
        )
        for nd in doc.get("nodes", ()):
            missing = [f for f in ("id", "module") if f not in nd]
            if missing:
                raise SpecError(f"workflow spec node missing field(s) {missing}")
            raw = nd.get("params") or {}
            # normalize to the canonical encoding so equal specs digest
            # equally however they were authored: string values are treated
            # as canonical/legacy *encodings* (to_json emits those; a literal
            # string is its JSON-quoted form, e.g. "\"fast\""), while plain
            # JSON values (numbers, bools, lists, objects) are taken as-is
            state = ToolState.from_config(
                {
                    str(k): decode_param(v) if isinstance(v, str) else v
                    for k, v in raw.items()
                }
            )
            spec._add_node(
                NodeSpec(
                    nd["id"],
                    nd["module"],
                    state,
                    tuple(nd.get("after") or ()),
                )
            )
        return spec

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "WorkflowSpec":
        try:
            doc = json.loads(text)
        except ValueError as e:
            raise SpecError(f"invalid workflow spec JSON: {e}") from e
        if not isinstance(doc, Mapping):
            raise SpecError("workflow spec JSON must be an object")
        return cls.from_dict(doc)

    # -- Galaxy import ---------------------------------------------------------
    @classmethod
    def from_galaxy(
        cls,
        doc: Mapping[str, Any] | str,
        dataset_id: str | None = None,
        simplify_tool_ids: bool = True,
    ) -> "WorkflowSpec":
        """Import a Galaxy ``.ga`` workflow document (the format the source
        thesis mined 508 of).

        ``data_input``/``data_collection_input`` steps become the workflow's
        input dataset (``dataset_id`` defaults to the first input's label,
        else the workflow name); each tool step becomes one node whose
        parents follow ``input_connections``.  ``tool_state`` params are
        kept, minus Galaxy's ``__``-prefixed internals; full toolshed ids
        are shortened to the tool's short name when ``simplify_tool_ids``.
        """
        if isinstance(doc, str):
            try:
                doc = json.loads(doc)
            except ValueError as e:
                raise SpecError(f"invalid Galaxy workflow JSON: {e}") from e
        steps = doc.get("steps")
        if not isinstance(steps, Mapping) or not steps:
            raise SpecError("Galaxy document has no steps")

        def _step_key(item: tuple[str, Any]) -> int:
            try:
                return int(item[1].get("id", item[0]))
            except (TypeError, ValueError):
                return 0

        ordered = [s for _, s in sorted(steps.items(), key=_step_key)]
        input_types = ("data_input", "data_collection_input", "parameter_input")
        inputs = {
            str(s.get("id")): s
            for s in ordered
            if s.get("type") in input_types or s.get("tool_id") in (None, "")
        }
        if dataset_id is None:
            for s in inputs.values():
                label = s.get("label") or s.get("name")
                if label:
                    dataset_id = str(label)
                    break
        dataset_id = dataset_id or str(doc.get("name") or "galaxy-input")
        spec = cls(dataset_id, workflow_id=str(doc.get("name") or ""))

        def _module_id(tool_id: str) -> str:
            if simplify_tool_ids and "/" in tool_id:
                parts = [p for p in tool_id.split("/") if p]
                # toolshed ids end in .../<short_name>/<version>
                return parts[-2] if len(parts) >= 2 else parts[-1]
            return tool_id

        for s in ordered:
            sid = str(s.get("id"))
            if sid in inputs:
                continue
            tool_id = s.get("tool_id") or s.get("name") or f"step{sid}"
            params: dict[str, Any] = {}
            raw_state = s.get("tool_state")
            if isinstance(raw_state, str):
                try:
                    raw_state = json.loads(raw_state)
                except ValueError:
                    raw_state = {}
            if isinstance(raw_state, Mapping):
                params = {
                    k: v
                    for k, v in raw_state.items()
                    if not str(k).startswith("__")
                }
            parents: list[str] = []
            conns = s.get("input_connections") or {}
            for conn in conns.values():
                entries = conn if isinstance(conn, list) else [conn]
                for entry in entries:
                    if not isinstance(entry, Mapping):
                        continue
                    pid = str(entry.get("id"))
                    if pid in inputs or pid in parents:
                        continue  # dataset inputs make the node a root
                    parents.append(pid)
            label = s.get("label")
            node_id = str(label) if label else sid
            spec.add(node_id, _module_id(str(tool_id)), params or None, parents or None)

        # Galaxy connections reference numeric step ids; relabel parents that
        # point at steps we renamed via labels
        id_to_node = {
            str(s.get("id")): (str(s.get("label")) if s.get("label") else str(s.get("id")))
            for s in ordered
            if str(s.get("id")) not in inputs
        }
        renamed: dict[str, NodeSpec] = {}
        for n in spec._nodes.values():
            renamed[n.node_id] = NodeSpec(
                n.node_id,
                n.module_id,
                n.params,
                tuple(id_to_node.get(p, p) for p in n.after),
            )
        spec._nodes = renamed
        spec.validate()
        return spec

    def __repr__(self) -> str:
        return (
            f"WorkflowSpec(dataset_id={self.dataset_id!r}, "
            f"workflow_id={self.workflow_id!r}, nodes={len(self)}, "
            f"digest={self.digest})"
        )
