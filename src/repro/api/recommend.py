"""Recommendation surface: ranked reuse and next-module suggestions.

The thesis' headline contribution (Ch. 4) is an *automatic recommendation
technique*: while a user composes a workflow, the system surfaces (a) stored
intermediate states the partial workflow can start from and (b) the module
sequences users historically applied next — the interaction pattern the
companion design study (arXiv:2010.04880) found users want *during*
composition, not after.  This module makes that pipeline public: it reads the
same :class:`~repro.core.rules.RuleMiner` state the storage policies maintain
(no extra bookkeeping) and ranks:

  * **reusable prefixes** — prefixes of the partial chain worth starting
    from, deepest first (the deepest is the thesis' skip point): either the
    policy claims them stored, or the mined history supports them (the
    prefix appeared in >=2 pipelines — PT's obtained-from-history gate, the
    replayed-corpus case where no artifact was ever persisted locally).
    ``stored`` flags artifacts live in the store *right now*;
  * **next modules** — association rules that extend the partial chain by
    one module, ranked by confidence then support (Ch. 4.3.3's "longest
    highest-confidence rule" ordering, applied incrementally).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from ..core.risp import StoragePolicy
from ..core.store import IntermediateStore
from ..core.workflow import ModuleRef, PrefixKey


@dataclass(frozen=True)
class Suggestion:
    """One ranked recommendation.

    ``kind`` is ``"reusable_prefix"`` (start from this stored state; its
    depth tells how many modules the user skips) or ``"next_module"``
    (``module_id`` extends the partial chain; ``prefix`` is the extended
    chain the rule describes).
    """

    kind: str
    prefix: PrefixKey
    support: int
    dataset_support: int
    stored: bool
    module_id: str | None = None

    @property
    def confidence(self) -> float:
        return self.support / self.dataset_support if self.dataset_support else 0.0

    @property
    def depth(self) -> int:
        return self.prefix.depth

    def describe(self) -> str:
        mods = ">".join(m.module_id for m in self.prefix.modules)
        if self.kind == "next_module":
            return (
                f"next: {self.module_id} (confidence {self.confidence:.2f}, "
                f"support {self.support}) -> {mods}"
            )
        live = "stored" if self.stored else "recommended"
        return (
            f"reuse depth {self.depth} [{live}]: {mods} "
            f"(confidence {self.confidence:.2f}, support {self.support})"
        )


@dataclass
class RecommendReport:
    """Both suggestion lists for one partial workflow."""

    dataset_id: str
    depth: int  # partial-chain length the suggestions are relative to
    reusable_prefixes: list[Suggestion]
    next_modules: list[Suggestion]

    @property
    def best_reuse(self) -> Suggestion | None:
        return self.reusable_prefixes[0] if self.reusable_prefixes else None

    @property
    def best_next(self) -> Suggestion | None:
        return self.next_modules[0] if self.next_modules else None


class Recommender:
    """Ranks suggestions from a policy's mined history + the live store.

    Shares the policy's ``RuleMiner`` and ``stored`` bookkeeping — feeding
    the recommender is just running (or replaying) workflows through the
    policy.  An index over ``(dataset, depth)`` is rebuilt lazily whenever
    the miner has advanced, so repeated ``recommend`` calls between runs are
    O(candidate rules), not O(all rules).
    """

    def __init__(
        self,
        policy: StoragePolicy,
        store: IntermediateStore | None = None,
    ) -> None:
        self.policy = policy
        self.store = store
        self._index: dict[tuple[str, int], list[PrefixKey]] = {}
        self._indexed_at = -1

    # -- index ---------------------------------------------------------------
    def _refresh(self) -> None:
        miner = self.policy.miner
        with self.policy.lock:
            if miner.n_pipelines == self._indexed_at:
                return
            index: dict[tuple[str, int], list[PrefixKey]] = {}
            for prefix in miner.iter_prefixes():
                index.setdefault((prefix.dataset_id, prefix.depth), []).append(prefix)
            self._index = index
            self._indexed_at = miner.n_pipelines

    def _is_live(self, key: str) -> bool:
        return self.store is not None and self.store.has(key)

    # -- queries ---------------------------------------------------------------
    def recommend(
        self,
        dataset_id: str,
        modules: Sequence[ModuleRef] = (),
        top_k: int = 5,
    ) -> RecommendReport:
        """Suggestions for the partial chain ``dataset_id => modules``.

        ``modules`` may be empty: then only next-module (first-module)
        suggestions are produced.
        """
        self._refresh()
        miner = self.policy.miner
        with_state = self.policy.with_state
        modules = tuple(modules)

        # snapshot miner/policy state under the lock; store liveness probes
        # happen after release (documented lock order: never call store
        # methods while holding the policy lock)
        reuse_cands: list[tuple[PrefixKey, str, int]] = []
        next_cands: list[tuple[PrefixKey, str, int]] = []
        with self.policy.lock:
            ds_support = miner.dataset_support(dataset_id)
            for k in range(len(modules), 0, -1):
                prefix = PrefixKey(dataset_id, modules[:k])
                key = prefix.key(with_state)
                support = miner.support_of_key(key)
                if key in self.policy.stored or support >= 2:
                    reuse_cands.append((prefix, key, support))
            chain_key = (
                PrefixKey(dataset_id, modules).key(with_state) if modules else None
            )
            for cand in self._index.get((dataset_id, len(modules) + 1), ()):
                parent = cand.parent()
                parent_key = parent.key(with_state) if parent is not None else None
                if parent_key != chain_key:
                    continue
                key = cand.key(with_state)
                next_cands.append((cand, key, miner.support_of_key(key)))

        reusable = [
            Suggestion(
                kind="reusable_prefix",
                prefix=prefix,
                support=support,
                dataset_support=ds_support,
                stored=self._is_live(key),
            )
            for prefix, key, support in reuse_cands[:top_k]
        ]
        nxt = [
            Suggestion(
                kind="next_module",
                prefix=cand,
                support=support,
                dataset_support=ds_support,
                stored=self._is_live(key),
                module_id=cand.modules[-1].module_id,
            )
            for cand, key, support in next_cands
        ]
        nxt.sort(key=lambda s: (-s.confidence, -s.support, s.module_id or ""))
        # one suggestion per module id (rules are per tool-state under
        # with_state=True; a frequently re-parameterized module must not
        # crowd every other next-module out of the report) — the kept entry
        # is that module's highest-confidence state
        seen_modules: set[str] = set()
        deduped = []
        for s in nxt:
            if s.module_id in seen_modules:
                continue
            seen_modules.add(s.module_id or "")
            deduped.append(s)
        return RecommendReport(
            dataset_id=dataset_id,
            depth=len(modules),
            reusable_prefixes=reusable,
            next_modules=deduped[:top_k],
        )
