"""Recommendation surface: ranked reuse and next-module suggestions.

The thesis' headline contribution (Ch. 4) is an *automatic recommendation
technique*: while a user composes a workflow, the system surfaces (a) stored
intermediate states the partial workflow can start from and (b) the module
sequences users historically applied next — the interaction pattern the
companion design study (arXiv:2010.04880) found users want *during*
composition, not after.  This module makes that pipeline public: it reads the
same :class:`~repro.core.rules.RuleMiner` state the storage policies maintain
(no extra bookkeeping) and ranks:

  * **reusable prefixes** — prefixes of the partial chain worth starting
    from, deepest first (the deepest is the thesis' skip point): either the
    policy claims them stored, or the mined history supports them (the
    prefix appeared in >=2 pipelines — PT's obtained-from-history gate, the
    replayed-corpus case where no artifact was ever persisted locally).
    ``stored`` flags artifacts live in the store *right now*;
  * **next modules** — association rules that extend the partial chain by
    one module, ranked by confidence then support (Ch. 4.3.3's "longest
    highest-confidence rule" ordering, applied incrementally).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

from ..core.risp import StoragePolicy
from ..core.store import IntermediateStore
from ..core.workflow import ModuleRef, PrefixKey, decode_param

if TYPE_CHECKING:  # pragma: no cover - typing only
    from ..catalog import Catalog


@dataclass(frozen=True)
class Suggestion:
    """One ranked recommendation.

    ``kind`` is ``"reusable_prefix"`` (start from this stored state; its
    depth tells how many modules the user skips), ``"next_module"``
    (``module_id`` extends the partial chain; ``prefix`` is the extended
    chain the rule describes), or ``"near_miss"`` (a *stored* artifact with
    the same module-id chain but exactly one differing parameter — served
    from the catalog; ``note`` names the difference).
    """

    kind: str
    prefix: PrefixKey
    support: int
    dataset_support: int
    stored: bool
    module_id: str | None = None
    note: str = ""

    @property
    def confidence(self) -> float:
        return self.support / self.dataset_support if self.dataset_support else 0.0

    @property
    def depth(self) -> int:
        return self.prefix.depth

    def describe(self) -> str:
        mods = ">".join(m.module_id for m in self.prefix.modules)
        if self.kind == "next_module":
            return (
                f"next: {self.module_id} (confidence {self.confidence:.2f}, "
                f"support {self.support}) -> {mods}"
            )
        if self.kind == "near_miss":
            return f"near miss [{self.note}]: {mods} (loads {self.support})"
        live = "stored" if self.stored else "recommended"
        return (
            f"reuse depth {self.depth} [{live}]: {mods} "
            f"(confidence {self.confidence:.2f}, support {self.support})"
        )


@dataclass
class RecommendReport:
    """Suggestion lists for one partial workflow."""

    dataset_id: str
    depth: int  # partial-chain length the suggestions are relative to
    reusable_prefixes: list[Suggestion]
    next_modules: list[Suggestion]
    near_misses: list[Suggestion] = field(default_factory=list)

    @property
    def best_reuse(self) -> Suggestion | None:
        return self.reusable_prefixes[0] if self.reusable_prefixes else None

    @property
    def best_next(self) -> Suggestion | None:
        return self.next_modules[0] if self.next_modules else None

    @property
    def best_near_miss(self) -> Suggestion | None:
        return self.near_misses[0] if self.near_misses else None


class Recommender:
    """Ranks suggestions from a policy's mined history + the live store.

    Shares the policy's ``RuleMiner`` and ``stored`` bookkeeping — feeding
    the recommender is just running (or replaying) workflows through the
    policy.  An index over ``(dataset, depth)`` is rebuilt lazily whenever
    the miner has advanced, so repeated ``recommend`` calls between runs are
    O(candidate rules), not O(all rules).
    """

    def __init__(
        self,
        policy: StoragePolicy,
        store: IntermediateStore | None = None,
        catalog: "Catalog | None" = None,
    ) -> None:
        self.policy = policy
        self.store = store
        self.catalog = catalog
        self._index: dict[tuple[str, int], list[PrefixKey]] = {}
        self._indexed_at = -1

    # -- index ---------------------------------------------------------------
    def _refresh(self) -> None:
        miner = self.policy.miner
        with self.policy.lock:
            if miner.n_pipelines == self._indexed_at:
                return
            index: dict[tuple[str, int], list[PrefixKey]] = {}
            for prefix in miner.iter_prefixes():
                index.setdefault((prefix.dataset_id, prefix.depth), []).append(prefix)
            self._index = index
            self._indexed_at = miner.n_pipelines

    def _is_live(self, key: str) -> bool:
        return self.store is not None and self.store.has(key)

    # -- queries ---------------------------------------------------------------
    def recommend(
        self,
        dataset_id: str,
        modules: Sequence[ModuleRef] = (),
        top_k: int = 5,
    ) -> RecommendReport:
        """Suggestions for the partial chain ``dataset_id => modules``.

        ``modules`` may be empty: then only next-module (first-module)
        suggestions are produced.
        """
        self._refresh()
        miner = self.policy.miner
        with_state = self.policy.with_state
        modules = tuple(modules)

        # snapshot miner/policy state under the lock; store liveness probes
        # happen after release (documented lock order: never call store
        # methods while holding the policy lock)
        reuse_cands: list[tuple[PrefixKey, str, int]] = []
        next_cands: list[tuple[PrefixKey, str, int]] = []
        with self.policy.lock:
            ds_support = miner.dataset_support(dataset_id)
            for k in range(len(modules), 0, -1):
                prefix = PrefixKey(dataset_id, modules[:k])
                key = prefix.key(with_state)
                support = miner.support_of_key(key)
                if key in self.policy.stored or support >= 2:
                    reuse_cands.append((prefix, key, support))
            chain_key = (
                PrefixKey(dataset_id, modules).key(with_state) if modules else None
            )
            for cand in self._index.get((dataset_id, len(modules) + 1), ()):
                parent = cand.parent()
                parent_key = parent.key(with_state) if parent is not None else None
                if parent_key != chain_key:
                    continue
                key = cand.key(with_state)
                next_cands.append((cand, key, miner.support_of_key(key)))

        reusable = [
            Suggestion(
                kind="reusable_prefix",
                prefix=prefix,
                support=support,
                dataset_support=ds_support,
                stored=self._is_live(key),
            )
            for prefix, key, support in reuse_cands[:top_k]
        ]
        nxt = [
            Suggestion(
                kind="next_module",
                prefix=cand,
                support=support,
                dataset_support=ds_support,
                stored=self._is_live(key),
                module_id=cand.modules[-1].module_id,
            )
            for cand, key, support in next_cands
        ]
        nxt.sort(key=lambda s: (-s.confidence, -s.support, s.module_id or ""))
        # one suggestion per module id (rules are per tool-state under
        # with_state=True; a frequently re-parameterized module must not
        # crowd every other next-module out of the report) — the kept entry
        # is that module's highest-confidence state
        seen_modules: set[str] = set()
        deduped = []
        for s in nxt:
            if s.module_id in seen_modules:
                continue
            seen_modules.add(s.module_id or "")
            deduped.append(s)
        return RecommendReport(
            dataset_id=dataset_id,
            depth=len(modules),
            reusable_prefixes=reusable,
            next_modules=deduped[:top_k],
            near_misses=self.near_misses(dataset_id, modules, top_k=top_k),
        )

    def near_misses(
        self,
        dataset_id: str,
        modules: Sequence[ModuleRef] = (),
        top_k: int = 5,
    ) -> list[Suggestion]:
        """Stored artifacts one parameter away from the partial chain.

        A *near miss* has the exact module-id chain of ``dataset_id =>
        modules`` but exactly one differing (or extra/missing) parameter
        somewhere along it — the catalog's answer to "someone already ran
        almost this; is their setting the one you meant?".  Served entirely
        from the :class:`~repro.catalog.Catalog` (empty without one), ranked
        by reuse count then recency.  ``dataset_id`` may be namespaced
        (``ns/dataset``); matching is namespace-exact.
        """
        if self.catalog is None or not modules:
            return []
        from ..catalog.records import split_namespaced_dataset

        modules = tuple(modules)
        chain = tuple(m.module_id for m in modules)
        ns, ds = split_namespaced_dataset(dataset_id)
        try:
            records = self.catalog.find(
                module=chain[-1],
                dataset=ds,
                namespace=ns or "",
                limit=max(64, top_k * 8),
            )
        except Exception:  # noqa: BLE001 - advisory surface: degrade to none
            return []

        own_params = [dict(m.state.params) for m in modules]
        hits: list[tuple[tuple, Suggestion]] = []
        for rec in records:
            if rec.modules != chain or rec.depth != len(chain):
                continue
            note = self._one_param_diff(own_params, rec.states, chain)
            if note is None:
                continue
            hits.append(
                (
                    (-rec.n_loads, -rec.last_used_at, rec.key),
                    Suggestion(
                        kind="near_miss",
                        prefix=rec.prefix_key(),
                        support=rec.n_loads,
                        dataset_support=rec.n_loads,
                        stored=True,
                        module_id=chain[-1],
                        note=note,
                    ),
                )
            )
        hits.sort(key=lambda it: it[0])
        return [s for _, s in hits[:top_k]]

    @staticmethod
    def _one_param_diff(
        own: "list[dict[str, str]]",
        theirs: "Sequence[dict[str, str] | Mapping[str, str]]",
        chain: "tuple[str, ...]",
    ) -> str | None:
        """Describe the single differing encoded param, or None if the
        chains differ by zero params (identical — a reuse hit, not a near
        miss) or by more than one."""
        diffs: list[str] = []
        for pos, module_id in enumerate(chain):
            mine = own[pos]
            other = dict(theirs[pos]) if pos < len(theirs) else {}
            for name in sorted(set(mine) | set(other)):
                a, b = mine.get(name), other.get(name)
                if a == b:
                    continue
                if len(diffs) >= 2:
                    return None
                mine_s = repr(decode_param(a)) if a is not None else "unset"
                their_s = repr(decode_param(b)) if b is not None else "unset"
                diffs.append(f"{module_id}.{name}={their_s} (yours {mine_s})")
        return diffs[0] if len(diffs) == 1 else None
