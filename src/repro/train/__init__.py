from .step import (
    REMAT_POLICIES,
    build_loss_fn,
    build_param_specs,
    build_serve_step,
    build_train_step,
    make_train_state,
)

__all__ = [
    "REMAT_POLICIES",
    "build_loss_fn",
    "build_param_specs",
    "build_serve_step",
    "build_train_step",
    "make_train_state",
]
