"""train_step / serve_step builders for every architecture family.

``build_param_specs(cfg, cell)`` -> PSpec tree
``build_train_step(cfg, ...)``  -> fn(state, batch) -> (state, metrics)
``build_serve_step(cfg, cell)`` -> fn(params, **inputs) -> outputs

All functions are pure and jit-able; distribution comes from in/out shardings
applied by the launcher (GSPMD propagates through the step).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import Config, GNNConfig, LMConfig, RecsysConfig, ShapeCell
from ..models import gnn, recsys, transformer
from ..optim import AdamWConfig, apply_updates, init_state

REMAT_POLICIES: dict[str, Any] = {
    "none": None,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "full": jax.checkpoint_policies.nothing_saveable,
}


# --------------------------------------------------------------------------
# param specs
# --------------------------------------------------------------------------
def build_param_specs(cfg: Config, cell: ShapeCell | None = None) -> Any:
    if isinstance(cfg, LMConfig):
        return transformer.lm_specs(cfg)
    if isinstance(cfg, GNNConfig):
        d_feat = 16
        if cell is not None:
            d_feat = cell.params.get("d_feat", 602 if cell.kind == "minibatch" else 16)
        return gnn.gnn_specs(cfg, d_feat)
    if isinstance(cfg, RecsysConfig):
        return {
            "fm-2way": recsys.fm_specs,
            "cross": recsys.dcn_specs,
            "transformer-seq": recsys.bst_specs,
            "self-attn-seq": recsys.sasrec_specs,
        }[cfg.interaction](cfg)
    raise TypeError(type(cfg))


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def _lm_loss(params, cfg: LMConfig, batch, *, remat=None, unroll=1):
    if cfg.loss_vocab_chunks:
        x, _ = transformer.forward(
            params, cfg, batch["tokens"], remat=remat, unroll=unroll, no_head=True
        )
        head = params.get("head")
        if head is None:
            head = params["embed"].T
        loss = transformer.streaming_ce_loss(
            x, head, batch["targets"], cfg.loss_vocab_chunks
        )
        return loss, {"loss": loss, "ppl_proxy": loss}
    logits, _ = transformer.forward(
        params, cfg, batch["tokens"], remat=remat, unroll=unroll
    )
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, batch["targets"][..., None], axis=-1)[..., 0]
    loss = ce.mean()
    return loss, {"loss": loss, "ppl_proxy": loss}


def _bce(logits, labels):
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def _gnn_loss(params, cfg: GNNConfig, batch, cell: ShapeCell, remat=None):
    if cell.kind == "batched_graphs":
        logits = gnn.forward_batched(params, cfg, batch["node_feat"], batch["edge_index"])
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1).mean()
        return loss, {"loss": loss}
    if cell.kind == "minibatch":
        logits = gnn.forward(params, cfg, batch["node_feat"], batch["edge_index"])
        seed_logits = logits[: batch["labels"].shape[0]]
        logp = jax.nn.log_softmax(seed_logits, axis=-1)
        loss = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1).mean()
        return loss, {"loss": loss}
    mask = batch.get("train_mask")
    loss = gnn.loss_fn(
        params, cfg, batch["node_feat"], batch["edge_index"], batch["labels"],
        mask.astype(jnp.float32) if mask is not None else None,
        remat=remat,
    )
    return loss, {"loss": loss}


def _recsys_loss(params, cfg: RecsysConfig, batch):
    if cfg.interaction == "fm-2way":
        logits = recsys.fm_forward(params, cfg, batch["sparse_ids"])
        loss = _bce(logits, batch["labels"])
    elif cfg.interaction == "cross":
        logits = recsys.dcn_forward(params, cfg, batch["dense"], batch["sparse_ids"])
        loss = _bce(logits, batch["labels"])
    elif cfg.interaction == "transformer-seq":
        logits = recsys.bst_forward(params, cfg, batch["hist_ids"], batch["target_id"])
        loss = _bce(logits, batch["labels"])
    elif cfg.interaction == "self-attn-seq":
        pos, neg = recsys.sasrec_forward(
            params, cfg, batch["hist_ids"], batch["pos_ids"], batch["neg_ids"]
        )
        loss = -jnp.mean(jax.nn.log_sigmoid(pos - neg))  # BPR
    else:
        raise ValueError(cfg.interaction)
    return loss, {"loss": loss}


def build_loss_fn(
    cfg: Config, cell: ShapeCell | None = None, *, remat: str = "none", unroll: int = 1
) -> Callable:
    if isinstance(cfg, LMConfig):
        policy = REMAT_POLICIES[remat]
        return lambda params, batch: _lm_loss(
            params, cfg, batch, remat=policy, unroll=unroll
        )
    if isinstance(cfg, GNNConfig):
        assert cell is not None
        policy = REMAT_POLICIES[remat]
        return lambda params, batch: _gnn_loss(params, cfg, batch, cell, remat=policy)
    if isinstance(cfg, RecsysConfig):
        return lambda params, batch: _recsys_loss(params, cfg, batch)
    raise TypeError(type(cfg))


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------
def make_train_state(params: Any, opt_cfg: AdamWConfig | None = None) -> dict:
    return {"params": params, "opt": init_state(params)}


def build_train_step(
    cfg: Config,
    cell: ShapeCell | None = None,
    opt_cfg: AdamWConfig | None = None,
    *,
    remat: str = "none",
    unroll: int = 1,
    grad_accum: int = 1,
) -> Callable:
    """Returns fn(state, batch) -> (state, metrics)."""
    opt_cfg = opt_cfg or AdamWConfig()
    loss_fn = build_loss_fn(cfg, cell, remat=remat, unroll=unroll)

    def single_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        if grad_accum == 1:
            _, metrics, grads = single_grads(params, batch)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
                batch,
            )

            def accum(carry, mb):
                _, metrics, g = single_grads(params, mb)
                return (
                    jax.tree_util.tree_map(jnp.add, carry[0], g),
                    jax.tree_util.tree_map(jnp.add, carry[1], metrics),
                ), None

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            zero_m = {"loss": jnp.zeros((), jnp.float32)}
            if isinstance(cfg, LMConfig):
                zero_m["ppl_proxy"] = jnp.zeros((), jnp.float32)
            (grads, metrics), _ = jax.lax.scan(accum, (zero_g, zero_m), micro)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            metrics = jax.tree_util.tree_map(lambda m: m / grad_accum, metrics)
        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, state["opt"], opt_cfg
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


# --------------------------------------------------------------------------
# serve step
# --------------------------------------------------------------------------
def build_serve_step(cfg: Config, cell: ShapeCell, *, unroll: int = 1) -> Callable:
    if isinstance(cfg, LMConfig):
        if cell.kind == "prefill":

            def prefill_step(params, tokens):
                return transformer.prefill(params, cfg, tokens, unroll=unroll)

            return prefill_step
        if cell.kind == "decode":

            def decode_step(params, tokens, cache, cache_len):
                return transformer.decode_step(
                    params, cfg, tokens, cache, cache_len, unroll=unroll
                )

            return decode_step
        raise ValueError(cell.kind)

    if isinstance(cfg, RecsysConfig):
        if cell.kind == "retrieval":
            fn = {
                "fm-2way": lambda p, **b: recsys.fm_retrieval(
                    p, cfg, b["sparse_ids"], b["candidate_ids"]
                ),
                "cross": lambda p, **b: recsys.dcn_retrieval(
                    p, cfg, b["dense"], b["sparse_ids"], b["candidate_ids"]
                ),
                "transformer-seq": lambda p, **b: recsys.bst_retrieval(
                    p, cfg, b["hist_ids"], b["candidate_ids"]
                ),
                "self-attn-seq": lambda p, **b: recsys.sasrec_retrieval(
                    p, cfg, b["hist_ids"], b["candidate_ids"]
                ),
            }[cfg.interaction]
            return fn

        def score(params, **batch):
            if cfg.interaction == "fm-2way":
                return recsys.fm_forward(params, cfg, batch["sparse_ids"])
            if cfg.interaction == "cross":
                return recsys.dcn_forward(params, cfg, batch["dense"], batch["sparse_ids"])
            if cfg.interaction == "transformer-seq":
                return recsys.bst_forward(params, cfg, batch["hist_ids"], batch["target_id"])
            if cfg.interaction == "self-attn-seq":
                pos, neg = recsys.sasrec_forward(
                    params, cfg, batch["hist_ids"], batch["pos_ids"], batch["neg_ids"]
                )
                return pos
            raise ValueError(cfg.interaction)

        return score

    raise TypeError(f"no serve step for {type(cfg)}")
