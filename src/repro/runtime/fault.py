"""Fault-tolerant training driver: checkpoint-restart with failure injection.

On a real multi-host pod the same loop runs per-host under
``jax.distributed.initialize``; the coordination service detects dead hosts
and the job restarts from ``CheckpointManager.restore`` (optionally onto a
smaller mesh — elastic).  Here the loop is single-process but exercises the
full restart path: deterministic batch re-assignment (step -> data seed),
crash injection, resume from the latest durable checkpoint.

Straggler mitigation at scale (documented design, see DESIGN §8): synchronous
steps bound straggler damage to one step; slow hosts are detected by
per-step heartbeat timing and evicted by restarting onto the healthy subset
(elastic restore); the input pipeline is prefetched host-side so data never
gates the step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from ..ckpt import CheckpointManager


class InjectedFailure(RuntimeError):
    pass


@dataclass
class TrainDriver:
    train_step: Callable[[dict, dict], tuple[dict, dict]]
    make_batch: Callable[[int], dict]  # step -> batch (deterministic reassignment)
    ckpt: CheckpointManager
    ckpt_every: int = 10
    fail_at_steps: tuple[int, ...] = ()  # injected crashes (once each)
    log: list[dict] = field(default_factory=list)
    _failed: set = field(default_factory=set)

    def run(self, state: dict, n_steps: int) -> tuple[dict, list[dict]]:
        """Run to ``n_steps``, restarting on failure. Returns (state, log)."""
        step = 0
        restored = self.ckpt.latest_step()
        if restored is not None:
            step, state = self.ckpt.restore()
        jitted = jax.jit(self.train_step)
        while step < n_steps:
            try:
                if step in self.fail_at_steps and step not in self._failed:
                    self._failed.add(step)
                    raise InjectedFailure(f"injected node failure at step {step}")
                t0 = time.perf_counter()
                state, metrics = jitted(state, self.make_batch(step))
                metrics = {k: float(v) for k, v in metrics.items()}
                step += 1
                self.log.append(
                    {"step": step, "seconds": time.perf_counter() - t0, **metrics}
                )
                if step % self.ckpt_every == 0 or step == n_steps:
                    self.ckpt.save(step, state)
            except InjectedFailure:
                # restart path: restore last durable checkpoint, re-derive the
                # batch stream from the restored step (no data loss/dup)
                restored = self.ckpt.latest_step()
                if restored is None:
                    step = 0
                    self.log.append({"event": "restart", "from_step": 0})
                    continue
                step, state = self.ckpt.restore()
                self.log.append({"event": "restart", "from_step": step})
        self.ckpt.wait()
        return state, self.log
