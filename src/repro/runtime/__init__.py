from .fault import InjectedFailure, TrainDriver

__all__ = ["InjectedFailure", "TrainDriver"]
